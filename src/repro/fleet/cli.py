"""Ensemble fleet driver: schedule many solver jobs, survive the chaos.

    PYTHONPATH=src python -m repro.fleet.cli --case heat --n 16 --steps 4 \\
        --jobs 4 --submesh 2x1 --slots 8 --ckpt-every 2 --report fleet.json

    # the CI chaos smoke: kill every worker after step 3, prove the merged
    # observables equal the unkilled campaign's bit for bit
    PYTHONPATH=src python -m repro.fleet.cli --case heat --n 16 --steps 4 \\
        --jobs 4 --submesh 2x1 --inject kill-at-step:3 --report chaos.json

    # a parameter sweep: one job per value, e.g. four diffusivities
    PYTHONPATH=src python -m repro.fleet.cli --case heat --n 16 --steps 4 \\
        --sweep kappa=0.05,0.1,0.15,0.2 --submesh 2x2 --slots 8

Builds the ensemble (``--sweep key=v1,v2,...`` makes one job per value;
otherwise ``--jobs K`` replicas at staggered initial amplitudes), runs it
through :class:`repro.fleet.controller.FleetController` — supervised
subprocess workers, checkpoint/restart, fault injection, retry with capped
backoff, quarantine on an exhausted budget — prints a per-job summary plus
the ``fleet.*`` counters, and optionally writes the full
``fleet-report/v1`` JSON. Exit code 0 when every job completed, 1 when any
was quarantined (the campaign itself always runs to completion either
way).
"""

from __future__ import annotations

import argparse
import json
import tempfile


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.fleet.cli",
        description="Fault-tolerant ensemble scheduler for repro.solvers.")
    ap.add_argument("--case", default="heat",
                    help="solver case every job runs (default: heat)")
    ap.add_argument("--n", type=int, default=16, help="cubic grid extent N")
    ap.add_argument("--steps", type=int, default=4, help="Δt steps per job")
    ap.add_argument("--jobs", type=int, default=4,
                    help="ensemble size when --sweep is not given; members "
                         "differ by initial-condition amplitude")
    ap.add_argument("--sweep", default="",
                    help="key=v1,v2,... — one job per swept physics value "
                         "(e.g. kappa=0.05,0.1,0.2)")
    ap.add_argument("--submesh", default="2x1",
                    help="PUxPV submesh each job runs on (default 2x1)")
    ap.add_argument("--slots", type=int, default=8,
                    help="device-slot pool the controller packs jobs into")
    ap.add_argument("--dt", type=float, default=None)
    ap.add_argument("--dtype", default="float64")
    ap.add_argument("--ckpt-every", type=int, default=2,
                    help="checkpoint cadence in steps (default 2)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="per-job retry budget before quarantine")
    ap.add_argument("--timeout", type=float, default=600.0,
                    help="per-attempt deadline in seconds (timeout class)")
    ap.add_argument("--inject", default="",
                    help="fault spec (see repro.fleet.faults): e.g. "
                         "'kill-at-step:3' or "
                         "'slow-at-step:2:30@job=job1;kill-at-step:1'")
    ap.add_argument("--reshape-on-retry", default="",
                    help="comma list of PUxPV shapes retries cycle through "
                         "(elastic restore), e.g. '1x2,2x1'")
    ap.add_argument("--workdir", default="",
                    help="campaign dir for specs/logs/checkpoints/reports "
                         "(default: a fresh temp dir)")
    ap.add_argument("--report", default="",
                    help="write the fleet-report/v1 JSON here")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--trace", dest="trace_path", default="",
                    help="write a Chrome-trace JSON of the fleet.* counters")
    return ap


def _parse_shapes(text: str) -> tuple:
    shapes = []
    for tok in text.split(","):
        tok = tok.strip()
        if not tok:
            continue
        try:
            pu, pv = (int(t) for t in tok.lower().split("x"))
        except ValueError:
            raise SystemExit(f"--reshape-on-retry wants PUxPV shapes, "
                             f"got {tok!r}")
        shapes.append((pu, pv))
    return tuple(shapes)


def build_jobs(args) -> list:
    from repro.fleet.controller import FleetJob

    try:
        pu, pv = (int(t) for t in args.submesh.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--submesh must look like 2x2, got {args.submesh!r}")
    common = dict(case=args.case, n=args.n, steps=args.steps, mesh=(pu, pv),
                  dt=args.dt, dtype=args.dtype)
    if args.sweep:
        key, _, vals = args.sweep.partition("=")
        if not key or not vals:
            raise SystemExit(f"--sweep wants key=v1,v2,..., got {args.sweep!r}")
        return [FleetJob(job_id=f"job{i}", params={key: float(v)}, **common)
                for i, v in enumerate(vals.split(","))]
    if args.jobs < 1:
        raise SystemExit(f"--jobs must be >= 1, got {args.jobs}")
    # replicas at staggered amplitudes: distinct trajectories, one physics
    return [FleetJob(job_id=f"job{i}", scale=1.0 + 0.25 * i, **common)
            for i in range(args.jobs)]


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    from repro import obs
    if args.trace_path:
        obs.clear()
        obs.enable()

    from repro.fleet.controller import FleetController
    jobs = build_jobs(args)
    workdir = args.workdir or tempfile.mkdtemp(prefix="repro-fleet-")
    try:
        ctl = FleetController(
            jobs, workdir=workdir, total_slots=args.slots,
            max_retries=args.max_retries, timeout_s=args.timeout,
            ckpt_every=args.ckpt_every, fault_spec=args.inject,
            reshape_on_retry=_parse_shapes(args.reshape_on_retry),
            verbose=not args.quiet)
    except ValueError as e:
        raise SystemExit(f"invalid fleet config: {e}")

    print(f"fleet: {len(jobs)} x {args.case} N={args.n}^3 steps={args.steps} "
          f"on {args.submesh} submeshes over {args.slots} slots "
          f"(retries={args.max_retries}"
          f"{', inject ' + args.inject if args.inject else ''})", flush=True)
    results = ctl.run()

    for jid in sorted(results):
        res = results[jid]
        final = res.final_observables()
        tail = ("  ".join(f"{k}={v:.6e}" for k, v in sorted(final.items())
                          if k != "t") if final else "no observables")
        print(f"  {jid}: {res.status} ({res.attempts} attempt(s), "
              f"{len(res.failures)} failure(s))  {tail}")
    print("counters: " + "  ".join(
        f"{k.split('fleet.')[-1]}={int(v)}"
        for k, v in sorted(ctl.counters.items())))

    if args.report:
        with open(args.report, "w") as f:
            json.dump(ctl.report(results), f, indent=1)
        print(f"wrote report {args.report}")
    if args.trace_path:
        obs.disable()
        obs.write_chrome_trace(args.trace_path, obs.tracer, obs.metrics)
        print(f"wrote trace {args.trace_path}")
    return 0 if all(r.ok for r in results.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
