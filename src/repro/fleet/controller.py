"""The fleet controller: supervised subprocess workers over submesh slots.

One :class:`FleetController` runs an *ensemble* — many independent solver
jobs (a parameter sweep, replicas at different amplitudes) — against a
fixed pool of device slots. Each :class:`FleetJob` names a registered
``repro.solvers`` case, a horizon in Δt steps, and the Pu×Pv submesh shape
it runs on; the controller packs jobs onto the pool (a job occupies
``pu·pv`` slots while running), launches each as a supervised
``python -m repro.fleet.worker`` subprocess, and babysits it to completion.

**Failure handling** is the whole point. When a worker dies the controller
classifies the death:

* ``crash``   — nonzero exit (incl. the fault injector's hard kill):
  retryable;
* ``timeout`` — the worker outlived its deadline and was killed by the
  supervisor (wedged collective, injected ``slow-at-step``): retryable;
* ``poison``  — the worker reported an invalid job spec
  (``records.POISON_EXIT``): deterministic, never retried.

Retryable failures are rescheduled from the job's **latest checkpoint**
(the worker resumes automatically via ``SpectralSolver.restore_state``)
with capped exponential backoff, up to a per-job retry budget. A job that
exhausts its budget is **quarantined** with its full
:class:`~repro.fleet.records.FailureRecord` trail — and the rest of the
ensemble keeps running: graceful degradation, never a wedged campaign.
Because checkpoints restore elastically, a retry may even land on a
*different* submesh shape (``reshape_on_retry``).

**Device partitioning model.** On the fake-host-device substrate each
worker is its own process pinning exactly its submesh's device count
(``XLA_FLAGS`` is scrubbed from the worker env; the worker calls
``ensure_host_devices(pu·pv)``), so the slot ledger here *is* the
partition: disjoint slot ranges, never oversubscribed. On real hardware
the same ledger would hand each worker a device-id range instead.

Counters (mirrored into ``repro.obs`` when tracing and always available on
``FleetController.counters`` for the report): ``fleet.jobs.scheduled`` /
``completed`` / ``failures`` / ``retried`` / ``quarantined``, plus
``fleet.checkpoint.bytes`` and the ``fleet.restore.latency_us`` gauge
aggregated from worker reports.

This module is jax-free — only the workers touch device state.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import math
import os
import subprocess
import sys
import time

from repro import obs
from repro.fleet import faults as _faults
from repro.fleet.records import FailureRecord, classify_exit

_COUNTERS = ("fleet.jobs.scheduled", "fleet.jobs.completed",
             "fleet.jobs.failures", "fleet.jobs.retried",
             "fleet.jobs.quarantined", "fleet.checkpoint.bytes")


@dataclasses.dataclass(frozen=True)
class FleetJob:
    """One ensemble member: a solver problem plus its submesh claim."""

    job_id: str
    case: str
    n: int | tuple = 16
    steps: int = 4
    mesh: tuple = (2, 1)            # (pu, pv) submesh shape
    dt: float | None = None
    dtype: str = "float64"
    params: dict = dataclasses.field(default_factory=dict)
    plan_cfg: dict | None = None
    scale: float = 1.0              # initial-condition amplitude

    @property
    def slots(self) -> int:
        return int(math.prod(self.mesh))

    def spec_dict(self, *, mesh, ckpt_dir: str, result_path: str,
                  progress_path: str, ckpt_every: int, keep: int) -> dict:
        """The JSON document one worker attempt runs from."""
        n = self.n if isinstance(self.n, int) else list(self.n)
        return {"job_id": self.job_id, "case": self.case, "n": n,
                "steps": int(self.steps), "mesh": list(mesh),
                "dt": self.dt, "dtype": self.dtype,
                "params": dict(self.params),
                "plan_cfg": dict(self.plan_cfg) if self.plan_cfg else None,
                "scale": float(self.scale), "ckpt_dir": ckpt_dir,
                "ckpt_every": int(ckpt_every), "keep": int(keep),
                "result_path": result_path, "progress_path": progress_path}


@dataclasses.dataclass
class JobResult:
    """Terminal state of one job after the campaign."""

    job: FleetJob
    status: str = "pending"         # completed | quarantined
    attempts: int = 0
    history: dict = dataclasses.field(default_factory=dict)  # step -> obs
    failures: list = dataclasses.field(default_factory=list)
    restore_latency_us: float = 0.0
    checkpoint_bytes: int = 0

    @property
    def ok(self) -> bool:
        return self.status == "completed"

    def final_observables(self) -> dict | None:
        if not self.history:
            return None
        return self.history[max(self.history)]

    def to_dict(self) -> dict:
        return {"job_id": self.job.job_id, "case": self.job.case,
                "status": self.status, "attempts": self.attempts,
                "final_step": max(self.history) if self.history else None,
                "restore_latency_us": self.restore_latency_us,
                "checkpoint_bytes": self.checkpoint_bytes,
                "failures": [f.to_dict() for f in self.failures],
                "history": {str(k): self.history[k]
                            for k in sorted(self.history)}}


@dataclasses.dataclass
class _Attempt:
    job: FleetJob
    attempt: int
    mesh: tuple
    eligible_s: float = 0.0         # monotonic time the backoff expires


@dataclasses.dataclass
class _Running:
    att: _Attempt
    proc: subprocess.Popen
    deadline_s: float
    log_path: str
    result_path: str


class FleetController:
    """Schedule, supervise, retry and quarantine an ensemble of jobs."""

    def __init__(self, jobs, *, workdir: str, total_slots: int = 8,
                 max_retries: int = 2, timeout_s: float = 600.0,
                 backoff_base_s: float = 0.25, backoff_cap_s: float = 4.0,
                 ckpt_every: int = 2, keep: int = 2, fault_spec: str = "",
                 reshape_on_retry: tuple = (), poll_s: float = 0.02,
                 worker_argv: tuple | None = None, verbose: bool = True):
        self.jobs = list(jobs)
        ids = [j.job_id for j in self.jobs]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate job ids: {ids}")
        for j in self.jobs:
            if j.slots > total_slots:
                raise ValueError(f"job {j.job_id} needs {j.slots} slots, "
                                 f"pool has {total_slots}")
        for shape in reshape_on_retry:
            if math.prod(shape) > total_slots:
                raise ValueError(f"reshape_on_retry shape {shape} exceeds "
                                 f"the {total_slots}-slot pool")
        _faults.parse_fault_spec(fault_spec)   # fail fast on a bad spec
        self.workdir = workdir
        self.total_slots = int(total_slots)
        self.max_retries = int(max_retries)
        self.timeout_s = float(timeout_s)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.ckpt_every = int(ckpt_every)
        self.keep = int(keep)
        self.fault_spec = fault_spec
        self.reshape_on_retry = tuple(tuple(s) for s in reshape_on_retry)
        self.poll_s = float(poll_s)
        self.worker_argv = tuple(worker_argv) if worker_argv else (
            sys.executable, "-m", "repro.fleet.worker")
        self.verbose = verbose
        self.counters: dict[str, float] = {k: 0 for k in _COUNTERS}
        os.makedirs(workdir, exist_ok=True)

    # ---- bookkeeping -----------------------------------------------------
    def _count(self, name: str, value: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + value
        obs.metrics.inc(name, value)

    def _say(self, msg: str) -> None:
        if self.verbose:
            print(f"[fleet] {msg}", flush=True)

    def _retry_mesh(self, job: FleetJob, attempt: int) -> tuple:
        """Submesh shape for a retry — cycles ``reshape_on_retry`` when set
        (elastic restore onto a different pencil grid), else the job's own."""
        if attempt == 0 or not self.reshape_on_retry:
            return tuple(job.mesh)
        return self.reshape_on_retry[(attempt - 1) % len(self.reshape_on_retry)]

    # ---- the supervision loop --------------------------------------------
    def run(self) -> dict[str, JobResult]:
        """Run the campaign to completion; every job ends ``completed`` or
        ``quarantined`` (this method never wedges on a single job)."""
        results = {j.job_id: JobResult(job=j) for j in self.jobs}
        pending = collections.deque(
            _Attempt(job=j, attempt=0, mesh=tuple(j.mesh)) for j in self.jobs)
        running: dict[str, _Running] = {}

        while pending or running:
            now = time.monotonic()
            # launch every eligible pending attempt that fits the free pool
            free = self.total_slots - sum(
                r.att.job.slots for r in running.values())
            deferred = collections.deque()
            while pending:
                att = pending.popleft()
                slots = int(math.prod(att.mesh))
                if att.eligible_s > now or slots > free:
                    deferred.append(att)
                    continue
                running[att.job.job_id] = self._launch(att)
                results[att.job.job_id].attempts = att.attempt + 1
                free -= slots
            pending = deferred

            progressed = False
            for job_id in list(running):
                run_ = running[job_id]
                rc = run_.proc.poll()
                if rc is None and time.monotonic() > run_.deadline_s:
                    run_.proc.kill()
                    run_.proc.wait()
                    del running[job_id]
                    self._on_failure(results[job_id], run_, "timeout", True,
                                     f"exceeded {self.timeout_s:g}s deadline",
                                     None, pending)
                    progressed = True
                elif rc is not None:
                    del running[job_id]
                    if rc == 0:
                        self._collect(results[job_id], run_)
                    else:
                        kind, retryable = classify_exit(rc)
                        self._on_failure(results[job_id], run_, kind,
                                         retryable, self._log_tail(run_),
                                         rc, pending)
                    progressed = True
            if not progressed and (running or pending):
                time.sleep(self.poll_s)

        for res in results.values():
            self._merge_history(res)
        return results

    # ---- launch / collect / fail -----------------------------------------
    def _paths(self, job: FleetJob, attempt: int) -> dict:
        base = os.path.join(self.workdir, job.job_id)
        return {"spec": f"{base}.attempt{attempt}.spec.json",
                "log": f"{base}.attempt{attempt}.log",
                "result": f"{base}.result.json",
                "progress": f"{base}.progress.jsonl",
                "ckpt": os.path.join(self.workdir, "ckpt", job.job_id)}

    def _launch(self, att: _Attempt) -> _Running:
        p = self._paths(att.job, att.attempt)
        spec = att.job.spec_dict(
            mesh=att.mesh, ckpt_dir=p["ckpt"], result_path=p["result"],
            progress_path=p["progress"], ckpt_every=self.ckpt_every,
            keep=self.keep)
        with open(p["spec"], "w") as f:
            json.dump(spec, f, indent=1)
        env = dict(os.environ)
        # the worker pins its own fake-device count to its submesh — the
        # slot ledger is the partition; an inherited flag must not leak in
        env.pop("XLA_FLAGS", None)
        if self.fault_spec:
            env["REPRO_FAULT_SPEC"] = self.fault_spec
        else:
            env.pop("REPRO_FAULT_SPEC", None)
        log = open(p["log"], "ab")
        proc = subprocess.Popen(
            [*self.worker_argv, "--spec", p["spec"],
             "--attempt", str(att.attempt)],
            stdout=log, stderr=subprocess.STDOUT, env=env)
        log.close()
        self._count("fleet.jobs.scheduled")
        pu, pv = att.mesh
        self._say(f"job {att.job.job_id} attempt {att.attempt} -> "
                  f"{pu}x{pv} submesh (pid {proc.pid})")
        return _Running(att=att, proc=proc,
                        deadline_s=time.monotonic() + self.timeout_s,
                        log_path=p["log"], result_path=p["result"])

    def _log_tail(self, run_: _Running, nbytes: int = 800) -> str:
        try:
            with open(run_.log_path, "rb") as f:
                f.seek(max(0, os.path.getsize(run_.log_path) - nbytes))
                return f.read().decode(errors="replace").strip()
        except OSError:
            return ""

    def _collect(self, res: JobResult, run_: _Running) -> None:
        try:
            with open(run_.result_path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            doc = {}
        res.status = "completed"
        res.restore_latency_us = float(doc.get("restore_latency_us", 0.0))
        res.checkpoint_bytes = int(doc.get("checkpoint_bytes", 0))
        self._count("fleet.jobs.completed")
        self._count("fleet.checkpoint.bytes", res.checkpoint_bytes)
        if res.restore_latency_us:
            obs.metrics.set_gauge("fleet.restore.latency_us",
                                  res.restore_latency_us)
        self._say(f"job {res.job.job_id} completed "
                  f"({res.attempts} attempt(s))")

    def _on_failure(self, res: JobResult, run_: _Running, kind: str,
                    retryable: bool, detail: str, rc: int | None,
                    pending: collections.deque) -> None:
        att = run_.att
        res.failures.append(FailureRecord(
            kind=kind, where="fleet.worker", job_id=att.job.job_id,
            attempt=att.attempt, detail=detail, exit_code=rc,
            retryable=retryable, time_s=time.time()))
        self._count("fleet.jobs.failures")
        if retryable and att.attempt < self.max_retries:
            delay = min(self.backoff_cap_s,
                        self.backoff_base_s * (2 ** att.attempt))
            mesh = self._retry_mesh(att.job, att.attempt + 1)
            pending.append(_Attempt(
                job=att.job, attempt=att.attempt + 1, mesh=mesh,
                eligible_s=time.monotonic() + delay))
            self._count("fleet.jobs.retried")
            self._say(f"job {att.job.job_id} {kind} on attempt "
                      f"{att.attempt}; retry in {delay:.2f}s on "
                      f"{mesh[0]}x{mesh[1]}")
        else:
            res.status = "quarantined"
            self._count("fleet.jobs.quarantined")
            self._say(f"job {att.job.job_id} QUARANTINED after "
                      f"{att.attempt + 1} attempt(s): {kind}")

    def _merge_history(self, res: JobResult) -> None:
        """Merge the job's append-only progress log into ``{step: obs}``.

        Every attempt appends to the same file; later attempts overwrite
        overlapping steps (they recompute the same values from the restored
        checkpoint — the identity the chaos smoke pins). A torn final line
        from a hard kill is tolerated.
        """
        path = self._paths(res.job, 0)["progress"]
        if not os.path.exists(path):
            return
        with open(path) as f:
            for line in f:
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue            # torn tail from a mid-write kill
                res.history[int(rec["step"])] = rec["obs"]

    # ---- reporting -------------------------------------------------------
    def report(self, results: dict[str, JobResult]) -> dict:
        """The JSON-serializable campaign report (``fleet-report/v1``)."""
        return {"schema": "fleet-report/v1",
                "counters": dict(self.counters),
                "config": {"total_slots": self.total_slots,
                           "max_retries": self.max_retries,
                           "ckpt_every": self.ckpt_every,
                           "fault_spec": self.fault_spec,
                           "timeout_s": self.timeout_s},
                "jobs": {jid: results[jid].to_dict()
                         for jid in sorted(results)}}
