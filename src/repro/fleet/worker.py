"""One supervised fleet worker: a solver job with checkpoints and faults.

    python -m repro.fleet.worker --spec job.spec.json --attempt 0

The worker is the unit the controller kills, restarts and quarantines. It
reads a JSON job spec (written by :class:`repro.fleet.controller.
FleetController`), pins its own fake-device submesh *before* importing
jax, builds the solver, and then either starts from t=0 (applying the
job's initial-condition ``scale``) or — if the job's checkpoint directory
has a complete snapshot — resumes mid-trajectory via
``SpectralSolver.restore_state`` (elastic: the snapshot may have been
written on a different submesh shape).

Per step it appends one JSON line ``{"step", "attempt", "obs"}`` to the
job's shared progress log (flushed immediately, so a hard kill loses at
most a torn final line) and snapshots through ``CheckpointManager`` every
``ckpt_every`` steps. On success it writes the result document atomically
and exits 0. Exit codes: ``records.POISON_EXIT`` for an invalid spec,
``records.KILL_EXIT`` from the injected hard kill, anything else nonzero
is a crash the controller will retry.

Faults come from ``REPRO_FAULT_SPEC`` (see :mod:`repro.fleet.faults`) and
are filtered by (job, attempt) before anything fires — deterministic by
construction.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time


def _write_json_atomic(path: str, doc: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
    os.replace(tmp, path)


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _, files in os.walk(path):
        for name in files:
            try:
                total += os.path.getsize(os.path.join(root, name))
            except OSError:
                pass
    return total


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.fleet.worker")
    ap.add_argument("--spec", required=True, help="job spec JSON path")
    ap.add_argument("--attempt", type=int, default=0)
    args = ap.parse_args(argv)

    with open(args.spec) as f:
        spec = json.load(f)
    job_id = spec["job_id"]
    mesh_shape = tuple(int(d) for d in spec["mesh"])

    # fault plan + device pinning happen before jax initializes
    from repro.fleet import faults as fl
    from repro.fleet.records import KILL_EXIT, POISON_EXIT
    plan = fl.plan_from_env()
    active = plan.active(job_id, args.attempt)

    from repro.launch.mesh import ensure_host_devices
    ensure_host_devices(math.prod(mesh_shape))

    import numpy as np

    from repro.core import precision
    if np.dtype(spec["dtype"]).itemsize >= 8:
        precision.enable_x64()

    from repro import compat
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.solvers import make_solver
    from repro.solvers.base import SolverState

    try:
        mesh = compat.make_mesh(mesh_shape, ("data", "model"))
        kwargs = dict(spec.get("params") or {})
        if spec.get("dt") is not None:
            kwargs["dt"] = spec["dt"]
        n = spec["n"] if isinstance(spec["n"], int) else tuple(spec["n"])
        solver = make_solver(spec["case"], mesh, n, dtype=spec["dtype"],
                             plan_cfg=spec.get("plan_cfg"), **kwargs)
    except (ValueError, TypeError) as e:
        # poison config: deterministically invalid — tell the controller
        # not to waste retries on it
        print(f"[poison] {type(e).__name__}: {e}", file=sys.stderr, flush=True)
        return POISON_EXIT

    mgr = CheckpointManager(spec["ckpt_dir"], keep=spec.get("keep", 2))
    for fault in active:
        if fault.kind == "torn-checkpoint":
            fl.arm_torn_checkpoint(mgr, at_step=fault.step)
    kill = next((f for f in active if f.kind == "kill-at-step"), None)
    slow = next((f for f in active if f.kind == "slow-at-step"), None)

    restore_us = 0.0
    if mgr.latest_step() is not None:
        t0 = time.monotonic()
        state, meta = solver.restore_state(mgr)
        restore_us = (time.monotonic() - t0) * 1e6
        print(f"[resume] job {job_id} from step {state.n_steps} "
              f"(saved on mesh {meta.get('mesh')}, "
              f"{restore_us / 1e3:.1f} ms restore)", flush=True)
    else:
        from repro.serving.server import scaled_initial_fields
        state = SolverState(
            fields=scaled_initial_fields(solver, spec.get("scale", 1.0)))

    progress = open(spec["progress_path"], "a")

    def emit(step: int, observables: dict) -> None:
        progress.write(json.dumps({"step": step, "attempt": args.attempt,
                                   "obs": observables}) + "\n")
        progress.flush()

    steps = int(spec["steps"])
    every = int(spec.get("ckpt_every", 2))
    ckpt_meta = {"job_id": job_id, "case": spec["case"],
                 "mesh": list(mesh_shape), "attempt": args.attempt}
    if state.n_steps == 0:
        emit(0, solver.observables(state))
    for i in range(state.n_steps + 1, steps + 1):
        state = solver.step(state)
        emit(i, solver.observables(state))
        if every and i % every == 0 and i < steps:
            mgr.save(i, solver.state_tree(state), meta=ckpt_meta)
        if slow and i == slow.step:
            print(f"[fault] slow-at-step {i}: sleeping {slow.seconds:g}s",
                  flush=True)
            time.sleep(slow.seconds)
        if kill and i == kill.step:
            # hard exit skipping every cleanup path (progress close, result
            # write, atexit) — but drain the in-flight snapshot first, so
            # whether the retry resumes is a function of (step, ckpt_every)
            # alone, not of writer-thread timing; the mid-write-tear case
            # is injected deterministically via torn-checkpoint instead
            try:
                mgr.wait()
            except Exception:
                pass
            print(f"[fault] kill-at-step {i}", flush=True)
            os._exit(KILL_EXIT)
    # final snapshot; block so a swallowed async write error becomes a crash
    mgr.save(steps, solver.state_tree(state), meta=ckpt_meta, block=True)
    progress.close()

    _write_json_atomic(spec["result_path"], {
        "job_id": job_id, "attempt": args.attempt, "final_step": steps,
        "restore_latency_us": round(restore_us, 1),
        "checkpoint_bytes": _dir_bytes(spec["ckpt_dir"]),
    })
    print(f"[done] job {job_id}: {steps} steps "
          f"(attempt {args.attempt})", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
