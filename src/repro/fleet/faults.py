"""Deterministic fault injection for the fleet — chaos you can assert on.

The point of injected faults is a *reproducible* proof: a worker killed at
step N must resume from its checkpoint and finish with observables
identical to the unkilled run. So every fault is keyed on (job, attempt,
step), never on wall time or randomness, and by default fires on attempt 0
only — the retried attempt sails through, exactly like a real preemption
that doesn't repeat.

Spec grammar (``REPRO_FAULT_SPEC`` env var, or ``--inject`` on the fleet
CLI — the controller forwards it to workers through the environment)::

    spec    := clause (";" clause)*
    clause  := kind ":" args ("@job=" JOB_ID)?

    kill-at-step:N[:times=T]        hard ``os._exit(KILL_EXIT)`` right
                                    after step N completes, skipping every
                                    cleanup path (in-flight snapshot writes
                                    are drained first, so whether the retry
                                    resumes depends only on the checkpoint
                                    cadence — use torn-checkpoint for the
                                    mid-write-tear case)
    torn-checkpoint:N[:times=T]     the first checkpoint save at step >= N
                                    writes a partial tmp dir and raises
                                    inside the async writer (exercises the
                                    CheckpointManager error capture and
                                    the scan-fallback restore)
    slow-at-step:N:SECONDS[:times=T]   sleep SECONDS after step N — long
                                    enough to trip the supervisor's
                                    deadline and be classified ``timeout``

``times=T`` fires the fault on attempts ``0 .. T-1`` (default 1);
``@job=ID`` restricts a clause to one job (default: every job). Unknown
kinds or malformed clauses raise ``ValueError`` at parse time — the
controller validates the spec *before* launching anything.

This module is jax-free and safe to import before the XLA backend
initializes (the worker parses its spec before ``import jax``).
"""

from __future__ import annotations

import dataclasses
import os

_KINDS = ("kill-at-step", "torn-checkpoint", "slow-at-step")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One armed fault clause."""

    kind: str                   # one of _KINDS
    step: int                   # the step the fault keys on
    seconds: float = 0.0        # slow-at-step only
    times: int = 1              # fires on attempts < times
    job: str = ""               # "" = every job

    def fires(self, job_id: str, attempt: int) -> bool:
        return (not self.job or self.job == job_id) and attempt < self.times


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A parsed spec: the full set of clauses, filterable per (job, attempt)."""

    faults: tuple = ()

    def active(self, job_id: str, attempt: int) -> list:
        """The clauses that fire for this job on this attempt."""
        return [f for f in self.faults if f.fires(job_id, attempt)]

    def __bool__(self) -> bool:
        return bool(self.faults)


def parse_fault_spec(text: str | None) -> FaultPlan:
    """Parse the grammar above; ``ValueError`` on any malformed clause."""
    if not text or not text.strip():
        return FaultPlan()
    faults = []
    for raw in text.split(";"):
        clause = raw.strip()
        if not clause:
            continue
        job = ""
        if "@" in clause:
            clause, _, tail = clause.partition("@")
            if not tail.startswith("job="):
                raise ValueError(
                    f"fault clause {raw!r}: expected '@job=ID', got {tail!r}")
            job = tail[len("job="):]
            if not job:
                raise ValueError(f"fault clause {raw!r}: empty job id")
        parts = clause.split(":")
        kind = parts[0]
        if kind not in _KINDS:
            raise ValueError(f"unknown fault kind {kind!r} in {raw!r}; "
                             f"have {list(_KINDS)}")
        args, opts = [], {}
        for p in parts[1:]:
            if "=" in p:
                k, _, v = p.partition("=")
                if k != "times":
                    raise ValueError(
                        f"fault clause {raw!r}: unknown option {k!r}")
                opts["times"] = int(v)
            else:
                args.append(p)
        try:
            if kind == "slow-at-step":
                step, seconds = int(args[0]), float(args[1])
            else:
                (step,), seconds = (int(args[0]),), 0.0
                if len(args) != 1:
                    raise IndexError
        except (IndexError, ValueError) as e:
            if isinstance(e, ValueError):
                raise ValueError(f"fault clause {raw!r}: bad argument") from e
            want = "N:SECONDS" if kind == "slow-at-step" else "N"
            raise ValueError(
                f"fault clause {raw!r}: expected {kind}:{want}") from e
        times = opts.get("times", 1)
        if times < 1 or step < 0:
            raise ValueError(f"fault clause {raw!r}: step/times must be >= 0/1")
        faults.append(Fault(kind=kind, step=step, seconds=seconds,
                            times=times, job=job))
    return FaultPlan(faults=tuple(faults))


def plan_from_env(default: str = "") -> FaultPlan:
    """The plan in ``REPRO_FAULT_SPEC`` (falling back to ``default``)."""
    return parse_fault_spec(os.environ.get("REPRO_FAULT_SPEC", default))


def arm_torn_checkpoint(manager, *, at_step: int):
    """Wrap ``manager`` so its first save at ``step >= at_step`` is torn.

    The injected write produces exactly what a mid-write kill leaves
    behind: a partial ``step_*.tmp`` directory with no ``manifest.json``
    and no rename — then raises inside the (async) writer thread. The
    manager's error capture must surface the exception on the next
    ``wait()``/``save()``, and ``latest_step()`` must keep resolving to the
    last *complete* checkpoint. Later saves go through untouched.
    """
    orig = manager._write
    fired = []

    def torn_write(step, host, meta):
        if not fired and step >= at_step:
            fired.append(step)
            tmp = os.path.join(manager.dir, f"step_{step:08d}.tmp")
            os.makedirs(tmp, exist_ok=True)
            with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
                f.write(b"torn")        # partial payload, no manifest
            raise OSError(f"injected torn checkpoint write at step {step}")
        return orig(step, host, meta)

    manager._write = torn_write
    return manager
