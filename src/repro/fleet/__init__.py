"""``repro.fleet`` — fault-tolerant orchestration of solver ensembles.

The ROADMAP's "fleet orchestration + checkpointed fault tolerance" layer:
one process per *worker*, many workers per *campaign*, and a controller
that assumes workers die. The pieces, each its own module:

* :mod:`~repro.fleet.records` — :class:`FailureRecord`, the structured
  failure type the controller, ``repro.serving`` and the reports share,
  plus the worker exit-code conventions.
* :mod:`~repro.fleet.faults` — deterministic fault injection
  (``kill-at-step`` / ``torn-checkpoint`` / ``slow-at-step``), parsed from
  ``REPRO_FAULT_SPEC`` / ``--inject`` and keyed on (job, attempt, step) so
  chaos runs are reproducible and assertable.
* :mod:`~repro.fleet.worker` — the supervised unit: run one solver job
  with periodic checkpoints, resume from the latest complete snapshot
  (elastically — possibly on a different submesh shape), apply injected
  faults.
* :mod:`~repro.fleet.controller` — :class:`FleetController`: pack jobs
  onto a device-slot pool, supervise the worker subprocesses, classify
  deaths (crash / timeout / poison), retry from checkpoint with capped
  exponential backoff, quarantine exhausted jobs without wedging the
  campaign.
* :mod:`~repro.fleet.cli` — ``python -m repro.fleet.cli``: the ensemble
  entry point and the CI chaos smoke's driver.

The headline invariant (pinned by ``tests/test_fleet_restart.py`` and the
CI chaos smoke): a campaign with an injected worker kill produces per-job
observable histories identical to the same campaign run unkilled, and a
job whose retry budget is exhausted is quarantined while its siblings
complete. ``docs/fleet.md`` documents the lifecycle end to end.

This package is jax-free to import; only workers touch device state.
"""

from __future__ import annotations

from repro.fleet.controller import FleetController, FleetJob, JobResult
from repro.fleet.faults import (Fault, FaultPlan, arm_torn_checkpoint,
                                parse_fault_spec)
from repro.fleet.records import (KILL_EXIT, POISON_EXIT, FailureRecord,
                                 classify_exit)

__all__ = [
    "FleetController", "FleetJob", "JobResult",
    "Fault", "FaultPlan", "parse_fault_spec", "arm_torn_checkpoint",
    "FailureRecord", "classify_exit", "KILL_EXIT", "POISON_EXIT",
]
