"""Structured failure records — the one vocabulary every supervisor speaks.

A :class:`FailureRecord` is what survives a failure: the fleet controller
attaches one to a job for every worker death (crash / timeout / poison
config), ``repro.serving`` records one per failed batch lane and per
finally-rejected load-generator submission, and the fleet report JSON
serializes them verbatim. Keeping the type here — jax-free, import-cheap —
lets the queue, the server, the controller and the tests share one schema
instead of four ad-hoc dicts.

Worker exit-code conventions (the controller's classification inputs):

* ``POISON_EXIT`` (4)  — the job *spec* is invalid (unknown case, grid not
  divisible by the submesh, bad physics kwargs). Deterministic: retrying
  cannot help, so the controller quarantines immediately.
* ``KILL_EXIT`` (13)   — the fault injector's hard kill (``os._exit``),
  indistinguishable from a real preemption on purpose: classified
  ``crash`` and retried like one.
* anything else nonzero — ``crash`` (retryable); a supervisor-initiated
  kill after the deadline is classified ``timeout`` (retryable) by the
  controller itself, not from the exit code.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar

#: worker exits with this when the job spec itself is invalid (never retry)
POISON_EXIT = 4
#: the fault injector's hard-kill exit code (retryable, like any crash)
KILL_EXIT = 13


@dataclasses.dataclass(frozen=True)
class FailureRecord:
    """One observed failure, structured for reports and retry decisions."""

    kind: str                   # crash | timeout | poison | batch_error | rejected
    where: str                  # component: "fleet.worker" | "serving.batch" | ...
    job_id: str                 # fleet job id / serving request id
    attempt: int = 0            # 0-based attempt index when it happened
    detail: str = ""            # human-readable cause (exception, log tail)
    exit_code: int | None = None
    retryable: bool = True      # may a supervisor reschedule after this?
    time_s: float = 0.0         # wall-clock (time.time()) of classification

    KINDS: ClassVar[frozenset] = frozenset(
        {"crash", "timeout", "poison", "batch_error", "rejected"})

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown failure kind {self.kind!r}; "
                             f"have {sorted(self.KINDS)}")

    def to_dict(self) -> dict:
        """JSON-serializable form (the fleet report embeds these)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FailureRecord":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


def classify_exit(returncode: int) -> tuple[str, bool]:
    """``(kind, retryable)`` for a dead worker's exit code.

    The controller calls this for any nonzero return; timeouts never reach
    here (the supervisor kills and classifies those itself).
    """
    if returncode == POISON_EXIT:
        return "poison", False
    return "crash", True
