"""Multi-FPGA-style distributed 3D FFT and FFT-based simulations in JAX.

Stable public surface — the names most programs need, re-exported lazily so
``import repro`` stays cheap (no jax import until a symbol is touched):

* :class:`~repro.core.decomposition.PencilGrid` — the 2D pencil grid, with
  per-mesh-axis factorizations (``u_sizes``/``v_sizes``) on ≥2D meshes.
* :class:`~repro.core.decomposition.CommStep` /
  :class:`~repro.core.decomposition.CommDAG` — the axis-labelled
  communication DAG every transpose engine executes.
* :class:`~repro.core.engine_spec.EngineSpec` — one frozen dataclass naming
  the engine/backend/schedule/chunks choice, consumed uniformly by
  ``core.comm``, ``core.perfmodel``, ``core.topology`` and ``repro.tuning``.
* :func:`~repro.core.fft3d.make_fft3d` — the distributed-3D-FFT factory.

Everything else lives in the subpackages (``repro.core``, ``repro.kernels``,
``repro.solvers``, ``repro.tuning``, ...), imported explicitly.
"""

from __future__ import annotations

__all__ = ["PencilGrid", "CommStep", "CommDAG", "EngineSpec", "FFT3DPlan",
           "make_fft3d"]

_EXPORTS = {
    "PencilGrid": ("repro.core.decomposition", "PencilGrid"),
    "CommStep": ("repro.core.decomposition", "CommStep"),
    "CommDAG": ("repro.core.decomposition", "CommDAG"),
    "EngineSpec": ("repro.core.engine_spec", "EngineSpec"),
    "FFT3DPlan": ("repro.core.fft3d", "FFT3DPlan"),
    "make_fft3d": ("repro.core.fft3d", "make_fft3d"),
}


def __getattr__(name):  # PEP 562 lazy re-export
    try:
        module, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value  # cache for the next lookup
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
