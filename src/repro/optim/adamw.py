"""AdamW with global-norm clipping, cosine schedule, and dtype-configurable
moments (bf16 moments let the 398B config fit 16 GB/chip; see EXPERIMENTS.md
§Dry-run). Pure pytree implementation — no optax dependency."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"


def schedule(c: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(c.warmup_steps, 1), 1.0)
    t = jnp.clip((step - c.warmup_steps)
                 / jnp.maximum(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return c.lr * warm * (c.min_lr_ratio + (1 - c.min_lr_ratio) * cos)


def init(c: AdamWConfig, params) -> dict:
    dt = jnp.dtype(c.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(c: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(c, count)
    b1, b2 = c.b1, c.b2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)
    mdt = jnp.dtype(c.moment_dtype)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        step_ = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + c.eps)
        step_ = step_ + c.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step_
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
