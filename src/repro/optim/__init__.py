"""Optimizers (pure-pytree AdamW)."""
