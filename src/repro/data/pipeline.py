"""Deterministic, stateless data pipeline.

``batch_for_step(step)`` is a pure function of (seed, step, shard) via a
counter-based Philox generator, so checkpoint/restart recovery replays the
exact token stream with zero pipeline state (DESIGN.md §5 fault tolerance),
and each host reads only its shard (host-sharded loading at pod scale).
A memory-mapped binary token-file source covers real-corpus training.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    kind: str = "tokens"      # tokens | embeds | frames
    d_model: int = 0          # for embeds/frames
    token_file: str = ""      # optional memmap source


def _rng(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    key = np.array([np.uint64(cfg.seed) ^ (np.uint64(shard) << np.uint64(32)),
                    np.uint64(step)], np.uint64)
    return np.random.Generator(np.random.Philox(key=key))


class Pipeline:
    """num_shards = number of data hosts; this instance yields shard ``shard``."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        self._mm = None
        if cfg.token_file:
            self._mm = np.memmap(cfg.token_file, dtype=np.uint32, mode="r")

    def batch_for_step(self, step: int) -> dict:
        cfg = self.cfg
        g = _rng(cfg, step, self.shard)
        b, s = self.local_batch, cfg.seq_len
        if cfg.kind == "embeds":
            return {"embeds": g.standard_normal((b, s, cfg.d_model),
                                                dtype=np.float32),
                    "labels": g.integers(0, cfg.vocab, (b, s)).astype(np.int32)}
        if cfg.kind == "frames":
            return {"frames": g.standard_normal((b, s, cfg.d_model),
                                                dtype=np.float32),
                    "tokens": g.integers(0, cfg.vocab, (b, s)).astype(np.int32)}
        if self._mm is not None:
            n = len(self._mm) - s - 1
            starts = g.integers(0, n, (b,))
            toks = np.stack([self._mm[i:i + s] for i in starts])
            return {"tokens": (toks % cfg.vocab).astype(np.int32)}
        return {"tokens": g.integers(0, cfg.vocab, (b, s)).astype(np.int32)}


def write_token_file(path: str, tokens: np.ndarray) -> None:
    tokens.astype(np.uint32).tofile(path)


def make_pipeline(cfg: DataConfig, process_index: int | None = None,
                  process_count: int | None = None) -> Pipeline:
    import jax
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    return Pipeline(cfg, shard=pi, num_shards=pc)
