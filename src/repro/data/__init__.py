"""Deterministic, stateless data pipeline."""
