"""Centralized precision policy: x64 enablement and dtype resolution.

JAX silently canonicalizes 64-bit dtypes down to 32-bit unless
``jax_enable_x64`` is on, which used to make ``core.spectral``'s float64
defaults a quiet precision loss. Every place that *requests* a dtype
(``FFT3DPlan``, solver construction, the autotuner fingerprint) now goes
through :func:`require_dtype`, which refuses to downcast silently, and the
spectral operators default to :func:`default_real_dtype` — the widest dtype
this process can actually compute in.
"""

from __future__ import annotations

import numpy as np


def x64_enabled() -> bool:
    """True when this process computes in 64-bit (``jax_enable_x64``)."""
    import jax

    return bool(jax.config.jax_enable_x64)


def enable_x64() -> None:
    """Turn on 64-bit computation for this process (idempotent).

    Safe to call after ``import jax``; entry points that want f64 (tests,
    the solver CLI) call this once instead of each setting the flag.
    """
    import jax

    jax.config.update("jax_enable_x64", True)


def default_real_dtype():
    """The widest real dtype JAX will actually compute in right now."""
    import jax.numpy as jnp

    return jnp.float64 if x64_enabled() else jnp.float32


def require_dtype(dtype, *, allow_downcast: bool = False,
                  who: str = "FFT3DPlan") -> np.dtype:
    """Resolve ``dtype`` to what JAX will compute in; never downcast silently.

    Returns the canonical dtype. When the request would lose precision
    (e.g. float64 with x64 off) raises ``ValueError`` with the fix, unless
    ``allow_downcast=True`` makes the demotion explicit.
    """
    import jax

    want = np.dtype(dtype)
    got = np.dtype(jax.dtypes.canonicalize_dtype(want))
    if got != want:
        if allow_downcast:
            return got
        raise ValueError(
            f"{who}: requested dtype {want.name} but JAX would silently "
            f"compute in {got.name} (jax_enable_x64 is off). Call "
            f"repro.core.precision.enable_x64() / set JAX_ENABLE_X64=1, "
            f"request a 32-bit dtype, or pass allow_downcast=True for an "
            f"explicit demotion.")
    return got
