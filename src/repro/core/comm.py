"""TransposeEngine — pluggable fold-communication layer (paper §4.2–4.3).

The paper's central architectural claim is that the fold communications
(hardware tasks C and G) must be *pipelined against* the butterfly engines,
not barriered between phases (Fig. 4.3): the NIC streams blocks while the
FFT engines keep computing. This module makes that scheduling decision a
first-class, pluggable object with five implementations:

* ``SwitchedEngine``    — one ``lax.all_to_all`` per fold (the 2D switched
  fabric of Fig. 5.10, Eq. 5.5). Overlap across ``chunks`` slabs is left to
  XLA's latency-hiding scheduler.
* ``TorusEngine``       — P−1 ``lax.ppermute`` ring rounds per fold (the 2D
  torus of Fig. 5.9, Eq. 5.6), same slab-level scheduling as switched.
* ``OverlapRingEngine`` — fuses the 1D FFT *into* the ring: while each of
  the P−1 ppermute rounds ships one block, another block's butterflies are
  emitted between the rounds, so compute and ``lax.ppermute`` interleave at
  block granularity instead of phase granularity — the TPU rendition of the
  paper's task C/G ↔ engine overlap.
* ``PallasRingEngine``  — the same ring schedule as a Pallas async-RDMA
  kernel (``kernels.ring_rdma``): each round *starts* the next block's
  neighbor DMA, computes, then waits — the overlap is explicit in the
  kernel (the paper's NIC offload) instead of hoped-for from XLA's
  scheduler. Off-TPU it runs the kernel's interpret-mode fallback
  (ppermute wire hop + Pallas NIC staging), bit-exact vs ``torus``.
* ``BidiRingEngine``    — the two-NIC ring of Fig. 5.9: every fold splits
  its blocks into a clockwise and a counter-clockwise stream and drives
  both torus directions concurrently, finishing in ``ceil((P−1)/2)``
  exchange rounds instead of P−1. On TPU the exchange is the bidirectional
  async-RDMA kernel (``kernels.ring_rdma.ring_exchange_bidi_rdma``,
  double-buffered sends to both neighbors with per-direction semaphores);
  off-TPU it is the two counter-rotating ``ppermute`` streams of
  ``transpose.ring_exchange_bidi`` — same overlapped schedule as
  ``overlap_ring``, half the rounds.

Engines are constructed from an :class:`~repro.core.engine_spec.EngineSpec`
via :func:`build_engine` and consume the axis-labelled **CommStep** contract
of ``core.decomposition``: every step names the processor-grid dimension it
exchanges over (``u``/``v``), and a dimension spanning several mesh axes
(e.g. ``u_axes=("pod", "data")``) runs **one ring per mesh axis** — the
per-axis staging of ``transpose.staged_exchange`` — instead of degrading to
a flat ``ppermute`` over the product group.

Ring engines carry an ``exchange_rounds`` counter: every exchange routed
through the ``_exchange``/``_rdma`` hooks adds its wire-round count at
trace time — ``wire_rounds(q)`` summed over the communicating mesh axes of
the step's grid dimension (qᵢ−1 per axis for the unidirectional rings,
``ceil((qᵢ−1)/2)`` for the bidirectional one) — so tests can pin the round
complexity an engine actually uses.

Engines expose two surfaces:

* **relayout primitives** ``fold_step / unfold_step`` (and the
  ``fold_xy``-style conveniences) — pure data movement over the shared
  block-exchange primitives of ``core.transpose``; every engine computes
  the identical relayout, and ``unfold ∘ fold`` is the identity
  (property-tested).
* **the scheduling contract** ``run_fold / run_unfold / run_roundtrip`` —
  a full FFT phase (butterflies then fold, or unfold then butterflies)
  over one :class:`~repro.core.decomposition.CommStep`, which the engine
  is free to chunk, stream, or fuse. ``run_roundtrip`` is the phase-pair
  variant for diagonal spectral operators: fold, folded-pencil kernel,
  and unfold threaded per slab so slab k's kernel runs under slab k+1's
  fold and slab k−1's unfold. ``fft3d_local``/``ifft3d_local``/
  ``spectral_roundtrip_local`` walk the plan's
  :class:`~repro.core.decomposition.CommDAG` against this contract only.

All engine methods run *inside* ``shard_map`` over the FFT mesh axes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs
from repro.core import decomposition as dec
from repro.core import transpose as tr
from repro.core.engine_spec import ENGINE_FABRIC, EngineSpec  # noqa: F401


# ---------------------------------------------------------------------------
# slab scheduling (the paper's Fig. 4.2/4.3 chunking, ex fft3d._run_chunked)
# ---------------------------------------------------------------------------

def run_chunked(fn, arrs, axis: int, chunks: int):
    """Apply ``fn`` per slab along ``axis`` (same axis in/out), concat results.

    Emitting independent per-slab chains is what lets XLA overlap slab i's
    collective with slab i+1's compute (paper Fig. 4.3 timeline).
    """
    if chunks == 1:
        return fn(*arrs)
    axis = axis % arrs[0].ndim
    size = arrs[0].shape[axis]
    c = min(chunks, size)
    while size % c:
        c -= 1
    outs = []
    step = size // c
    for i in range(c):
        sl = [jax.lax.slice_in_dim(a, i * step, (i + 1) * step, axis=axis)
              for a in arrs]
        outs.append(fn(*sl))
    if isinstance(outs[0], tuple):
        return tuple(jnp.concatenate([o[j] for o in outs], axis=axis)
                     for j in range(len(outs[0])))
    return jnp.concatenate(outs, axis=axis)


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

ENGINES: dict[str, type] = {}


def _register(cls):
    ENGINES[cls.name] = cls
    return cls


def build_engine(spec: EngineSpec, grid) -> "TransposeEngine":
    """Instantiate the engine an :class:`EngineSpec` names, for a grid.

    The spec's ``backend``/``real`` describe the butterfly compute the
    engine will be asked to schedule: engines that can *fuse* compute into
    their communication kernel (``pallas_ring`` on TPU) use them to decide
    when in-kernel butterflies reproduce the phase compute.
    """
    try:
        cls = ENGINES[spec.engine]
    except KeyError:
        raise ValueError(f"unknown comm engine {spec.engine!r}; "
                         f"have {sorted(ENGINES)}") from None
    return cls(grid, spec)


def engine_fabric(name: str) -> str:
    """The §5.5 network fabric an engine needs sizing for."""
    try:
        return ENGINES[name].fabric
    except KeyError:
        raise ValueError(
            f"unknown comm engine {name!r}; have {sorted(ENGINES)}") from None


# ---------------------------------------------------------------------------
# base engine: phase = compute + fold, scheduled at slab granularity
# ---------------------------------------------------------------------------

class TransposeEngine:
    """Interface + slab-granular base schedule shared by switched/torus."""

    name = "base"
    mode = "switched"    # wire format of the shared block-exchange primitives
    fabric = "switched"  # §5.5 network the engine maps onto

    def __init__(self, grid, spec: EngineSpec | None = None):
        self.grid = grid
        self.spec = spec if spec is not None else EngineSpec(
            engine=self.name if self.name in ENGINE_FABRIC else "switched")
        self.chunks = max(int(self.spec.chunks), 1)
        self.backend = self.spec.backend  # butterfly engine of the schedule
        self.real = self.spec.real        # r2c model (X phase not plain c2c)
        # wire rounds traced through the ring engines' exchange hooks (the
        # base/switched engines never route through them and keep 0)
        self.exchange_rounds = 0

    # ---- CommStep resolution ---------------------------------------------
    def _step(self, which) -> dec.CommStep:
        """Resolve a legacy ``"xy"``/``"yz"`` tag (or pass a step through)."""
        if isinstance(which, dec.CommStep):
            return which
        if which == "xy":
            return dec.XY_STEP.replace(c2c=not self.real)
        if which == "yz":
            return dec.YZ_STEP
        raise ValueError(f"unknown fold {which!r}; have ('xy', 'yz')")

    def _axes(self, which) -> tuple[str, ...]:
        """Mesh axes the step's grid dimension spans (one ring per axis)."""
        return self.grid.dim_axes(self._step(which).grid_dim)

    def _ranks(self, which) -> int:
        return self.grid.dim_ranks(self._step(which).grid_dim)

    # ---- relayout primitives (pure data movement) ------------------------
    def fold_step(self, step: dec.CommStep, a):
        """Execute one CommStep's fold relayout: block exchange over the
        step's grid dimension, then the step's local permute."""
        d = a.ndim
        b = tr.all_to_all_blocks(a, self._axes(step),
                                 split_axis=d + step.split_offset,
                                 concat_axis=d + step.concat_offset,
                                 mode=self.mode)
        return tr.permute_last3(b, step.permute)

    def unfold_step(self, step: dec.CommStep, a):
        """Inverse relayout: the step's permute, then the derived inverse
        exchange (split where the fold concatenated and vice versa)."""
        d = a.ndim
        b = tr.permute_last3(a, step.permute)
        return tr.all_to_all_blocks(b, self._axes(step),
                                    split_axis=d + step.unfold_split,
                                    concat_axis=d + step.unfold_concat,
                                    mode=self.mode)

    def fold_xy(self, a):
        return self.fold_step(self._step("xy"), a)

    def unfold_xy(self, a):
        return self.unfold_step(self._step("xy"), a)

    def fold_yz(self, a):
        return self.fold_step(self._step("yz"), a)

    def unfold_yz(self, a):
        return self.unfold_step(self._step("yz"), a)

    def fold(self, which, a):
        return self.fold_step(self._step(which), a)

    def unfold(self, which, a):
        return self.unfold_step(self._step(which), a)

    # ---- scheduling contract ---------------------------------------------
    def run_fold(self, step: dec.CommStep, compute, arrs):
        """Forward phase: butterflies (``compute``) then the step's fold.

        ``compute(*slab) -> tuple`` runs the 1D FFT of the phase; the
        step's ``slab_offset`` names a local axis untouched by the fold,
        along which the engine may slice the volume without changing the
        result.
        """
        def phase(*sl):
            return tuple(self.fold_step(step, o) for o in compute(*sl))
        return run_chunked(phase, arrs, axis=step.slab_offset,
                           chunks=self.chunks)

    def run_unfold(self, step: dec.CommStep, compute, arrs):
        """Inverse phase: the step's unfold relayout then butterflies."""
        def phase(*sl):
            return compute(*(self.unfold_step(step, a) for a in sl))
        return run_chunked(phase, arrs, axis=step.slab_offset,
                           chunks=self.chunks)

    def run_roundtrip(self, step: dec.CommStep, fwd, kernel, inv, arrs, *,
                      diag=None):
        """Fused spectral roundtrip over one CommStep, slab by slab.

        A spectral operator that is pointwise-diagonal in k-space factors
        through a single fold: ``fwd`` (the forward butterflies of the
        folding phase) → fold → ``kernel`` (everything at the folded
        pencil: the remaining transform, the diagonal multiply, its
        inverse) → unfold → ``inv`` (the inverse butterflies). The step's
        ``slab_offset`` axis is untouched by fold, kernel, and unfold
        alike, so the engine may thread slabs through the whole roundtrip
        independently — no full-volume barrier between the phases.

        ``fwd(*slab) -> (cr, ci)`` matches ``run_fold``'s compute
        contract; ``kernel(zr, zi, lo, hi) -> (kr, ki)`` receives one
        folded slab plus its static row range ``[lo, hi)`` along the slab
        axis (to slice planar multipliers in lockstep); ``inv(ur, ui)``
        matches ``run_unfold``'s. ``diag`` optionally carries the raw
        planar multiplier pair for engines that can fuse the diagonal
        multiply into their communication kernel; the base schedule
        ignores it.
        """
        del diag  # consumed only by the in-kernel payload engines
        axis = step.slab_offset % arrs[0].ndim
        size = arrs[0].shape[axis]
        c = min(max(self.chunks, 1), size)
        while size % c:
            c -= 1
        stride = size // c

        outs = []
        for i in range(c):
            sl = [lax.slice_in_dim(a, i * stride, (i + 1) * stride,
                                   axis=axis) for a in arrs]
            cr, ci = fwd(*sl)
            zr = self.fold_step(step, cr)
            zi = self.fold_step(step, ci)
            kr, ki = kernel(zr, zi, i * stride, (i + 1) * stride)
            ur = self.unfold_step(step, kr)
            ui = self.unfold_step(step, ki)
            outs.append(inv(ur, ui))
        return tuple(jnp.concatenate([o[k] for o in outs], axis=axis)
                     for k in range(len(outs[0])))


@_register
class SwitchedEngine(TransposeEngine):
    """Single ``lax.all_to_all`` per fold — Fig. 5.10 / Eq. 5.5."""

    name = "switched"
    mode = "switched"
    fabric = "switched"


@_register
class TorusEngine(TransposeEngine):
    """P−1 ``lax.ppermute`` ring rounds per fold — Fig. 5.9 / Eq. 5.6.

    A grid dimension spanning several mesh axes runs the staged per-axis
    ring of ``transpose.staged_exchange`` (Σ(qᵢ−1) rounds, neighbor hops
    only) — ``all_to_all_blocks(mode="torus")`` routes through
    ``ring_exchange``, which stages multi-axis tuples itself.
    """

    name = "torus"
    mode = "torus"
    fabric = "torus"


# ---------------------------------------------------------------------------
# overlap ring: the ring with butterflies emitted between its rounds
# ---------------------------------------------------------------------------

@_register
class OverlapRingEngine(TorusEngine):
    """The ring with the 1D FFT fused into it (paper Fig. 4.3, tasks C/G).

    Forward: the local volume is cut into slabs along the step's slab axis
    (one per ring rank by default, so compute granularity matches block
    granularity); slab i+1's butterflies are emitted between slab i's
    ppermute rounds. Inverse: slab i−1's butterflies (on blocks already
    received) run between slab i's rounds — "ship one block while the
    previously-received block's butterflies run". The relayout itself is
    the shared ring primitive, so results match the other engines' (same
    blocks, same order).

    Every exchange — the fold/unfold relayout primitives *and* the
    overlapped phases — goes through ``self._exchange``, the one hook a
    subclass overrides to swap the transport (``PallasRingEngine`` routes
    it into the async-RDMA kernel).
    """

    name = "overlap_ring"
    mode = "torus"
    fabric = "torus"

    #: wire rounds one exchange costs over a q-rank mesh axis — the round
    #: model the ``exchange_rounds`` counter accumulates per communicating
    #: axis (pure Python, so the complexity claim is unit-testable without
    #: devices)
    wire_rounds = staticmethod(tr.ring_rounds)

    def _count_rounds(self, axes):
        """Σ ``wire_rounds(qᵢ)`` over the communicating mesh axes — the
        per-axis round model of the staged multi-axis exchange."""
        rounds = sum(self.wire_rounds(q) for q in tr.comm_axis_sizes(axes))
        self.exchange_rounds += rounds
        obs.metrics.inc(f"comm.engine_exchange_rounds.{self.name}", rounds)

    # ---- the transport hook ----------------------------------------------
    def _exchange(self, arrs, axes, *, split_axis: int, concat_axis: int,
                  interleave=None):
        """Tiled ring all-to-all of same-shaped ``arrs`` (+ fused thunk)."""
        self._count_rounds(axes)
        return tr.ring_exchange(arrs, axes, split_axis=split_axis,
                                concat_axis=concat_axis, interleave=interleave)

    # ---- relayout primitives routed through the transport hook -----------
    # (folds over a 1-rank dimension never communicate: defer to the base
    # methods, which degenerate to pure local transposes)
    def _fold_ring(self, step: dec.CommStep, a):
        d = a.ndim
        outs, _ = self._exchange((a,), self._axes(step),
                                 split_axis=d + step.split_offset,
                                 concat_axis=d + step.concat_offset)
        return tr.permute_last3(outs[0], step.permute)

    def _unfold_ring(self, step: dec.CommStep, a):
        b = tr.permute_last3(a, step.permute)
        d = b.ndim
        outs, _ = self._exchange((b,), self._axes(step),
                                 split_axis=d + step.unfold_split,
                                 concat_axis=d + step.unfold_concat)
        return outs[0]

    def fold_step(self, step: dec.CommStep, a):
        if self.grid.dim_ranks(step.grid_dim) <= 1:
            return super().fold_step(step, a)
        return self._fold_ring(step, a)

    def unfold_step(self, step: dec.CommStep, a):
        if self.grid.dim_ranks(step.grid_dim) <= 1:
            return super().unfold_step(step, a)
        return self._unfold_ring(step, a)

    # ---- overlapped phase schedules --------------------------------------
    def _n_slabs(self, size: int, ranks: int) -> int:
        ns = self.chunks if self.chunks > 1 else max(ranks, 2)
        ns = min(ns, size)
        while size % ns:
            ns -= 1
        return max(ns, 1)

    def run_fold(self, step: dec.CommStep, compute, arrs):
        p = self.grid.dim_ranks(step.grid_dim)
        if p <= 1:  # fold never communicates — nothing to overlap
            return super().run_fold(step, compute, arrs)
        axis = step.slab_offset % arrs[0].ndim
        size = arrs[0].shape[axis]
        ns = self._n_slabs(size, p)
        stride = size // ns
        axes = self._axes(step)

        def slab(i):
            return tuple(lax.slice_in_dim(a, i * stride, (i + 1) * stride,
                                          axis=axis) for a in arrs)

        cur = compute(*slab(0))
        outs = []
        for i in range(ns):
            nxt = (lambda j=i + 1: compute(*slab(j))) if i + 1 < ns else None
            d = cur[0].ndim
            (fr, fi), follow = self._exchange(
                (cur[0], cur[1]), axes, split_axis=d + step.split_offset,
                concat_axis=d + step.concat_offset, interleave=nxt)
            outs.append((tr.permute_last3(fr, step.permute),
                         tr.permute_last3(fi, step.permute)))
            cur = follow
        return tuple(jnp.concatenate([o[k] for o in outs], axis=axis)
                     for k in range(2))

    def run_unfold(self, step: dec.CommStep, compute, arrs):
        p = self.grid.dim_ranks(step.grid_dim)
        if p <= 1:
            return super().run_unfold(step, compute, arrs)
        axis = step.slab_offset % arrs[0].ndim
        size = arrs[0].shape[axis]
        ns = self._n_slabs(size, p)
        stride = size // ns
        axes = self._axes(step)

        outs = []
        prev = None
        for i in range(ns):
            sl = [lax.slice_in_dim(a, i * stride, (i + 1) * stride, axis=axis)
                  for a in arrs]
            br = tr.permute_last3(sl[0], step.permute)
            bi = tr.permute_last3(sl[1], step.permute)
            d = br.ndim
            thunk = (lambda c=prev: compute(*c)) if prev is not None else None
            (ur, ui), done = self._exchange(
                (br, bi), axes, split_axis=d + step.unfold_split,
                concat_axis=d + step.unfold_concat, interleave=thunk)
            if done is not None:
                outs.append(done)
            prev = (ur, ui)
        outs.append(compute(*prev))
        return tuple(jnp.concatenate([o[k] for o in outs], axis=axis)
                     for k in range(len(outs[0])))

    def run_roundtrip(self, step: dec.CommStep, fwd, kernel, inv, arrs, *,
                      diag=None):
        """The slab-streamed roundtrip: slab k's kernel and slab k−2's
        inverse butterflies run in slab k−1's unfold-exchange overlap
        window, while slab k+1's forward butterflies ride slab k's fold
        exchange — fold k+1 ∥ kernel k ∥ unfold k−1, with only slab 0's
        kernel exposed as pipeline fill."""
        p = self.grid.dim_ranks(step.grid_dim)
        if p <= 1:  # step never communicates — nothing to overlap
            return super().run_roundtrip(step, fwd, kernel, inv, arrs,
                                         diag=diag)
        axis = step.slab_offset % arrs[0].ndim
        size = arrs[0].shape[axis]
        ns = self._n_slabs(size, p)
        stride = size // ns
        axes = self._axes(step)

        def slab(i):
            return tuple(lax.slice_in_dim(a, i * stride, (i + 1) * stride,
                                          axis=axis) for a in arrs)

        def unfold_exchange(mid, thunk):
            br = tr.permute_last3(mid[0], step.permute)
            bi = tr.permute_last3(mid[1], step.permute)
            d = br.ndim
            return self._exchange(
                (br, bi), axes, split_axis=d + step.unfold_split,
                concat_axis=d + step.unfold_concat, interleave=thunk)

        cur = fwd(*slab(0))
        mid = tail = None
        outs = []
        for i in range(ns):
            nxt = (lambda j=i + 1: fwd(*slab(j))) if i + 1 < ns else None
            d = cur[0].ndim
            (fr, fi), follow = self._exchange(
                (cur[0], cur[1]), axes, split_axis=d + step.split_offset,
                concat_axis=d + step.concat_offset, interleave=nxt)
            folded = (tr.permute_last3(fr, step.permute),
                      tr.permute_last3(fi, step.permute))
            cur = follow

            def kern(f=folded, lo=i * stride, hi=(i + 1) * stride):
                return kernel(f[0], f[1], lo, hi)

            if mid is None:
                mid = kern()            # pipeline fill: slab 0's kernel
                continue
            # slab i−1's unfold exchange hides slab i's kernel and slab
            # i−2's inverse butterflies
            def thunk(k=kern, t=tail):
                return k(), (inv(*t) if t is not None else None)
            (ur, ui), (mid, fin) = unfold_exchange(mid, thunk)
            if fin is not None:
                outs.append(fin)
            tail = (ur, ui)
        # drain: the last kernel result unfolds over slab ns−2's inverse
        # butterflies, then the final slab's butterflies run exposed
        thunk = (lambda t=tail: inv(*t)) if tail is not None else None
        (ur, ui), fin = unfold_exchange(mid, thunk)
        if fin is not None:
            outs.append(fin)
        outs.append(inv(ur, ui))
        return tuple(jnp.concatenate([o[k] for o in outs], axis=axis)
                     for k in range(len(outs[0])))


# ---------------------------------------------------------------------------
# pallas ring: the same schedule as an async-RDMA kernel (the paper's NIC)
# ---------------------------------------------------------------------------

@_register
class PallasRingEngine(OverlapRingEngine):
    """The overlapped ring with its transport lowered to the Pallas
    async-RDMA kernel of ``kernels.ring_rdma`` (paper §4.2's NIC engine).

    On TPU every exchange is one fused kernel of P−1 double-buffered
    ``make_async_remote_copy`` rounds per mesh axis (multi-axis grid
    dimensions stage one kernel per axis) — and when the phase butterflies
    are the radix-2 c2c engine (``backend="pallas"``, a ``c2c`` CommStep),
    they run *inside* the kernel between a round's ``start`` and ``wait``,
    making the send/compute overlap explicit rather than
    scheduler-dependent. Off-TPU the kernel's interpret fallback keeps the
    identical schedule and block order (ppermute wire hop + Pallas NIC
    staging kernels), so the engine is bit-exact vs ``torus`` everywhere
    it runs.
    """

    name = "pallas_ring"
    mode = "torus"
    fabric = "torus"

    # ---- the RDMA transport hooks ----------------------------------------
    def _transport(self, arrs, axes, **kw):
        """The async-RDMA contract this engine's exchanges lower to — the
        one method ``bidi_ring`` overrides to swap in the two-NIC kernel."""
        from repro.kernels import ring_rdma
        return ring_rdma.ring_exchange_rdma(arrs, axes, **kw)

    def _rdma(self, arrs, axes, **kw):
        """Counted transport: every exchange — the ``_exchange`` hook *and*
        the fused phases' in-kernel payload path — goes through here, so
        ``exchange_rounds`` reflects the kernel's real round complexity
        (summed per communicating mesh axis under staging)."""
        self._count_rounds(axes)
        return self._transport(arrs, axes, **kw)

    def _exchange(self, arrs, axes, *, split_axis: int, concat_axis: int,
                  interleave=None):
        return self._rdma(arrs, axes, split_axis=split_axis,
                          concat_axis=concat_axis, interleave=interleave)

    # ---- in-kernel butterfly fusion (TPU only) ---------------------------
    def _fusable(self, step: dec.CommStep, pair) -> bool:
        """When in-kernel radix-2 butterflies reproduce the phase compute:
        the plan's engine is the Pallas radix-2 kernel and the step wraps a
        plain c2c transform (the r2c X phase pads/packs — not fusable).
        Multi-axis steps fuse too: the payload rides the first staged
        ring; later stages relay the already-butterflied blocks."""
        from repro.kernels import ring_rdma
        return (ring_rdma.use_rdma() and self.backend == "pallas"
                and step.c2c and ring_rdma.fusable_payload(pair))

    def run_fold(self, step: dec.CommStep, compute, arrs):
        p = self.grid.dim_ranks(step.grid_dim)
        if p <= 1 or not self._fusable(step, tuple(arrs[:2])):
            return super().run_fold(step, compute, arrs)
        axis = step.slab_offset % arrs[0].ndim
        size = arrs[0].shape[axis]
        ns = self._n_slabs(size, p)
        stride = size // ns
        axes = self._axes(step)

        def slab(i):
            return tuple(lax.slice_in_dim(a, i * stride, (i + 1) * stride,
                                          axis=axis) for a in arrs)

        cur = compute(*slab(0))
        outs = []
        for i in range(ns):
            payload = slab(i + 1) if i + 1 < ns else None
            d = cur[0].ndim
            ex, follow = self._rdma(
                (cur[0], cur[1]), axes, split_axis=d + step.split_offset,
                concat_axis=d + step.concat_offset, payload=payload)
            outs.append((tr.permute_last3(ex[0], step.permute),
                         tr.permute_last3(ex[1], step.permute)))
            cur = follow
        return tuple(jnp.concatenate([o[k] for o in outs], axis=axis)
                     for k in range(2))

    def run_unfold(self, step: dec.CommStep, compute, arrs):
        p = self.grid.dim_ranks(step.grid_dim)
        if p <= 1 or not self._fusable(step, tuple(arrs[:2])):
            return super().run_unfold(step, compute, arrs)
        axis = step.slab_offset % arrs[0].ndim
        size = arrs[0].shape[axis]
        ns = self._n_slabs(size, p)
        stride = size // ns
        axes = self._axes(step)

        outs = []
        prev = None
        for i in range(ns):
            sl = [lax.slice_in_dim(a, i * stride, (i + 1) * stride, axis=axis)
                  for a in arrs]
            br = tr.permute_last3(sl[0], step.permute)
            bi = tr.permute_last3(sl[1], step.permute)
            d = br.ndim
            ex, done = self._rdma(
                (br, bi), axes, split_axis=d + step.unfold_split,
                concat_axis=d + step.unfold_concat, payload=prev,
                inverse=True)
            if done is not None:
                outs.append(done)
            prev = (ex[0], ex[1])
        outs.append(compute(*prev))
        return tuple(jnp.concatenate([o[k] for o in outs], axis=axis)
                     for k in range(len(outs[0])))

    def run_roundtrip(self, step: dec.CommStep, fwd, kernel, inv, arrs, *,
                      diag=None):
        """The RDMA roundtrip: slab k+1's forward butterflies ride slab
        k's fold kernel as payload (like ``run_fold``), and the *entire*
        spectral middle of slab k — forward butterflies, diagonal
        multiply, conjugate-trick inverse — rides slab k−1's unfold
        kernel as a roundtrip payload (``diag=``), the paper's NIC
        offload extended from butterflies to the spectral computation.
        The inverse butterflies after each unfold run at the JAX level
        (both payload slots per slab are taken). Requires the raw planar
        multiplier ``diag``; otherwise (or off-TPU) the overlapped-ring
        schedule of the superclass applies."""
        from repro.kernels import ring_rdma
        p = self.grid.dim_ranks(step.grid_dim)
        if (p <= 1 or diag is None
                or not self._fusable(step, tuple(arrs[:2]))
                or not ring_rdma.fusable_payload((diag[0], diag[0]))):
            return super().run_roundtrip(step, fwd, kernel, inv, arrs,
                                         diag=diag)
        axis = step.slab_offset % arrs[0].ndim
        size = arrs[0].shape[axis]
        ns = self._n_slabs(size, p)
        stride = size // ns
        axes = self._axes(step)
        dr, di = diag
        if di is None:
            di = jnp.zeros_like(dr)
        daxis = dr.ndim + step.slab_offset

        def slab(i):
            return tuple(lax.slice_in_dim(a, i * stride, (i + 1) * stride,
                                          axis=axis) for a in arrs)

        def diag_slab(i, like):
            # the multiplier rows of folded slab i, broadcast to the
            # payload's leading (component/batch) axes
            sr = lax.slice_in_dim(dr, i * stride, (i + 1) * stride,
                                  axis=daxis)
            si = lax.slice_in_dim(di, i * stride, (i + 1) * stride,
                                  axis=daxis)
            return (jnp.broadcast_to(sr, like[0].shape),
                    jnp.broadcast_to(si, like[1].shape))

        def unfold_rdma(mid, **kw):
            br = tr.permute_last3(mid[0], step.permute)
            bi = tr.permute_last3(mid[1], step.permute)
            d = br.ndim
            return self._rdma(
                (br, bi), axes, split_axis=d + step.unfold_split,
                concat_axis=d + step.unfold_concat, **kw)

        cur = fwd(*slab(0))
        mid = None
        outs = []
        for i in range(ns):
            payload = slab(i + 1) if i + 1 < ns else None
            d = cur[0].ndim
            ex, follow = self._rdma(
                (cur[0], cur[1]), axes, split_axis=d + step.split_offset,
                concat_axis=d + step.concat_offset, payload=payload)
            folded = (tr.permute_last3(ex[0], step.permute),
                      tr.permute_last3(ex[1], step.permute))
            cur = follow
            if mid is None:
                mid = kernel(folded[0], folded[1], 0, stride)  # fill
                continue
            # slab i−1's unfold carries slab i's whole middle in-kernel
            ex2, mid = unfold_rdma(mid, payload=folded,
                                   diag=diag_slab(i, folded))
            outs.append(inv(ex2[0], ex2[1]))
        ex2, _ = unfold_rdma(mid)
        outs.append(inv(ex2[0], ex2[1]))
        return tuple(jnp.concatenate([o[k] for o in outs], axis=axis)
                     for k in range(len(outs[0])))


# ---------------------------------------------------------------------------
# bidirectional ring: both torus directions per round (two NICs, Fig. 5.9)
# ---------------------------------------------------------------------------

@_register
class BidiRingEngine(PallasRingEngine):
    """The ring driven over *both* torus directions at once (paper Fig. 5.9:
    every node owns a +u and a −u link, and the NIC can stream on both).

    Each fold's blocks split into a clockwise and a counter-clockwise
    stream — round r ships block me+r one way and block me−r the other, on
    opposite links — so the exchange completes in ``ceil((P−1)/2)`` rounds
    instead of the unidirectional rings' P−1 (``wire_rounds``; asserted via
    the ``exchange_rounds`` counter, summed per mesh axis for multi-axis
    grid dimensions). P=2 degenerates to the plain ring (both directions
    name the same neighbor, one round); odd P splits (P−1)/2 blocks per
    direction every round; even P sends the shared farthest block clockwise
    only on the last round.

    Transports: on TPU the exchange is the bidirectional async-RDMA kernel
    (``kernels.ring_rdma.ring_exchange_bidi_rdma`` — double-buffered
    ``make_async_remote_copy`` sends to both neighbors per round with
    per-direction semaphores, in-kernel butterflies on fusable payloads
    like ``pallas_ring``, one staged kernel per mesh axis on multi-axis
    grid dimensions); off-TPU it is the two counter-rotating ``ppermute``
    streams of ``transpose.ring_exchange_bidi``, keeping the
    ``overlap_ring`` compute-overlap schedule with half the rounds and
    staying bit-exact vs ``torus``.
    """

    name = "bidi_ring"
    mode = "torus"
    fabric = "torus"

    wire_rounds = staticmethod(tr.bidi_rounds)

    def _transport(self, arrs, axes, **kw):
        from repro.kernels import ring_rdma
        return ring_rdma.ring_exchange_bidi_rdma(arrs, axes, **kw)


ENGINE_NAMES = tuple(ENGINES)
# ("switched", "torus", "overlap_ring", "pallas_ring", "bidi_ring")
