"""TransposeEngine — pluggable fold-communication layer (paper §4.2–4.3).

The paper's central architectural claim is that the fold communications
(hardware tasks C and G) must be *pipelined against* the butterfly engines,
not barriered between phases (Fig. 4.3): the NIC streams blocks while the
FFT engines keep computing. This module makes that scheduling decision a
first-class, pluggable object with five implementations:

* ``SwitchedEngine``    — one ``lax.all_to_all`` per fold (the 2D switched
  fabric of Fig. 5.10, Eq. 5.5). Overlap across ``chunks`` slabs is left to
  XLA's latency-hiding scheduler.
* ``TorusEngine``       — P−1 ``lax.ppermute`` ring rounds per fold (the 2D
  torus of Fig. 5.9, Eq. 5.6), same slab-level scheduling as switched.
* ``OverlapRingEngine`` — fuses the 1D FFT *into* the ring: while each of
  the P−1 ppermute rounds ships one block, another block's butterflies are
  emitted between the rounds, so compute and ``lax.ppermute`` interleave at
  block granularity instead of phase granularity — the TPU rendition of the
  paper's task C/G ↔ engine overlap.
* ``PallasRingEngine``  — the same ring schedule as a Pallas async-RDMA
  kernel (``kernels.ring_rdma``): each round *starts* the next block's
  neighbor DMA, computes, then waits — the overlap is explicit in the
  kernel (the paper's NIC offload) instead of hoped-for from XLA's
  scheduler. Off-TPU it runs the kernel's interpret-mode fallback
  (ppermute wire hop + Pallas NIC staging), bit-exact vs ``torus``.
* ``BidiRingEngine``    — the two-NIC ring of Fig. 5.9: every fold splits
  its blocks into a clockwise and a counter-clockwise stream and drives
  both torus directions concurrently, finishing in ``ceil((P−1)/2)``
  exchange rounds instead of P−1. On TPU the exchange is the bidirectional
  async-RDMA kernel (``kernels.ring_rdma.ring_exchange_bidi_rdma``,
  double-buffered sends to both neighbors with per-direction semaphores);
  off-TPU it is the two counter-rotating ``ppermute`` streams of
  ``transpose.ring_exchange_bidi`` — same overlapped schedule as
  ``overlap_ring``, half the rounds.

Ring engines carry an ``exchange_rounds`` counter: every exchange routed
through the ``_exchange``/``_rdma`` hooks adds its wire-round count
(``wire_rounds(P)`` — P−1 for the unidirectional rings, ``ceil((P−1)/2)``
for the bidirectional one) at trace time, so tests can pin the round
complexity an engine actually uses.

Engines expose two surfaces:

* **relayout primitives** ``fold_xy / unfold_xy / fold_yz / unfold_yz`` —
  pure data movement over the shared block-exchange primitives of
  ``core.transpose``; every engine computes the identical relayout, and
  ``unfold ∘ fold`` is the identity (property-tested).
* **the scheduling contract** ``fold_phase / unfold_phase`` — a full FFT
  phase (butterflies then fold, or unfold then butterflies) that the engine
  is free to chunk, stream, or fuse. ``fft3d_local``/``ifft3d_local`` are
  written against this contract only; the old ``_run_chunked`` slab loop
  lives here as the base engine's schedule.

All engine methods run *inside* ``shard_map`` over the FFT mesh axes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import transpose as tr


# ---------------------------------------------------------------------------
# slab scheduling (the paper's Fig. 4.2/4.3 chunking, ex fft3d._run_chunked)
# ---------------------------------------------------------------------------

def run_chunked(fn, arrs, axis: int, chunks: int):
    """Apply ``fn`` per slab along ``axis`` (same axis in/out), concat results.

    Emitting independent per-slab chains is what lets XLA overlap slab i's
    collective with slab i+1's compute (paper Fig. 4.3 timeline).
    """
    if chunks == 1:
        return fn(*arrs)
    axis = axis % arrs[0].ndim
    size = arrs[0].shape[axis]
    c = min(chunks, size)
    while size % c:
        c -= 1
    outs = []
    step = size // c
    for i in range(c):
        sl = [jax.lax.slice_in_dim(a, i * step, (i + 1) * step, axis=axis)
              for a in arrs]
        outs.append(fn(*sl))
    if isinstance(outs[0], tuple):
        return tuple(jnp.concatenate([o[j] for o in outs], axis=axis)
                     for j in range(len(outs[0])))
    return jnp.concatenate(outs, axis=axis)


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

ENGINES: dict[str, type] = {}


def _register(cls):
    ENGINES[cls.name] = cls
    return cls


def make_engine(name: str, grid, chunks: int = 1, *, backend: str = "jnp",
                real: bool = False) -> "TransposeEngine":
    """Instantiate a registered engine for a ``PencilGrid``.

    ``backend``/``real`` describe the butterfly compute the engine will be
    asked to schedule (the ``FFT3DPlan`` knobs): engines that can *fuse*
    compute into their communication kernel (``pallas_ring`` on TPU) use
    them to decide when in-kernel butterflies reproduce the phase compute.
    """
    try:
        cls = ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown comm engine {name!r}; have {sorted(ENGINES)}") from None
    return cls(grid, chunks=chunks, backend=backend, real=real)


def engine_fabric(name: str) -> str:
    """The §5.5 network fabric an engine needs sizing for."""
    try:
        return ENGINES[name].fabric
    except KeyError:
        raise ValueError(
            f"unknown comm engine {name!r}; have {sorted(ENGINES)}") from None


# ---------------------------------------------------------------------------
# base engine: phase = compute + fold, scheduled at slab granularity
# ---------------------------------------------------------------------------

class TransposeEngine:
    """Interface + slab-granular base schedule shared by switched/torus."""

    name = "base"
    mode = "switched"    # wire format of the shared block-exchange primitives
    fabric = "switched"  # §5.5 network the engine maps onto

    def __init__(self, grid, chunks: int = 1, *, backend: str = "jnp",
                 real: bool = False):
        self.grid = grid
        self.chunks = max(int(chunks), 1)
        self.backend = backend   # butterfly engine the schedule will run
        self.real = real         # r2c data model (X phase is not plain c2c)
        # wire rounds traced through the ring engines' exchange hooks (the
        # base/switched engines never route through them and keep 0)
        self.exchange_rounds = 0

    # ---- relayout primitives (pure data movement) ------------------------
    def fold_xy(self, a):
        return tr.xy_fold(a, self.grid.u_axes, mode=self.mode)

    def unfold_xy(self, a):
        return tr.xy_unfold(a, self.grid.u_axes, mode=self.mode)

    def fold_yz(self, a):
        return tr.yz_fold(a, self.grid.v_axes, mode=self.mode)

    def unfold_yz(self, a):
        return tr.yz_unfold(a, self.grid.v_axes, mode=self.mode)

    def fold(self, which: str, a):
        return self.fold_xy(a) if which == "xy" else self.fold_yz(a)

    def unfold(self, which: str, a):
        return self.unfold_xy(a) if which == "xy" else self.unfold_yz(a)

    def _axes(self, which: str):
        return self.grid.u_axes if which == "xy" else self.grid.v_axes

    def _ranks(self, which: str) -> int:
        return self.grid.pu if which == "xy" else self.grid.pv

    # ---- scheduling contract ---------------------------------------------
    def fold_phase(self, compute, arrs, *, fold: str, slab_axis: int):
        """Forward phase: butterflies (``compute``) then the ``fold`` relayout.

        ``compute(*slab) -> tuple`` runs the 1D FFT of the phase; ``slab_axis``
        is a local axis untouched by the fold, along which the engine may
        slice the volume without changing the result.
        """
        def phase(*sl):
            return tuple(self.fold(fold, o) for o in compute(*sl))
        return run_chunked(phase, arrs, axis=slab_axis, chunks=self.chunks)

    def unfold_phase(self, compute, arrs, *, fold: str, slab_axis: int):
        """Inverse phase: the ``unfold`` relayout then butterflies."""
        def phase(*sl):
            return compute(*(self.unfold(fold, a) for a in sl))
        return run_chunked(phase, arrs, axis=slab_axis, chunks=self.chunks)


@_register
class SwitchedEngine(TransposeEngine):
    """Single ``lax.all_to_all`` per fold — Fig. 5.10 / Eq. 5.5."""

    name = "switched"
    mode = "switched"
    fabric = "switched"


@_register
class TorusEngine(TransposeEngine):
    """P−1 ``lax.ppermute`` ring rounds per fold — Fig. 5.9 / Eq. 5.6."""

    name = "torus"
    mode = "torus"
    fabric = "torus"


# ---------------------------------------------------------------------------
# overlap ring: the ring with butterflies emitted between its rounds
# ---------------------------------------------------------------------------

# (split_axis, concat_axis, post-transpose) of each fold's block exchange,
# as offsets from ndim — mirrors transpose.xy_fold / yz_fold exactly.
_FOLD_GEOM = {"xy": (-1, -3, tr._swap_last3), "yz": (-1, -2, tr._swap_last2)}
# (pre-transpose, split_axis, concat_axis) of each unfold
_UNFOLD_GEOM = {"xy": (tr._swap_last3, -3, -1), "yz": (tr._swap_last2, -2, -1)}


@_register
class OverlapRingEngine(TorusEngine):
    """The ring with the 1D FFT fused into it (paper Fig. 4.3, tasks C/G).

    Forward: the local volume is cut into slabs along ``slab_axis`` (one per
    ring rank by default, so compute granularity matches block granularity);
    slab i+1's butterflies are emitted between slab i's ppermute rounds.
    Inverse: slab i−1's butterflies (on blocks already received) run between
    slab i's rounds — "ship one block while the previously-received block's
    butterflies run". The relayout itself is the shared ring primitive, so
    results match the other engines' (same blocks, same order).

    Every exchange — the fold/unfold relayout primitives *and* the
    overlapped phases — goes through ``self._exchange``, the one hook a
    subclass overrides to swap the transport (``PallasRingEngine`` routes
    it into the async-RDMA kernel).
    """

    name = "overlap_ring"
    mode = "torus"
    fabric = "torus"

    #: wire rounds one exchange costs over a P-rank dimension — the round
    #: model the ``exchange_rounds`` counter accumulates (pure Python, so
    #: the complexity claim is unit-testable without devices)
    wire_rounds = staticmethod(tr.ring_rounds)

    # ---- the transport hook ----------------------------------------------
    def _exchange(self, arrs, axes, *, split_axis: int, concat_axis: int,
                  interleave=None):
        """Tiled ring all-to-all of same-shaped ``arrs`` (+ fused thunk)."""
        self.exchange_rounds += self.wire_rounds(tr._axis_size(axes))
        return tr.ring_exchange(arrs, axes, split_axis=split_axis,
                                concat_axis=concat_axis, interleave=interleave)

    # ---- relayout primitives routed through the transport hook -----------
    # (folds over a 1-rank dimension never communicate: defer to the base
    # leaf methods, which degenerate to pure local transposes)
    def _fold_ring(self, which: str, a):
        split_off, concat_off, post = _FOLD_GEOM[which]
        d = a.ndim
        outs, _ = self._exchange((a,), self._axes(which),
                                 split_axis=d + split_off,
                                 concat_axis=d + concat_off)
        return post(outs[0])

    def _unfold_ring(self, which: str, a):
        pre, split_off, concat_off = _UNFOLD_GEOM[which]
        b = pre(a)
        d = b.ndim
        outs, _ = self._exchange((b,), self._axes(which),
                                 split_axis=d + split_off,
                                 concat_axis=d + concat_off)
        return outs[0]

    def fold_xy(self, a):
        if self._ranks("xy") <= 1:
            return super().fold_xy(a)
        return self._fold_ring("xy", a)

    def fold_yz(self, a):
        if self._ranks("yz") <= 1:
            return super().fold_yz(a)
        return self._fold_ring("yz", a)

    def unfold_xy(self, a):
        if self._ranks("xy") <= 1:
            return super().unfold_xy(a)
        return self._unfold_ring("xy", a)

    def unfold_yz(self, a):
        if self._ranks("yz") <= 1:
            return super().unfold_yz(a)
        return self._unfold_ring("yz", a)

    # ---- overlapped phase schedules --------------------------------------
    def _n_slabs(self, size: int, ranks: int) -> int:
        ns = self.chunks if self.chunks > 1 else max(ranks, 2)
        ns = min(ns, size)
        while size % ns:
            ns -= 1
        return max(ns, 1)

    def fold_phase(self, compute, arrs, *, fold: str, slab_axis: int):
        p = self._ranks(fold)
        if p <= 1:  # fold never communicates — nothing to overlap
            return super().fold_phase(compute, arrs, fold=fold,
                                      slab_axis=slab_axis)
        axis = slab_axis % arrs[0].ndim
        size = arrs[0].shape[axis]
        ns = self._n_slabs(size, p)
        step = size // ns
        split_off, concat_off, post = _FOLD_GEOM[fold]
        axes = self._axes(fold)

        def slab(i):
            return tuple(lax.slice_in_dim(a, i * step, (i + 1) * step,
                                          axis=axis) for a in arrs)

        cur = compute(*slab(0))
        outs = []
        for i in range(ns):
            nxt = (lambda j=i + 1: compute(*slab(j))) if i + 1 < ns else None
            d = cur[0].ndim
            (fr, fi), follow = self._exchange(
                (cur[0], cur[1]), axes, split_axis=d + split_off,
                concat_axis=d + concat_off, interleave=nxt)
            outs.append((post(fr), post(fi)))
            cur = follow
        return tuple(jnp.concatenate([o[k] for o in outs], axis=axis)
                     for k in range(2))

    def unfold_phase(self, compute, arrs, *, fold: str, slab_axis: int):
        p = self._ranks(fold)
        if p <= 1:
            return super().unfold_phase(compute, arrs, fold=fold,
                                        slab_axis=slab_axis)
        axis = slab_axis % arrs[0].ndim
        size = arrs[0].shape[axis]
        ns = self._n_slabs(size, p)
        step = size // ns
        pre, split_off, concat_off = _UNFOLD_GEOM[fold]
        axes = self._axes(fold)

        outs = []
        prev = None
        for i in range(ns):
            sl = [lax.slice_in_dim(a, i * step, (i + 1) * step, axis=axis)
                  for a in arrs]
            br, bi = pre(sl[0]), pre(sl[1])
            d = br.ndim
            thunk = (lambda c=prev: compute(*c)) if prev is not None else None
            (ur, ui), done = self._exchange(
                (br, bi), axes, split_axis=d + split_off,
                concat_axis=d + concat_off, interleave=thunk)
            if done is not None:
                outs.append(done)
            prev = (ur, ui)
        outs.append(compute(*prev))
        return tuple(jnp.concatenate([o[k] for o in outs], axis=axis)
                     for k in range(len(outs[0])))


# ---------------------------------------------------------------------------
# pallas ring: the same schedule as an async-RDMA kernel (the paper's NIC)
# ---------------------------------------------------------------------------

@_register
class PallasRingEngine(OverlapRingEngine):
    """The overlapped ring with its transport lowered to the Pallas
    async-RDMA kernel of ``kernels.ring_rdma`` (paper §4.2's NIC engine).

    On TPU every exchange is one fused kernel of P−1 double-buffered
    ``make_async_remote_copy`` rounds — and when the phase butterflies are
    the radix-2 c2c engine (``backend="pallas"``, complex data), they run
    *inside* the kernel between a round's ``start`` and ``wait``, making
    the send/compute overlap explicit rather than scheduler-dependent.
    Off-TPU the kernel's interpret fallback keeps the identical schedule
    and block order (ppermute wire hop + Pallas NIC staging kernels), so
    the engine is bit-exact vs ``torus`` everywhere it runs.
    """

    name = "pallas_ring"
    mode = "torus"
    fabric = "torus"

    # ---- the RDMA transport hooks ----------------------------------------
    def _transport(self, arrs, axes, **kw):
        """The async-RDMA contract this engine's exchanges lower to — the
        one method ``bidi_ring`` overrides to swap in the two-NIC kernel."""
        from repro.kernels import ring_rdma
        return ring_rdma.ring_exchange_rdma(arrs, axes, **kw)

    def _rdma(self, arrs, axes, **kw):
        """Counted transport: every exchange — the ``_exchange`` hook *and*
        the fused phases' in-kernel payload path — goes through here, so
        ``exchange_rounds`` reflects the kernel's real round complexity."""
        self.exchange_rounds += self.wire_rounds(tr._axis_size(axes))
        return self._transport(arrs, axes, **kw)

    def _exchange(self, arrs, axes, *, split_axis: int, concat_axis: int,
                  interleave=None):
        return self._rdma(arrs, axes, split_axis=split_axis,
                          concat_axis=concat_axis, interleave=interleave)

    # ---- in-kernel butterfly fusion (TPU only) ---------------------------
    def _fusable(self, fold: str, pair) -> bool:
        """When in-kernel radix-2 butterflies reproduce the phase compute:
        the plan's engine is the Pallas radix-2 kernel and the phase is a
        plain c2c transform (the r2c X phase pads/packs — not fusable)."""
        from repro.kernels import ring_rdma
        return (ring_rdma.use_rdma() and self.backend == "pallas"
                and (fold == "yz" or not self.real)
                and len(self._axes(fold)) == 1
                and ring_rdma.fusable_payload(pair))

    def fold_phase(self, compute, arrs, *, fold: str, slab_axis: int):
        p = self._ranks(fold)
        if p <= 1 or not self._fusable(fold, tuple(arrs[:2])):
            return super().fold_phase(compute, arrs, fold=fold,
                                      slab_axis=slab_axis)
        axis = slab_axis % arrs[0].ndim
        size = arrs[0].shape[axis]
        ns = self._n_slabs(size, p)
        step = size // ns
        split_off, concat_off, post = _FOLD_GEOM[fold]
        axes = self._axes(fold)

        def slab(i):
            return tuple(lax.slice_in_dim(a, i * step, (i + 1) * step,
                                          axis=axis) for a in arrs)

        cur = compute(*slab(0))
        outs = []
        for i in range(ns):
            payload = slab(i + 1) if i + 1 < ns else None
            d = cur[0].ndim
            ex, follow = self._rdma(
                (cur[0], cur[1]), axes, split_axis=d + split_off,
                concat_axis=d + concat_off, payload=payload)
            outs.append((post(ex[0]), post(ex[1])))
            cur = follow
        return tuple(jnp.concatenate([o[k] for o in outs], axis=axis)
                     for k in range(2))

    def unfold_phase(self, compute, arrs, *, fold: str, slab_axis: int):
        p = self._ranks(fold)
        if p <= 1 or not self._fusable(fold, tuple(arrs[:2])):
            return super().unfold_phase(compute, arrs, fold=fold,
                                        slab_axis=slab_axis)
        axis = slab_axis % arrs[0].ndim
        size = arrs[0].shape[axis]
        ns = self._n_slabs(size, p)
        step = size // ns
        pre, split_off, concat_off = _UNFOLD_GEOM[fold]
        axes = self._axes(fold)

        outs = []
        prev = None
        for i in range(ns):
            sl = [lax.slice_in_dim(a, i * step, (i + 1) * step, axis=axis)
                  for a in arrs]
            br, bi = pre(sl[0]), pre(sl[1])
            d = br.ndim
            ex, done = self._rdma(
                (br, bi), axes, split_axis=d + split_off,
                concat_axis=d + concat_off, payload=prev, inverse=True)
            if done is not None:
                outs.append(done)
            prev = (ex[0], ex[1])
        outs.append(compute(*prev))
        return tuple(jnp.concatenate([o[k] for o in outs], axis=axis)
                     for k in range(len(outs[0])))


# ---------------------------------------------------------------------------
# bidirectional ring: both torus directions per round (two NICs, Fig. 5.9)
# ---------------------------------------------------------------------------

@_register
class BidiRingEngine(PallasRingEngine):
    """The ring driven over *both* torus directions at once (paper Fig. 5.9:
    every node owns a +u and a −u link, and the NIC can stream on both).

    Each fold's blocks split into a clockwise and a counter-clockwise
    stream — round r ships block me+r one way and block me−r the other, on
    opposite links — so the exchange completes in ``ceil((P−1)/2)`` rounds
    instead of the unidirectional rings' P−1 (``wire_rounds``; asserted via
    the ``exchange_rounds`` counter). P=2 degenerates to the plain ring
    (both directions name the same neighbor, one round); odd P splits
    (P−1)/2 blocks per direction every round; even P sends the shared
    farthest block clockwise only on the last round.

    Transports: on TPU the exchange is the bidirectional async-RDMA kernel
    (``kernels.ring_rdma.ring_exchange_bidi_rdma`` — double-buffered
    ``make_async_remote_copy`` sends to both neighbors per round with
    per-direction semaphores, in-kernel butterflies on fusable payloads
    like ``pallas_ring``); off-TPU it is the two counter-rotating
    ``ppermute`` streams of ``transpose.ring_exchange_bidi``, keeping the
    ``overlap_ring`` compute-overlap schedule with half the rounds and
    staying bit-exact vs ``torus``.
    """

    name = "bidi_ring"
    mode = "torus"
    fabric = "torus"

    wire_rounds = staticmethod(tr.bidi_rounds)

    def _transport(self, arrs, axes, **kw):
        from repro.kernels import ring_rdma
        return ring_rdma.ring_exchange_bidi_rdma(arrs, axes, **kw)


ENGINE_NAMES = tuple(ENGINES)
# ("switched", "torus", "overlap_ring", "pallas_ring", "bidi_ring")
