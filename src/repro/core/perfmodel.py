"""The paper's analytic performance/resource model (Chapters 3–5).

Every closed form from the thesis, validated against the thesis' own tables
in ``tests/test_perfmodel.py`` and printed by the benchmark suite:

* Engine timing  — Eq. 5.2 (l_but), Eq. 5.3 (l_FFT), Eq. 3.11 (T_FFT),
  Eq. 3.12 (B_FFT), Eq. 5.4 (GFLOPS)          → Tables 5.1–5.6
* Architecture comparison (sequential / pipelined / parallel) — Eq. 4.4–4.17
  → Tables 4.1, 4.2
* Network required bandwidth — Eq. 5.5 (switched), Eq. 5.6 (torus)
  → Figs 5.11, 5.12
* Global 3D-FFT projection — Table 5.7 (with its 8 GiB HBM feasibility mask)

Conventions: ``s`` = 8 bytes (one double); complex points are ``2s``;
GB/s figures are binary (GiB/s) to match the thesis tables; GFLOPS decimal.
"""

from __future__ import annotations

import dataclasses
import math

S_BYTES = 8               # double precision word (paper §3.2.5)
GIB = 2.0 ** 30
HBM_LIMIT_BYTES = 8 * GIB  # VU37P in-package HBM (paper §5.4)


# ---------------------------------------------------------------------------
# 1D engine model (paper §3.4, §5.1–5.3)
# ---------------------------------------------------------------------------

def l_butterfly(l_op: int) -> int:
    """Eq. 5.2 with l_A = l_B = l_C = l_op: l_but = 3·l_op + 4."""
    return 3 * l_op + 4


def l_fft_cycles(n: int, l_op: int, r: int = 1) -> int:
    """Eq. 5.3 generalized to R rows: the shuffle shift registers shrink by
    R (on-chip reorder memory ∝ N − 2R, §5.2), so
    l_FFT = (l_but + 1)·log2 N + N/(2R) − 1.

    Matches the latency columns of Tables 5.2 (R=1), 5.4 (R=2), 5.6 (R=4).
    """
    s = int(math.log2(n))
    return (l_butterfly(l_op) + 1) * s + n // (2 * r) - 1


def engine_latency_cycles(n: int, l_op: int, r: int = 1) -> int:
    """The 'latency cycles' column of Tables 5.2/5.4/5.6 (= l_FFT + 1; the
    thesis counts one extra output-registration cycle in the tables)."""
    return l_fft_cycles(n, l_op, r) + 1


def t_fft_seconds(n: int, r: int, l_op: int, f_hz: float) -> float:
    """Eq. 3.11: T_FFT = l_FFT + t_clk·N/(2R)."""
    return (l_fft_cycles(n, l_op, r) + n / (2 * r)) / f_hz


def b_fft_bytes_per_s(r: int, f_hz: float, s: int = S_BYTES) -> float:
    """Eq. 3.12: B_FFT = 4·s·R/t_clk — two complex words in+out per cycle/row."""
    return 4.0 * s * r * f_hz


def engine_gflops(n: int, r: int, f_hz: float) -> float:
    """Eq. 5.4: 10 FLOPs per butterfly × R rows × log2 N stages per cycle."""
    return 10.0 * r * math.log2(n) * f_hz / 1e9


@dataclasses.dataclass(frozen=True)
class EnginePoint:
    n: int
    r: int
    l_op: int
    f_mhz: float

    @property
    def latency_cycles(self) -> int:
        return engine_latency_cycles(self.n, self.l_op, self.r)

    @property
    def l_fft_us(self) -> float:
        return self.latency_cycles / self.f_mhz  # cycles / MHz = µs

    @property
    def t_fft_us(self) -> float:
        return t_fft_seconds(self.n, self.r, self.l_op, self.f_mhz * 1e6) * 1e6

    @property
    def b_fft_gib_s(self) -> float:
        return b_fft_bytes_per_s(self.r, self.f_mhz * 1e6) / GIB

    @property
    def gflops(self) -> float:
        return engine_gflops(self.n, self.r, self.f_mhz * 1e6)


# ---------------------------------------------------------------------------
# 3D architecture comparison (paper Ch. 4)
# ---------------------------------------------------------------------------

def t_tot_sequential(n: int, p: int, r: int, q: int, f_hz: float,
                     mu: int = 1, exact: bool = False,
                     l_dma: int = 0, l_comm: int = 0, l_op: int = 9) -> float:
    """Eq. 4.4 (exact) / Eq. 4.14 (asymptotic): sequential architecture."""
    if exact:
        cyc = (4 * l_dma + 3 * l_fft_cycles(n, l_op, r) + 3 * l_comm
               + n**3 / (2 * p * r * q)
               + 2 * (n**3 + 2 * n**2) / (4 * p * r * q))
        return mu * cyc / f_hz
    return 2.0 * mu * n**3 / (2 * p * r * q) / f_hz


def t_tot_pipelined(n: int, p: int, r: int, k: int, f_hz: float,
                    mu: int = 1) -> float:
    """Eq. 4.15: pipelined-streaming with doubled X engines (Q = 4k)."""
    return (mu + 1.0) * n**3 / (4 * p * r * k) / f_hz


def t_tot_parallel(n: int, p: int, r: int, f_hz: float, mu: int = 1) -> float:
    """Parallel vector processing: same time as sequential μ=1 (Table 4.1)."""
    return 2.0 * n**3 / (2 * p * r) / f_hz


def table_4_1(mu: int):
    """Architectural comparison at k=1, in the paper's normalized units
    (T_tot in t_clk·N³/2P ; B in 4s/t_clk ; M in sN³/P)."""
    return {
        "sequential": dict(T_tot=2 * mu, B=1, M=2, N_L_DMA=2, N_H_DMA=1, Q=1, N_NET=1),
        "pipelined": dict(T_tot=(mu + 1) / 2, B=1, M=2, N_L_DMA=4, N_H_DMA=2, Q=4, N_NET=2),
        "parallel": dict(T_tot=2, B=mu, M=2 * mu, N_L_DMA=2 * mu, N_H_DMA=mu, Q=mu, N_NET=mu),
    }


def table_4_2(mu: int):
    """Fixed Q=4 comparison (normalized units as above)."""
    return {
        "sequential": dict(T_tot=mu / 2.0, B=4, M=2),
        "pipelined": dict(T_tot=(mu + 1) / 2.0, B=1, M=2),
    }


def m_tot_sequential_bytes(n: int, p: int, s: int = S_BYTES) -> float:
    """Eq. 4.8: M = 2·V' = 2s(N³+2N²)/P."""
    return 2.0 * s * (n**3 + 2 * n**2) / p


def m_tot_pipelined_bytes(n: int, p: int, pu: int, s: int = S_BYTES) -> float:
    """Eq. 4.17 (streaming pipelined): 2s(N³+2N²)/P + 2sN²/Pu."""
    return 2.0 * s * (n**3 + 2 * n**2) / p + 2.0 * s * n**2 / pu


# ---------------------------------------------------------------------------
# Network required bandwidth (paper §5.5)
# ---------------------------------------------------------------------------

def b_net_switched(p: int, r: int, f_hz: float, s: int = S_BYTES) -> float:
    """Eq. 5.5: B = (4sR/t_clk)·(√P−1)/√P  [bytes/s]."""
    sq = math.sqrt(p)
    return b_fft_bytes_per_s(r, f_hz, s) * (sq - 1.0) / sq


def b_net_torus(p: int, r: int, f_hz: float, s: int = S_BYTES) -> float:
    """Eq. 5.6: B = (2sR/t_clk)·(√P−1)  [bytes/s] — multi-hop penalty."""
    return 2.0 * s * r * f_hz * (math.sqrt(p) - 1.0)


def max_scalable_p(r: int, f_hz: float, link_bits_per_s: float,
                   topology: str = "switched", sq_max: int = 1024) -> int:
    """Largest square grid P = q² whose required bandwidth fits the link."""
    fn = b_net_switched if topology == "switched" else b_net_torus
    best = 1
    for q in range(1, sq_max + 1):
        if fn(q * q, r, f_hz) * 8.0 <= link_bits_per_s:
            best = q * q
        else:
            break
    return best


# ---------------------------------------------------------------------------
# Global projection (paper §5.6, Table 5.7)
# ---------------------------------------------------------------------------

def global_fft_time(n: int, p: int, mu: int = 1, r: int = 4, k: int = 1,
                    f_hz: float = 180e6) -> float:
    """Expected 3D-FFT time as tabulated in Table 5.7.

    Note: the table's entries follow T = (μ+1)·t_clk·N³/(2PRk) — a factor 2
    above Eq. 4.15; we reproduce the table as printed (validated in tests)
    and keep Eq. 4.15 separately in :func:`t_tot_pipelined`.
    """
    return (mu + 1.0) * n**3 / (2.0 * p * r * k) / f_hz


def fits_hbm(n: int, p: int, s: int = S_BYTES,
             limit_bytes: float = HBM_LIMIT_BYTES) -> bool:
    """Table 5.7 feasibility mask: M ≈ 2sN³/P ≤ 8 GiB (O(N²) terms dropped,
    matching the thesis' empty-cell pattern exactly)."""
    return 2.0 * s * n**3 / p <= limit_bytes


def table_5_7(mu: int = 1, r: int = 4, k: int = 1, f_hz: float = 180e6):
    """Reproduce Table 5.7: rows N, cols P; None = exceeds local HBM."""
    rows = {}
    for n in (512, 1024, 2048, 4096, 8192):
        row = {}
        for p in (1, 4, 16, 64, 256, 1024):
            row[p] = global_fft_time(n, p, mu, r, k, f_hz) if fits_hbm(n, p) else None
        rows[n] = row
    return rows


# ---------------------------------------------------------------------------
# Autotuner candidate scoring (paper Eq. 3.3–3.4, §5.5, §5.6)
# ---------------------------------------------------------------------------

#: Relative compute-cost weight of each software FFT engine in this repo,
#: used only to *rank* autotuner candidates before real timing (the measured
#: sweep decides; these just keep obviously-dominated configs out of it).
#: ``jnp`` is XLA's native FFT; ``mxu`` the four-step matmul engine (~8.5×
#: the arithmetic, on denser units); ``ref`` the pure-jnp radix-2 oracle;
#: ``pallas`` the radix-2 kernel, interpreted off-TPU.
#: These are the *fallback priors*: :func:`backend_compute_weight` prefers
#: the measured values of an active ``repro.tuning.calibrate`` run.
BACKEND_COMPUTE_WEIGHT = {"jnp": 1.0, "mxu": 3.0, "ref": 10.0, "pallas": 30.0}


#: Which §5.5 fabric each TransposeEngine's traffic is priced on. Owned by
#: the jax-free ``core.engine_spec`` (shared with ``core.comm`` and
#: ``core.topology``); re-exported here for backward compatibility.
from repro.core.engine_spec import ENGINE_FABRIC, EngineSpec  # noqa: E402,F401


#: Exposed per-message overhead (seconds, nominal FPGA) each engine pays on
#: its critical path — the §4.2 DMA/NIC setup latency (l_comm) wearing the
#: engine's clothes: the switched fabric dispatches one all-to-all per slab;
#: the XLA rings dispatch one ppermute per ring round; the Pallas RDMA rings
#: (``pallas_ring`` and the two-NIC ``bidi_ring``) post their sends from
#: inside the kernel (a NIC doorbell, no per-round XLA dispatch), which is
#: the whole point of the paper's NIC offload.
#: These are the *fallback priors*: :func:`message_overhead_s` prefers the
#: measured values of an active ``repro.tuning.calibrate`` run.
ENGINE_MESSAGE_OVERHEAD_S = {
    "switched": 2e-6,
    "torus": 2e-6,
    "overlap_ring": 2e-6,
    "pallas_ring": 0.5e-6,
    "bidi_ring": 0.5e-6,
}


#: Nominal per-link wire bandwidth (bytes/s): the thesis' 200 Gbit/s
#: reference link at 25 GB/s. This is the *fallback prior*:
#: :func:`link_bytes_per_s` prefers the wire-bandwidth slope measured by an
#: active ``repro.tuning.calibrate`` run (the two-size extrapolation that
#: yields the per-message intercept also yields bytes-per-second).
LINK_BYTES_PER_S = 25e9


# ---------------------------------------------------------------------------
# measured calibration overlay (repro.tuning.calibrate)
# ---------------------------------------------------------------------------

_CALIBRATION: dict | None = None
_CALIBRATION_LOADED = False


def set_calibration(doc: dict | None) -> None:
    """Install a calibration document for this process (``None`` pins the
    built-in priors). Overrides the lazily-loaded on-disk calibration until
    :func:`reset_calibration`."""
    global _CALIBRATION, _CALIBRATION_LOADED
    _CALIBRATION = dict(doc) if doc else None
    _CALIBRATION_LOADED = True


def reset_calibration() -> None:
    """Forget any installed calibration; the next query lazily re-loads the
    on-disk document (``$REPRO_CALIBRATION`` / the default cache path)."""
    global _CALIBRATION, _CALIBRATION_LOADED
    _CALIBRATION = None
    _CALIBRATION_LOADED = False


def active_calibration() -> dict | None:
    """The calibration document the model currently consults, if any.

    Lazily loads the persisted ``calibration.json`` on first use (only a
    document whose substrate fingerprint matches this process is accepted —
    see ``repro.tuning.calibrate``); :func:`set_calibration` short-circuits
    the load. Never raises: a missing/invalid/foreign file means priors.
    """
    global _CALIBRATION, _CALIBRATION_LOADED
    if not _CALIBRATION_LOADED:
        _CALIBRATION_LOADED = True
        try:
            from repro.tuning.calibrate import load_active_calibration
            _CALIBRATION = load_active_calibration()
        except Exception:
            _CALIBRATION = None
    return _CALIBRATION


def message_overhead_s(engine: str) -> float:
    """Exposed per-message cost of ``engine`` on this substrate: the
    measured value of the active calibration when one exists, else the
    ``ENGINE_MESSAGE_OVERHEAD_S`` prior."""
    if engine not in ENGINE_MESSAGE_OVERHEAD_S:
        raise ValueError(f"unknown comm engine {engine!r}; "
                         f"have {sorted(ENGINE_MESSAGE_OVERHEAD_S)}")
    cal = active_calibration() or {}
    got = (cal.get("engine_message_overhead_s") or {}).get(engine)
    if isinstance(got, (int, float)) and got > 0:
        return float(got)
    return ENGINE_MESSAGE_OVERHEAD_S[engine]


def backend_compute_weight(backend: str) -> float:
    """Relative compute cost of ``backend``: measured (active calibration)
    when available, else the ``BACKEND_COMPUTE_WEIGHT`` prior (1.0 for
    unknown backends, matching the old ``.get`` default)."""
    cal = active_calibration() or {}
    got = (cal.get("backend_compute_weight") or {}).get(backend)
    if isinstance(got, (int, float)) and got > 0:
        return float(got)
    return BACKEND_COMPUTE_WEIGHT.get(backend, 1.0)


def link_bytes_per_s() -> float:
    """Effective per-link wire bandwidth on this substrate: the slope the
    active calibration measured (``repro.tuning.calibrate`` extrapolates
    two fold sizes; the slope is bytes moved per wall second), else the
    ``LINK_BYTES_PER_S`` prior."""
    cal = active_calibration() or {}
    got = cal.get("link_bytes_per_s")
    if isinstance(got, (int, float)) and got > 0:
        return float(got)
    return LINK_BYTES_PER_S


def _resolve_link_rate(value: float | None) -> float:
    """An explicit caller override wins; ``None`` asks the calibration."""
    return float(value) if value is not None else link_bytes_per_s()


def bidi_round_ratio(q: int) -> float:
    """Wire-time ratio of the bidirectional ring vs the unidirectional one
    over a ``q``-rank dimension: ``ceil((q−1)/2) / (q−1)`` exchange rounds
    (both directions carry blocks concurrently; 1.0 at q ≤ 2 where both
    directions name the same neighbor)."""
    if q <= 2:
        return 1.0
    return (q // 2) / (q - 1)


def fold_messages(q, fabric: str, engine: str = "") -> int:
    """Exposed message dispatches one rank pays for one fold over a
    ``q``-rank dimension: one tiled all-to-all on the switched fabric, q−1
    ring rounds on the torus (Fig. 5.9/5.10) — except the bidirectional
    ring, whose two per-round sends are posted concurrently on opposite
    links, leaving ``ceil((q−1)/2)`` round dispatches on the critical path.
    Zero when the fold never communicates.

    ``q`` may be a tuple of per-mesh-axis sizes (a grid dimension spanning
    several mesh axes, e.g. ``(Pu₀, Pu₁)``): the ring engines stage one
    ring per axis, so the torus fabrics pay Σᵢ ``fold_messages(qᵢ)`` round
    dispatches, while the switched fabric still dispatches one all-to-all
    over the whole product group."""
    if isinstance(q, (tuple, list)):
        sizes = [int(x) for x in q if int(x) > 1]
        if not sizes:
            return 0
        if fabric == "switched":
            return 1
        return sum(fold_messages(x, fabric, engine) for x in sizes)
    if q <= 1:
        return 0
    if fabric == "switched":
        return 1
    if engine == "bidi_ring":
        return q // 2
    return q - 1


def _dim_sizes(q: int, q_axes) -> tuple[int, ...]:
    """Normalize a grid dimension to its per-mesh-axis factorization.

    ``q_axes=None`` means the flat single-axis view ``(q,)``; an explicit
    factorization must multiply out to ``q``.
    """
    if q_axes is None:
        return (max(int(q), 1),)
    sizes = tuple(int(x) for x in q_axes)
    if math.prod(sizes) != max(int(q), 1):
        raise ValueError(f"per-axis sizes {sizes} do not factor P={q}")
    return sizes


def _fold_wire_seconds(v_prime: float, sizes: tuple[int, ...], *,
                       fabric: str, link_bytes_per_s: float,
                       bidi: bool = False) -> float:
    """Wire seconds of one fold moving V′ bytes (Eq. 3.4) over a — possibly
    multi-mesh-axis — grid dimension: the Eq. 5.5/5.6 fabric penalty per
    axis, one all-to-all over the product group on the switched fabric,
    one staged ring per axis on the torus fabrics."""
    def axis_seconds(q: int) -> float:
        t = v_prime * (q - 1) / q / link_bytes_per_s
        if fabric == "torus":
            t *= max(1.0, q / 2.0)  # Eq. 5.6 vs 5.5 required-bandwidth ratio
        if bidi:
            t *= bidi_round_ratio(q)  # both directions stream concurrently
        return t

    sizes = tuple(q for q in sizes if q > 1)
    if not sizes:
        return 0.0
    if fabric == "switched":
        # one all-to-all over the product group regardless of staging
        return axis_seconds(math.prod(sizes))
    return sum(axis_seconds(q) for q in sizes)


def estimate_fold_seconds(n, pu: int, pv: int, dim_sizes, *,
                          comm_engine: str = "switched", mu: int = 1,
                          link_bytes_per_s: float | None = None,
                          s: int = S_BYTES) -> float:
    """Wire seconds of one fold over one grid dimension (the per-phase
    slice of :func:`estimate_plan_seconds`'s network term): V′ of Eq. 3.4
    across ``dim_sizes`` — the per-mesh-axis factorization of the folding
    dimension (``PencilGrid.u_sizes``/``v_sizes``) — on ``comm_engine``'s
    fabric with the Eq. 5.5/5.6 penalty. Used by the observability layer
    to annotate each fold span with its own model prediction."""
    if comm_engine not in ENGINE_FABRIC:
        raise ValueError(f"unknown comm engine {comm_engine!r}; "
                         f"have {sorted(ENGINE_FABRIC)}")
    nx, ny, nz = (n, n, n) if isinstance(n, int) else tuple(n)
    p = max(pu, 1) * max(pv, 1)
    v_prime = max(mu, 1) * s * (nx * ny * nz + 2 * ny * nz) / p  # Eq. 3.4
    return _fold_wire_seconds(
        v_prime, tuple(int(x) for x in dim_sizes),
        fabric=ENGINE_FABRIC[comm_engine],
        link_bytes_per_s=_resolve_link_rate(link_bytes_per_s),
        bidi=comm_engine == "bidi_ring")


def _comp_net_seconds(n, pu: int, pv: int, *, fabric: str, backend: str,
                      schedule: str, mu: int, r2c_packed: bool, r: int,
                      f_hz: float, link_bytes_per_s: float,
                      s: int, bidi: bool = False,
                      pu_axes=None, pv_axes=None) -> tuple[float, float]:
    """(T_comp, T_net) of one transform: Eq. 4.14/4.15 compute and the
    per-fold V′ traffic of Eq. 3.4 with the Eq. 5.5/5.6 fabric penalty.
    ``bidi`` scales each fold's wire time by the bidirectional ring's
    round ratio (both torus directions carry blocks concurrently).
    ``pu_axes``/``pv_axes`` give the per-mesh-axis factorization of each
    grid dimension: on the torus fabrics a fold over several axes runs one
    staged ring per axis, so its wire time is Σᵢ over single-axis rings
    (each with that axis' own q/2 multi-hop penalty) instead of one flat
    ring over the product — the multi-axis schedule is strictly cheaper.
    Shared by :func:`estimate_plan_seconds` and :func:`optimal_chunks`."""
    nx, ny, nz = (n, n, n) if isinstance(n, int) else tuple(n)
    p = max(pu, 1) * max(pv, 1)
    mu = max(mu, 1)
    vol = nx * ny * nz
    if schedule == "pipelined":
        # Eq. 4.15 with k=1: the k in the paper is *hardware engine
        # replication* (doubled X engines); our software slab count adds no
        # compute throughput — chunks only enter via the overlap/fill terms.
        t_comp = (mu + 1.0) * vol / (4.0 * p * r) / f_hz
    else:
        t_comp = 2.0 * mu * vol / (2.0 * p * r) / f_hz          # Eq. 4.14
    t_comp *= backend_compute_weight(backend)
    if r2c_packed:
        t_comp *= 5.0 / 6.0  # X phase runs an N/2-point engine (1 of 3 phases)

    v_prime = mu * s * (vol + 2 * ny * nz) / p                  # Eq. 3.4

    def fold_seconds(sizes: tuple[int, ...]) -> float:
        return _fold_wire_seconds(v_prime, sizes, fabric=fabric,
                                  link_bytes_per_s=link_bytes_per_s,
                                  bidi=bidi)

    return t_comp, (fold_seconds(_dim_sizes(pu, pu_axes))
                    + fold_seconds(_dim_sizes(pv, pv_axes)))


def estimate_plan_seconds(n, pu: int, pv: int, *, backend: str = "jnp",
                          schedule: str = "sequential", chunks: int = 1,
                          net: str = "switched", comm_engine: str = "",
                          mu: int = 1,
                          r2c_packed: bool = False, r: int = 4,
                          f_hz: float = 180e6,
                          link_bytes_per_s: float | None = None,
                          s: int = S_BYTES, spec: EngineSpec | None = None,
                          pu_axes=None, pv_axes=None) -> float:
    """Analytic time estimate for one ``FFT3DPlan`` configuration.

    This is the paper's model wearing an autotuner hat: compute follows the
    task-organization forms of Ch. 4 (Eq. 4.14 sequential / Eq. 4.15
    pipelined, as tabulated in §5.6), the per-fold traffic is V′ of Eq. 3.4,
    and the torus penalty is the Eq. 5.5/5.6 required-bandwidth ratio
    (B_torus/B_switched = √P/2 → ×q/2 time per fold over a q-rank dimension).

    ``comm_engine`` makes the estimate overlap- and overhead-aware: serial
    engines (``switched``/``torus``) pay compute + communication
    back-to-back per phase (only the ``pipelined`` schedule's slab overlap
    helps them) plus one exposed message dispatch per slab exchange; the
    overlapped rings interleave butterflies with every ring round, so the
    longer of the two streams dominates — ``max(T_comp, T_net)`` plus a
    pipeline-fill term that shrinks with the slab count and the steady-state
    ring-round dispatches. ``pallas_ring`` is the same timeline with its
    sends posted by the kernel itself: half the exposed fill (double
    buffering) and the NIC-doorbell message cost of
    :func:`message_overhead_s`. ``bidi_ring`` additionally drives both
    torus directions per round (Fig. 5.9), scaling each fold's wire time
    and round dispatches by ``ceil((q−1)/2)/(q−1)``. Message overheads and
    backend weights come from the active measured calibration when one
    exists (``repro.tuning.calibrate``), else the built-in priors.
    ``spec`` supplies the engine configuration as one
    :class:`~repro.core.engine_spec.EngineSpec`, overriding the individual
    ``backend/schedule/chunks/comm_engine/r2c_packed`` arguments.
    ``pu_axes``/``pv_axes`` give the per-mesh-axis factorization of the
    grid dimensions (``PencilGrid.u_sizes``/``v_sizes``): the ring engines
    then pay per-axis rounds — Σᵢ(qᵢ−1) instead of P−1 — with each staged
    ring priced at its own axis' multi-hop penalty.
    ``link_bytes_per_s=None`` (the default) uses the measured wire
    bandwidth of the active calibration via :func:`link_bytes_per_s`, else
    the nominal prior. Absolute numbers are nominal-FPGA seconds; the
    autotuner only uses the *ordering* to prune the sweep.
    """
    link_bytes_per_s = _resolve_link_rate(link_bytes_per_s)
    if spec is not None:
        backend, schedule = spec.backend, spec.schedule
        chunks, comm_engine = spec.chunks, spec.engine
        r2c_packed = spec.r2c_packed
    engine = comm_engine or net
    if engine not in ENGINE_FABRIC:
        raise ValueError(f"unknown comm engine {engine!r}; "
                         f"have {sorted(ENGINE_FABRIC)}")
    fabric = ENGINE_FABRIC[engine]
    k = max(chunks, 1)
    t_comp, t_net = _comp_net_seconds(
        n, pu, pv, fabric=fabric, backend=backend, schedule=schedule, mu=mu,
        r2c_packed=r2c_packed, r=r, f_hz=f_hz,
        link_bytes_per_s=link_bytes_per_s, s=s, bidi=engine == "bidi_ring",
        pu_axes=pu_axes, pv_axes=pv_axes)
    t_msg = message_overhead_s(engine)
    msgs = (fold_messages(_dim_sizes(pu, pu_axes), fabric, engine)
            + fold_messages(_dim_sizes(pv, pv_axes), fabric, engine))
    if engine in ("overlap_ring", "pallas_ring", "bidi_ring") \
            and (pu > 1 or pv > 1):
        # block-granular overlap: every ring round's latency hides under
        # another block's butterflies (Fig. 4.3), so the longer stream
        # dominates and only a pipeline-fill fraction of the shorter one
        # remains exposed. The engine cuts each fold into one slab per ring
        # rank (or ``chunks``), so the fill shrinks with the total slab
        # count — and the estimate can never exceed the serial sum, since
        # overlapping identical work cannot be slower. Message dispatches
        # pipeline with the compute too; only the steady-state round count
        # stays on the critical path. The Pallas RDMA rings' explicit
        # double buffering halves the exposed fill. On a 1×1 grid nothing
        # communicates and the engine degenerates to the serial forms below.
        slabs = max(max(pu, 1) + max(pv, 1), k, 2)
        fill = min(t_comp, t_net) / slabs
        if engine in ("pallas_ring", "bidi_ring"):
            fill /= 2.0
        return max(t_comp, t_net) + fill + msgs * t_msg
    overhead = k * msgs * t_msg  # one exposed dispatch per slab exchange
    if schedule == "pipelined":
        # slab i+1's butterflies run under slab i's fold (Fig. 4.3): the
        # longer of the two streams dominates, plus a 1/k pipeline-fill term.
        return max(t_comp, t_net) + (t_comp + t_net) / k + overhead
    return t_comp + t_net + overhead


def estimate_roundtrip_seconds(n, pu: int, pv: int, *,
                               fused: bool | None = None,
                               kernel_weight: float = 1.0,
                               backend: str = "jnp",
                               schedule: str = "sequential", chunks: int = 1,
                               net: str = "switched", comm_engine: str = "",
                               mu: int = 1, r2c_packed: bool = False,
                               r: int = 4, f_hz: float = 180e6,
                               link_bytes_per_s: float | None = None,
                               s: int = S_BYTES,
                               spec: EngineSpec | None = None,
                               pu_axes=None, pv_axes=None) -> float:
    """Analytic time of one diagonal spectral roundtrip — forward 3D FFT,
    pointwise k-space multiply, inverse 3D FFT — for one plan config.

    Composed (``fused=False``) prices the three phases back to back: two
    full transforms (:func:`estimate_plan_seconds`) plus one exposed
    kernel sweep over the local spectrum, ``kernel_weight`` engine passes
    at R points per cycle (1.0 for a plain complex multiply; heavier
    per-point operators scale it up). The fused executor
    (``fused=True``, or ``spec.fused_roundtrip``) threads kx-slabs through
    Y↔Z fold → Z-FFT → kernel → inverse Z-FFT → Y↔Z unfold with no
    full-volume barrier, so slab k's kernel sweep runs under slab k+1's
    fold and slab k−1's unfold — the kernel time hides up to the
    roundtrip's Y↔Z wire budget (one fold plus one unfold):

        fused = composed − min(T_kernel, 2·T_yz_wire)

    With no Y↔Z communication (``pv == 1``) nothing hides and
    fused == composed; the estimate therefore never predicts the fused
    schedule above the composed one. All other knobs match
    :func:`estimate_plan_seconds`.
    """
    if spec is not None:
        if fused is None:
            fused = spec.fused_roundtrip
        backend, schedule = spec.backend, spec.schedule
        chunks, comm_engine = spec.chunks, spec.engine
        r2c_packed = spec.r2c_packed
    engine = comm_engine or net
    if engine not in ENGINE_FABRIC:
        raise ValueError(f"unknown comm engine {engine!r}; "
                         f"have {sorted(ENGINE_FABRIC)}")
    link_bytes_per_s = _resolve_link_rate(link_bytes_per_s)
    one = estimate_plan_seconds(
        n, pu, pv, backend=backend, schedule=schedule, chunks=chunks,
        comm_engine=engine, mu=mu, r2c_packed=r2c_packed, r=r, f_hz=f_hz,
        link_bytes_per_s=link_bytes_per_s, s=s,
        pu_axes=pu_axes, pv_axes=pv_axes)
    nx, ny, nz = (n, n, n) if isinstance(n, int) else tuple(n)
    p = max(pu, 1) * max(pv, 1)
    mu = max(mu, 1)
    t_kernel = (max(kernel_weight, 0.0) * backend_compute_weight(backend)
                * mu * nx * ny * nz / (2.0 * p * r) / f_hz)
    composed = 2.0 * one + t_kernel
    if not fused:
        return composed
    fabric = ENGINE_FABRIC[engine]
    v_prime = mu * s * (nx * ny * nz + 2 * ny * nz) / p         # Eq. 3.4
    t_yz = 2.0 * _fold_wire_seconds(
        v_prime, _dim_sizes(pv, pv_axes), fabric=fabric,
        link_bytes_per_s=link_bytes_per_s, bidi=engine == "bidi_ring")
    return composed - min(t_kernel, t_yz)


# ---------------------------------------------------------------------------
# Engine-aware chunk-size model (paper Fig. 4.3's slab-count knob)
# ---------------------------------------------------------------------------

MAX_MODEL_CHUNKS = 32          # finest slab granularity the model proposes
_FALLBACK_CHUNKS = (2, 4, 8)   # engine-blind legacy choices (no-comm grids)


def optimal_chunks(n, pu: int, pv: int, *, comm_engine: str = "",
                   backend: str = "jnp", schedule: str = "pipelined",
                   mu: int = 1, r2c_packed: bool = False, r: int = 4,
                   f_hz: float = 180e6,
                   link_bytes_per_s: float | None = None,
                   s: int = S_BYTES, spec: EngineSpec | None = None,
                   pu_axes=None, pv_axes=None) -> int:
    """Model-optimal slab count for one engine on one problem.

    Chunking trades the pipeline-fill exposure (the ``(T_comp+T_net)/k``
    term of the Fig. 4.3 timeline — one slab's fold latency stays
    unhidden) against per-message overhead (each extra slab re-dispatches
    the fold's messages: one all-to-all on the switched fabric, q−1 ring
    rounds on the torus). Minimizing

        T(k) ≈ (T_comp + T_net)/k + k · m · t_msg

    gives ``k* = sqrt((T_comp + T_net) / (m · t_msg))``, snapped to the
    nearest power of two in ``[1, MAX_MODEL_CHUNKS]``. The model is
    engine-aware through both the per-message cost ``t_msg``
    (:func:`message_overhead_s` — measured by ``repro.tuning.calibrate``
    when a calibration is active, else the prior; the Pallas RDMA rings'
    cheap NIC-doorbell sends support finer slabs than the XLA rings) and
    the per-slab message count ``m`` (``fold_messages`` on the engine's
    fabric — halved round dispatches for ``bidi_ring``, summed per mesh
    axis when ``pu_axes``/``pv_axes`` factor a grid dimension over several).
    ``spec`` supplies ``comm_engine``/``backend``/``r2c_packed`` in one
    object (its ``schedule`` is ignored — the answer is by definition for
    the pipelined schedule). Returns 1 when no fold communicates
    (nothing to overlap).
    """
    link_bytes_per_s = _resolve_link_rate(link_bytes_per_s)
    if spec is not None:
        # schedule stays "pipelined": the question this model answers is what
        # slab count the pipelined schedule should run at for spec's engine.
        comm_engine, backend = spec.engine, spec.backend
        r2c_packed = spec.r2c_packed
    if comm_engine not in ENGINE_FABRIC:
        raise ValueError(f"unknown comm engine {comm_engine!r}; "
                         f"have {sorted(ENGINE_FABRIC)}")
    fabric = ENGINE_FABRIC[comm_engine]
    msgs = (fold_messages(_dim_sizes(pu, pu_axes), fabric, comm_engine)
            + fold_messages(_dim_sizes(pv, pv_axes), fabric, comm_engine))
    t_msg = message_overhead_s(comm_engine)
    if msgs == 0 or t_msg <= 0:
        return 1
    t_comp, t_net = _comp_net_seconds(
        n, pu, pv, fabric=fabric, backend=backend, schedule=schedule, mu=mu,
        r2c_packed=r2c_packed, r=r, f_hz=f_hz,
        link_bytes_per_s=link_bytes_per_s, s=s, bidi=comm_engine == "bidi_ring",
        pu_axes=pu_axes, pv_axes=pv_axes)
    k_star = math.sqrt((t_comp + t_net) / (msgs * t_msg))
    if k_star <= 1.0:
        return 1
    snapped = 2 ** round(math.log2(k_star))
    return int(min(max(snapped, 1), MAX_MODEL_CHUNKS))


def chunk_candidates(n, pu: int, pv: int, comm_engine: str,
                     **kwargs) -> tuple[int, ...]:
    """Pipelined slab counts worth timing for this engine and problem:
    the model optimum and its power-of-two neighbors (the measured sweep
    decides — the model only keeps obviously-dominated counts out of it).
    Falls back to the engine-blind legacy choices when no fold
    communicates, where the model has no signal to prune on."""
    opt = optimal_chunks(n, pu, pv, comm_engine=comm_engine, **kwargs)
    if opt <= 1 and fold_messages(max(pu, 1), ENGINE_FABRIC[comm_engine]) \
            + fold_messages(max(pv, 1), ENGINE_FABRIC[comm_engine]) == 0:
        return _FALLBACK_CHUNKS
    cands = {c for c in (opt // 2, opt, 2 * opt)
             if 2 <= c <= MAX_MODEL_CHUNKS}
    return tuple(sorted(cands)) or (2,)


# ---------------------------------------------------------------------------
# Required-RAM trend (paper Fig. 1.1)
# ---------------------------------------------------------------------------

def required_ram_per_node(n: int, p: int, s: int = S_BYTES) -> float:
    """Fig. 1.1: one complex double field = 2s·N³/P bytes per node."""
    return 2.0 * s * n**3 / p
