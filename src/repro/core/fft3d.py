"""Distributed 3D FFT over a 2D pencil decomposition — the paper's core.

Implements the *transpose method* (§3.2.4): local X FFT → X↔Y fold → local Y
FFT → Y↔Z fold → local Z FFT, with the task-organization models of Chapter 4:

* ``schedule="sequential"`` — each phase processes the whole local volume
  before the next starts (Fig. 4.2; the paper's case B — XLA still overlaps
  DMA-like copies, but FFT phases are serialized on the full volume).
* ``schedule="pipelined"`` — the volume is split into ``chunks`` slabs along
  an axis untouched by the upcoming fold, and each slab's FFT→fold chain is
  emitted independently (Fig. 4.3 / case C). XLA's latency-hiding scheduler
  can then run slab i+1's butterflies underneath slab i's all-to-all — the
  TPU rendition of the paper's deep pipeline across engines and network.
* ``vector_mode="parallel"|"streaming"`` — μ-component vector fields are
  processed either simultaneously (leading component axis, ~μ× live memory;
  §4.4.1) or as a per-dimension stream (unrolled loop, §4.4.2/Fig. 4.6).

Configuration rides one object: ``make_fft3d(mesh, n, spec=EngineSpec(...))``
picks the comm engine, compute backend, schedule/chunks and vector mode in a
single frozen dataclass (``core.engine_spec``).

Beyond the plain transform pair, :func:`spectral_roundtrip_local` executes a
whole *spectral roundtrip* — forward FFT, pointwise-diagonal k-space
multiply (:class:`DiagonalKernel`), inverse FFT — and, when the plan's
``fused_roundtrip`` knob is on, streams the Y↔Z phase pair through the
engine's ``run_roundtrip`` schedule: slab k's Z-FFT→multiply→inverse runs
under slab k+1's fold and slab k−1's unfold, with no full-volume barrier
between the forward and inverse transforms.

Communication: the plan walks the axis-labelled :class:`CommDAG` from
``core.decomposition`` — the ``xy`` step exchanges over the grid's ``u``
dimension, the ``yz`` step over ``v`` — and hands each step to a pluggable
**TransposeEngine** (``core.comm``): ``engine="switched"`` (single
all-to-all, Fig. 5.10), ``"torus"`` (ppermute ring, Fig. 5.9),
``"overlap_ring"`` (the ring with the 1D FFT fused between its rounds —
block-granular compute/communication overlap, the paper's task C/G ↔ engine
pipelining of Fig. 4.3), ``"pallas_ring"`` (the same schedule as a Pallas
async-RDMA kernel with explicit double-buffered neighbor DMA — the paper's
NIC offload; interpret mode off-TPU) or ``"bidi_ring"`` (two-NIC
bidirectional ring, ⌈(P−1)/2⌉ rounds). When a grid dimension spans several
mesh axes (``u_axes=("pod", "data")`` on a 3-axis mesh) every engine runs
the staged per-axis exchange — one ring per mesh axis — instead of one flat
ring over the product group; ``spec.fabric`` is the derived §5.5 fabric
("switched" | "torus") the chosen engine runs on.

Real-to-complex: the X phase uses the general complex engine on real input
and keeps N/2+1 bins (padded to a Pu-divisible length), exactly the paper's
choice (§3.2.5, §3.4: "we prefer a more general and flexible architecture").
``r2c_packed=True`` switches on the beyond-paper even/odd packed real FFT.

All ``*_local`` functions run inside ``shard_map``; ``make_fft3d`` builds the
jitted global-array entry points.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat, obs
from repro.core import comm, perfmodel as pm, precision
from repro.core.decomposition import CommDAG, PencilGrid, fft3d_dag
from repro.core.engine_spec import EngineSpec
from repro.kernels import ops as kops

Schedule = Literal["sequential", "pipelined"]
VectorMode = Literal["parallel", "streaming"]


@dataclasses.dataclass(frozen=True)
class FFT3DPlan:
    n: tuple[int, int, int]
    grid: PencilGrid
    real: bool = False
    backend: str = "jnp"             # "pallas" | "ref" | "jnp"
    schedule: Schedule = "sequential"
    chunks: int = 1                  # pipelined slab count (1 = sequential)
    net: str = "switched"            # fabric: "switched" | "torus" (derived)
    r2c_packed: bool = False         # beyond-paper packed real FFT
    comm_engine: str = ""            # "" -> engine named by ``net``
    dtype: str = ""                  # "" -> caller-supplied arrays decide
    fused_roundtrip: bool = False    # stream diagonal spectral roundtrips

    def __post_init__(self):
        self.grid.validate(self.n)
        if self.dtype:
            # refuse the silent f64→f32 demotion JAX performs with x64 off —
            # a plan that claims float64 must actually compute in it
            canonical = precision.require_dtype(self.dtype, who="FFT3DPlan")
            object.__setattr__(self, "dtype", canonical.name)
        if self.schedule == "sequential":
            object.__setattr__(self, "chunks", 1)
        assert self.chunks >= 1
        engine = self.comm_engine or self.net
        if engine not in comm.ENGINES:
            raise ValueError(f"unknown comm_engine {engine!r}; "
                             f"have {sorted(comm.ENGINES)}")
        object.__setattr__(self, "comm_engine", engine)
        object.__setattr__(self, "net", comm.engine_fabric(engine))

    def spec(self) -> EngineSpec:
        """This plan's engine configuration as one :class:`EngineSpec`."""
        return EngineSpec(engine=self.comm_engine, backend=self.backend,
                          schedule=self.schedule, chunks=self.chunks,
                          real=self.real, r2c_packed=self.r2c_packed,
                          fused_roundtrip=self.fused_roundtrip)

    @classmethod
    def from_spec(cls, n, grid: PencilGrid, spec: EngineSpec,
                  dtype: str = "") -> "FFT3DPlan":
        """Build a plan from an :class:`EngineSpec` (the new spelling)."""
        return cls(n=tuple(n), grid=grid, real=spec.real,
                   backend=spec.backend, schedule=spec.schedule,
                   chunks=spec.chunks, r2c_packed=spec.r2c_packed,
                   comm_engine=spec.engine, dtype=dtype,
                   fused_roundtrip=spec.fused_roundtrip)

    def dag(self) -> CommDAG:
        """The axis-labelled transpose DAG this plan executes (X↔Y fold on
        grid dimension ``u``, Y↔Z fold on ``v``)."""
        return fft3d_dag(self.real)

    def engine(self) -> comm.TransposeEngine:
        """The TransposeEngine instance scheduling this plan's fold phases."""
        return comm.build_engine(self.spec(), self.grid)

    @property
    def kx(self) -> int:
        """Spectral X length: padded N/2+1 bins if real, else Nx."""
        return self.grid.padded_r2c_len(self.n[0]) if self.real else self.n[0]

    @property
    def kx_keep(self) -> int:
        return self.n[0] // 2 + 1 if self.real else self.n[0]


def _fftx(plan, xr, xi):
    if plan.real:
        yr, yi = kops.rfft1d(xr, axis=-1, backend=plan.backend, packed=plan.r2c_packed)
        pad = plan.kx - plan.kx_keep
        if pad:
            pw = [(0, 0)] * (yr.ndim - 1) + [(0, pad)]
            yr, yi = jnp.pad(yr, pw), jnp.pad(yi, pw)
        return yr, yi
    return kops.fft1d(xr, xi, axis=-1, backend=plan.backend)


def _ifftx(plan, xr, xi):
    if plan.real:
        xr = xr[..., : plan.kx_keep]
        xi = xi[..., : plan.kx_keep]
        return kops.irfft1d(xr, xi, n=plan.n[0], axis=-1, backend=plan.backend)
    return kops.fft1d(xr, xi, axis=-1, backend=plan.backend, inverse=True)


# ---------------------------------------------------------------------------
# local (inside-shard_map) forward / inverse
# ---------------------------------------------------------------------------

def _phase_span(plan: "FFT3DPlan", name: str, dim: str):
    """A ``trace/...`` span around one fold phase, annotated with the perf
    model's wire prediction for that phase. These run *inside* jit tracing
    of the shard_map body, so they fire once per compilation and time
    tracing, not execution — they exist to pin the DAG structure and the
    per-phase model numbers onto the trace (see README "Observability")."""
    if not obs.is_enabled():
        return obs.NULL_SPAN
    g = plan.grid
    sizes = g.u_sizes if dim == "u" else g.v_sizes
    wire_us = pm.estimate_fold_seconds(
        plan.n, g.pu, g.pv, sizes, comm_engine=plan.comm_engine) * 1e6
    return obs.span(name, engine=plan.comm_engine, grid_dim=dim,
                    dim_sizes=list(int(q) for q in sizes),
                    model_wire_us=round(wire_us, 3))


def fft3d_local(plan: FFT3DPlan, xr, xi=None):
    """Forward 3D FFT of the local pencil (any leading axes).

    In : X-pencil ``(..., Ny/Pu, Nz/Pv, Nx)`` (xi may be None for real input)
    Out: Z-pencil ``(..., Kx/Pu, Ny/Pv, Nz)`` planar complex, natural order.
    """
    eng = plan.engine()
    dag = plan.dag()
    obs.metrics.inc("fft3d.retraces.forward")
    if xi is None:
        xi = jnp.zeros_like(xr)

    # Phase X + X↔Y fold over grid dim u (hardware tasks A–D), slabbable
    # along local z (the step's slab axis)
    def butterflies_x(cr, ci):
        return _fftx(plan, cr, ci)

    with _phase_span(plan, "trace/fft3d.fold_xy", "u"):
        yr, yi = eng.run_fold(dag.step("xy"), butterflies_x, (xr, xi))

    # Phase Y + Y↔Z fold over grid dim v (tasks E–H), slabbable along kx
    def butterflies_y(cr, ci):
        return kops.fft1d(cr, ci, axis=-1, backend=plan.backend)

    with _phase_span(plan, "trace/fft3d.fold_yz", "v"):
        yr, yi = eng.run_fold(dag.step("yz"), butterflies_y, (yr, yi))

    # Phase Z (tasks I–K)
    return kops.fft1d(yr, yi, axis=-1, backend=plan.backend)


def ifft3d_local(plan: FFT3DPlan, kr, ki):
    """Inverse 3D FFT: Z-pencil spectral in, X-pencil physical out.

    Returns real array if ``plan.real`` else a planar (re, im) pair.
    """
    eng = plan.engine()
    dag = plan.dag()
    obs.metrics.inc("fft3d.retraces.inverse")
    yr, yi = kops.fft1d(kr, ki, axis=-1, backend=plan.backend, inverse=True)

    def butterflies_y_inv(ur, ui):
        return kops.fft1d(ur, ui, axis=-1, backend=plan.backend, inverse=True)

    with _phase_span(plan, "trace/fft3d.unfold_yz", "v"):
        yr, yi = eng.run_unfold(dag.step("yz"), butterflies_y_inv, (yr, yi))

    def butterflies_x_inv(ur, ui):
        if plan.real:
            return (_ifftx(plan, ur, ui),)
        return _ifftx(plan, ur, ui)

    with _phase_span(plan, "trace/fft3d.unfold_xy", "u"):
        out = eng.run_unfold(dag.step("xy"), butterflies_x_inv, (yr, yi))
    if plan.real:
        return out[0] if isinstance(out, tuple) and len(out) == 1 else out
    return out


# ---------------------------------------------------------------------------
# fused spectral roundtrip (forward FFT → diagonal multiply → inverse FFT)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DiagonalKernel:
    """A spectral operator that is pointwise-diagonal in k-space.

    ``dr``/``di`` hold the real/imaginary parts of the multiplier on the
    local Z-pencil spectrum — rank-local arrays of shape
    ``(Kx/Pu, Ny/Pv, Nz)``, exactly the layout the wavenumber helpers of
    ``core.spectral`` produce. ``di=None`` marks a purely real multiplier
    (heat decay, inverse Laplacian, dealias masks); the NLS rotation
    ``exp(iθ(k))`` uses both parts.

    One object serves all three execution paths: the composed full-volume
    multiply, the per-slab multiply inside the fused ``run_roundtrip``
    kernel callback (``lo``/``hi`` slice the kx rows in lockstep with the
    slab stream), and — on the RDMA ring engines — the raw arrays that
    join the in-kernel butterfly payload (``arrays()``).
    """

    dr: object
    di: object = None

    def apply(self, kr, ki, lo: int | None = None, hi: int | None = None):
        """Multiply the planar spectrum by the kernel; ``[lo, hi)`` selects
        the kx rows of a slab (slab axis −3 of the Z-pencil)."""
        dr, di = self.dr, self.di
        if lo is not None:
            axis = dr.ndim - 3
            dr = jax.lax.slice_in_dim(dr, lo, hi, axis=axis)
            if di is not None:
                di = jax.lax.slice_in_dim(di, lo, hi, axis=axis)
        if di is None:
            return kr * dr, ki * dr
        return kr * dr - ki * di, kr * di + ki * dr

    def arrays(self):
        """The raw planar multiplier pair (``di`` may be None) for engines
        that fuse the multiply into their communication kernel."""
        return self.dr, self.di


def spectral_roundtrip_local(plan: FFT3DPlan, kernel: DiagonalKernel,
                             xr, xi=None):
    """Forward 3D FFT → diagonal k-space multiply → inverse 3D FFT of the
    local pencil, as one solver-step primitive.

    With ``plan.fused_roundtrip`` off this composes ``fft3d_local`` →
    ``kernel.apply`` → ``ifft3d_local`` (three barriered phases). With it
    on, the whole Y↔Z phase pair — forward Y butterflies, yz fold, Z-FFT,
    multiply, inverse Z-FFT, yz unfold, inverse Y butterflies — streams
    through the engine's ``run_roundtrip`` schedule per kx-slab (fold k+1
    ∥ kernel k ∥ unfold k−1), bit-exact vs the composed path.

    In/out: X-pencil like ``fft3d_local``/``ifft3d_local`` (a real array
    comes back when ``plan.real``).
    """
    if not plan.fused_roundtrip:
        kr, ki = fft3d_local(plan, xr, xi)
        kr, ki = kernel.apply(kr, ki)
        return ifft3d_local(plan, kr, ki)

    eng = plan.engine()
    dag = plan.dag()
    obs.metrics.inc("fft3d.retraces.roundtrip")
    if xi is None:
        xi = jnp.zeros_like(xr)

    def butterflies_x(cr, ci):
        return _fftx(plan, cr, ci)

    with _phase_span(plan, "trace/fft3d.fold_xy", "u"):
        yr, yi = eng.run_fold(dag.step("xy"), butterflies_x, (xr, xi))

    def butterflies_y(cr, ci):
        return kops.fft1d(cr, ci, axis=-1, backend=plan.backend)

    def butterflies_y_inv(ur, ui):
        return kops.fft1d(ur, ui, axis=-1, backend=plan.backend,
                          inverse=True)

    def middle(zr, zi, lo, hi):
        # everything at the Z pencil, for kx rows [lo, hi): the remaining
        # transform, the spectral multiply, and its inverse
        zr, zi = kops.fft1d(zr, zi, axis=-1, backend=plan.backend)
        zr, zi = kernel.apply(zr, zi, lo, hi)
        return kops.fft1d(zr, zi, axis=-1, backend=plan.backend,
                          inverse=True)

    with _phase_span(plan, "trace/fft3d.roundtrip_yz", "v"):
        yr, yi = eng.run_roundtrip(dag.step("yz"), butterflies_y, middle,
                                   butterflies_y_inv, (yr, yi),
                                   diag=kernel.arrays())

    def butterflies_x_inv(ur, ui):
        if plan.real:
            return (_ifftx(plan, ur, ui),)
        return _ifftx(plan, ur, ui)

    with _phase_span(plan, "trace/fft3d.unfold_xy", "u"):
        out = eng.run_unfold(dag.step("xy"), butterflies_x_inv, (yr, yi))
    if plan.real:
        return out[0] if isinstance(out, tuple) and len(out) == 1 else out
    return out


def fft3d_vector_local(plan: FFT3DPlan, xr, xi=None,
                       vector_mode: VectorMode = "streaming"):
    """μ-component transform; leading axis 0 of ``xr`` is the component axis.

    ``parallel``  — one pass with the component axis live throughout (μ×
                    memory, paper §4.4.1).
    ``streaming`` — per-dimension stream X(c),Y(c),Z(c) per component c
                    (Fig. 4.4/4.6): unrolled so XLA pipelines component c+1
                    under component c.
    """
    if vector_mode == "parallel":
        return fft3d_local(plan, xr, xi)
    outs = [fft3d_local(plan, xr[c], None if xi is None else xi[c])
            for c in range(xr.shape[0])]
    return (jnp.stack([o[0] for o in outs]), jnp.stack([o[1] for o in outs]))


def ifft3d_vector_local(plan: FFT3DPlan, kr, ki,
                        vector_mode: VectorMode = "streaming"):
    if vector_mode == "parallel":
        return ifft3d_local(plan, kr, ki)
    outs = [ifft3d_local(plan, kr[c], ki[c]) for c in range(kr.shape[0])]
    if plan.real:
        return jnp.stack(outs)
    return (jnp.stack([o[0] for o in outs]), jnp.stack([o[1] for o in outs]))


# ---------------------------------------------------------------------------
# global entry points
# ---------------------------------------------------------------------------

def make_fft3d(mesh, n, *, spec: EngineSpec | None = None,
               u_axes=("data",), v_axes=("model",), real: bool | None = None,
               components: int = 0, autotune: bool = False,
               tune_kwargs: dict | None = None):
    """Build jitted (forward, inverse, plan) over globally-sharded arrays.

    Global input layout: X-pencil ``(Ny, Nz, Nx)`` sharded ``P(u, v, None)``
    (plus a leading component axis if ``components``); output Z-pencil
    ``(Kx, Ny, Nz)`` sharded the same way.

    ``spec`` is the one engine-configuration knob (engine, backend,
    schedule, chunks, vector_mode, r2c_packed, fused_roundtrip — see
    :class:`~repro.core.engine_spec.EngineSpec`); ``real`` stays a separate
    argument because it describes the *problem* (the data model of the
    field being transformed), overriding ``spec.real`` when given.

    ``u_axes``/``v_axes`` bind the two grid dimensions to mesh axes; either
    may span several (e.g. ``u_axes=("pod", "data")``), in which case every
    engine — including the RDMA rings — runs one per-axis exchange per
    mesh axis (the staged multi-axis schedule of ``core.transpose``).

    ``autotune=True`` ignores the explicit engine configuration and
    instead sweeps the plan space for this ``(n, mesh, real, components)``
    problem (see ``repro.tuning``), reusing the persistent plan cache when
    a prior run already timed it. ``tune_kwargs`` forwards extra options
    to ``repro.tuning.autotune`` (``cache_path``, ``max_candidates``,
    ``iters``, ``fwd_weight``, ``inv_weight``, ...).
    """
    n = (n, n, n) if isinstance(n, int) else tuple(n)
    s = spec if spec is not None else EngineSpec()
    if real is not None:
        s = s.replace(real=bool(real))
    if autotune:
        from repro.tuning import autotune as _autotune
        from repro.tuning.space import Candidate
        result = _autotune(mesh, n, real=s.real, components=components,
                           u_axes=u_axes, v_axes=v_axes,
                           **(tune_kwargs or {}))
        best = Candidate.from_config(result.best_config)  # legacy-net aware
        s = best.spec(real=s.real)
    grid = PencilGrid.from_mesh(mesh, u_axes, v_axes)
    plan = FFT3DPlan.from_spec(n, grid, s)
    real = s.real
    vector_mode = s.vector_mode
    base = grid.pencil_spec()
    spec = P(*((None,) + tuple(base))) if components else base

    def fwd_local(xr, xi):
        f = functools.partial(fft3d_vector_local, plan, vector_mode=vector_mode) \
            if components else functools.partial(fft3d_local, plan)
        return f(xr, xi)

    def inv_local(kr, ki):
        f = functools.partial(ifft3d_vector_local, plan, vector_mode=vector_mode) \
            if components else functools.partial(ifft3d_local, plan)
        return f(kr, ki)

    if real:
        fwd = jax.jit(compat.shard_map(
            lambda x: fwd_local(x, None), mesh=mesh,
            in_specs=spec, out_specs=(spec, spec), check_vma=False))
        inv = jax.jit(compat.shard_map(
            inv_local, mesh=mesh, in_specs=(spec, spec), out_specs=spec,
            check_vma=False))
    else:
        fwd = jax.jit(compat.shard_map(
            fwd_local, mesh=mesh,
            in_specs=(spec, spec), out_specs=(spec, spec), check_vma=False))
        inv = jax.jit(compat.shard_map(
            inv_local, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, spec),
            check_vma=False))
    # dispatch-boundary spans (one branch + tail call while tracing is off);
    # jit surfaces like ``.lower`` forward through the wrapper
    attrs = {
        "engine": plan.comm_engine, "n": list(n),
        "mesh": "x".join(str(int(q)) for q in grid.u_sizes + grid.v_sizes),
        "model_predicted_us": round(pm.estimate_plan_seconds(
            n, grid.pu, grid.pv, spec=s, mu=max(components, 1),
            pu_axes=grid.u_sizes, pv_axes=grid.v_sizes) * 1e6, 3),
    }
    fwd = obs.traced_call(fwd, "dispatch/fft3d.fwd", attrs)
    inv = obs.traced_call(inv, "dispatch/fft3d.inv", attrs)
    return fwd, inv, plan
