"""The paper's primary contribution: distributed 3D FFT on a 2D pencil grid
with sequential/pipelined scheduling, switched/torus network models, and the
analytic performance model of the thesis."""

from repro.core.decomposition import (CommDAG, CommStep, PencilGrid,
                                      fft3d_dag)
from repro.core.engine_spec import EngineSpec
from repro.core.comm import build_engine
from repro.core.fft3d import (FFT3DPlan, fft3d_local, ifft3d_local,
                              fft3d_vector_local, ifft3d_vector_local,
                              make_fft3d)
from repro.core import perfmodel, spectral, topology, transpose

__all__ = [
    "PencilGrid", "CommStep", "CommDAG", "EngineSpec", "fft3d_dag",
    "build_engine", "FFT3DPlan", "fft3d_local", "ifft3d_local",
    "fft3d_vector_local", "ifft3d_vector_local", "make_fft3d",
    "perfmodel", "spectral", "topology", "transpose",
]
