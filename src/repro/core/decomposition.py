"""2D pencil domain decomposition (paper §3.2.3, Fig. 3.2).

The N³ grid is distributed over a Pu×Pv process grid mapped onto mesh axes
(u → ``data``-like axes, v → ``model``-like axes). Layout convention (matching
P3DFFT and the thesis):

* **X-pencil** (physical space input): local ``(Ny/Pu, Nz/Pv, Nx)`` — the full
  X line is local, FFT runs over the last axis.
* **Y-pencil** (after the X↔Y fold): local ``(Nx/Pu, Nz/Pv, Ny)``.
* **Z-pencil** (after the Y↔Z fold, spectral output): local
  ``(Nx/Pu, Ny/Pv, Nz)`` — i.e. global ``(kx, ky, kz)`` natural order sharded
  ``P(u, v, None)``.

The forward transform therefore lands in natural (kx, ky, kz) order, and the
inverse retraces the pipeline back to X-pencils.

Besides the grid itself this module owns the **communication DAG** describing
the transpose pipeline: each :class:`CommStep` names the processor-grid
dimension it exchanges over (``u`` or ``v`` — each possibly spanning several
mesh axes), the local split/concat/permute geometry of the relayout, the
slab axis untouched by the exchange (the overlap/pipelining axis), and
whether the compute between the exchanges is plain c2c (in-kernel fusable).
:func:`fft3d_dag` builds the two-step forward DAG (X↔Y fold on ``u``, Y↔Z
fold on ``v``); the inverse walks the same steps backwards with the derived
unfold geometry (:meth:`CommStep.unfold_split` / ``unfold_concat``).
"""

from __future__ import annotations

import dataclasses
import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PencilGrid:
    """The Pu×Pv processor grid of the paper, bound to mesh axis names.

    ``u_sizes``/``v_sizes`` record the per-mesh-axis factorization of each
    grid dimension (e.g. ``u_axes=("pod", "data")`` on a 2×4×… mesh gives
    ``u_sizes=(2, 4)``): the ring engines run one ring per mesh axis, so the
    perf model prices Σᵢ(qᵢ−1) rounds rather than P−1.  When not supplied
    they default to the flat ``(pu,)``/``(pv,)`` single-axis view.
    """

    pu: int
    pv: int
    u_axes: tuple[str, ...] = ("data",)
    v_axes: tuple[str, ...] = ("model",)
    u_sizes: tuple[int, ...] = ()
    v_sizes: tuple[int, ...] = ()

    def __post_init__(self):
        if not self.u_sizes:
            object.__setattr__(self, "u_sizes", (self.pu,))
        if not self.v_sizes:
            object.__setattr__(self, "v_sizes", (self.pv,))
        if math.prod(self.u_sizes) != self.pu:
            raise ValueError(f"u_sizes {self.u_sizes} do not factor pu={self.pu}")
        if math.prod(self.v_sizes) != self.pv:
            raise ValueError(f"v_sizes {self.v_sizes} do not factor pv={self.pv}")

    @classmethod
    def from_mesh(cls, mesh: jax.sharding.Mesh,
                  u_axes=("data",), v_axes=("model",)) -> "PencilGrid":
        u_axes, v_axes = tuple(u_axes), tuple(v_axes)
        u_sizes = tuple(mesh.shape[a] for a in u_axes)
        v_sizes = tuple(mesh.shape[a] for a in v_axes)
        return cls(pu=math.prod(u_sizes), pv=math.prod(v_sizes),
                   u_axes=u_axes, v_axes=v_axes,
                   u_sizes=u_sizes or (1,), v_sizes=v_sizes or (1,))

    @property
    def p(self) -> int:
        return self.pu * self.pv

    # ---- per-dimension views (CommStep.grid_dim -> mesh axes/ranks) ------
    def dim_axes(self, dim: str) -> tuple[str, ...]:
        """Mesh axis names spanned by grid dimension ``"u"`` or ``"v"``."""
        if dim not in ("u", "v"):
            raise ValueError(f"grid dimension must be 'u' or 'v', got {dim!r}")
        return self.u_axes if dim == "u" else self.v_axes

    def dim_ranks(self, dim: str) -> int:
        """Total rank count of grid dimension ``"u"`` or ``"v"``."""
        return self.pu if dim == "u" else self.pv

    def dim_sizes(self, dim: str) -> tuple[int, ...]:
        """Per-mesh-axis rank factorization of grid dimension ``dim``."""
        return self.u_sizes if dim == "u" else self.v_sizes

    # ---- shardings -------------------------------------------------------
    def pencil_spec(self) -> P:
        """All three pencil layouts shard axes 0,1 over (u, v)."""
        u = self.u_axes if len(self.u_axes) > 1 else self.u_axes[0]
        v = self.v_axes if len(self.v_axes) > 1 else self.v_axes[0]
        return P(u, v, None)

    def sharding(self, mesh) -> NamedSharding:
        return NamedSharding(mesh, self.pencil_spec())

    # ---- local shapes ----------------------------------------------------
    def validate(self, n: tuple[int, int, int]) -> None:
        nx, ny, nz = n
        if ny % self.pu:
            raise ValueError(f"Ny={ny} not divisible by Pu={self.pu}")
        if nz % self.pv:
            raise ValueError(f"Nz={nz} not divisible by Pv={self.pv}")
        if nx % self.pu:
            raise ValueError(f"Nx={nx} not divisible by Pu={self.pu} (X<->Y fold)")
        if ny % self.pv:
            raise ValueError(f"Ny={ny} not divisible by Pv={self.pv} (Y<->Z fold)")

    def x_pencil_local(self, n):  # (Ny/Pu, Nz/Pv, Nx)
        nx, ny, nz = n
        return (ny // self.pu, nz // self.pv, nx)

    def y_pencil_local(self, n, kx: int | None = None):
        nx, ny, nz = n
        return ((kx or nx) // self.pu, nz // self.pv, ny)

    def z_pencil_local(self, n, kx: int | None = None):
        nx, ny, nz = n
        return ((kx or nx) // self.pu, ny // self.pv, nz)

    def padded_r2c_len(self, nx: int) -> int:
        """Shard-divisible length holding the N/2+1 significant bins.

        The paper keeps N/2+1 complex outputs of the real X transform
        (§3.2.5) and accepts the resulting slight imbalance; on a rigid SPMD
        mesh we instead pad to the next multiple of Pu (the padding carries
        zeros and is dropped by the inverse).
        """
        keep = nx // 2 + 1
        return ((keep + self.pu - 1) // self.pu) * self.pu

    # ---- data-volume model (paper §3.2.5) --------------------------------
    def local_volume_bytes(self, n, s: int = 8) -> int:
        """V = s·N³/P (Eq. 3.3)."""
        nx, ny, nz = n
        return s * nx * ny * nz // self.p

    def local_volume_after_x_bytes(self, n, s: int = 8) -> int:
        """V' = s(N³ + 2N²)/P (Eq. 3.4), N=Nx."""
        nx, ny, nz = n
        return s * (nx * ny * nz + 2 * ny * nz) // self.p


# ---------------------------------------------------------------------------
# Communication DAG: axis-labelled transpose steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CommStep:
    """One distributed transpose of the pencil pipeline, axis-labelled.

    A step is the *whole* contract an engine needs to execute (and overlap)
    one fold: which processor-grid dimension carries the exchange, how the
    local block is split/recombined around it, and which local axis stays
    untouched (the slab/pipelining axis).  Offsets are negative (counted
    from the trailing axis) so the same step applies under leading batch or
    component axes.

    ``name``          step label (``"xy"``, ``"yz"``)
    ``grid_dim``      ``"u"`` or ``"v"`` — resolved to mesh axes via
                      :meth:`PencilGrid.dim_axes`; a dimension spanning
                      several mesh axes runs one ring per axis
    ``split_offset``  local axis split across the ranks on the way out
    ``concat_offset`` local axis the received blocks are merged into
    ``permute``       permutation of the last three local axes applied after
                      the fold exchange (and before the unfold exchange) —
                      an involution for both pipeline steps
    ``slab_offset``   local axis untouched by the exchange; phase compute is
                      chunked/overlapped along it
    ``c2c``           the compute paired with this step is plain c2c
                      butterflies (eligible for in-kernel RDMA fusion); the
                      r2c X-transform step sets this False
    """

    name: str
    grid_dim: str
    split_offset: int
    concat_offset: int
    permute: tuple[int, int, int]
    slab_offset: int
    c2c: bool = True

    # unfold geometry is fully derived: the inverse exchange splits where the
    # fold concatenated and concatenates where the fold split, with the same
    # (involutive) local permute applied first.
    @property
    def unfold_split(self) -> int:
        return self.concat_offset

    @property
    def unfold_concat(self) -> int:
        return self.split_offset

    def replace(self, **changes) -> "CommStep":
        return dataclasses.replace(self, **changes)


# The two steps of the forward 3D-FFT pipeline (§3.2.4): X-pencil → Y-pencil
# over u, then Y-pencil → Z-pencil over v.  ``permute`` spells transpose.
# _swap_last3 / _swap_last2 as explicit last-three-axes permutations.
XY_STEP = CommStep(name="xy", grid_dim="u", split_offset=-1, concat_offset=-3,
                   permute=(2, 1, 0), slab_offset=-2, c2c=True)
YZ_STEP = CommStep(name="yz", grid_dim="v", split_offset=-1, concat_offset=-2,
                   permute=(0, 2, 1), slab_offset=-3, c2c=True)


@dataclasses.dataclass(frozen=True)
class CommDAG:
    """The ordered transpose steps of one distributed transform.

    Forward execution runs ``steps`` left to right (fold direction); the
    inverse runs them right to left in unfold direction.  Engines consume
    steps one at a time — the DAG is the plan-level object that `fft3d`
    threads through :meth:`TransposeEngine.run_fold` / ``run_unfold``.
    """

    steps: tuple[CommStep, ...]

    def __iter__(self):
        return iter(self.steps)

    def __len__(self) -> int:
        return len(self.steps)

    def step(self, name: str) -> CommStep:
        for s in self.steps:
            if s.name == name:
                return s
        raise KeyError(f"no CommStep named {name!r} in "
                       f"{tuple(s.name for s in self.steps)}")

    def inverse_steps(self) -> tuple[CommStep, ...]:
        """Steps in unfold order (right to left)."""
        return tuple(reversed(self.steps))

    def validate(self, grid: PencilGrid) -> None:
        for s in self.steps:
            grid.dim_axes(s.grid_dim)  # raises on unknown grid_dim
            if sorted(s.permute) != [0, 1, 2]:
                raise ValueError(f"step {s.name!r}: permute {s.permute} is "
                                 "not a permutation of the last three axes")


def fft3d_dag(real: bool = False) -> CommDAG:
    """The two-step pencil-transpose DAG of the 3D FFT.

    The X↔Y fold overlaps the X-line transforms: under the r2c data model
    those are not plain c2c butterflies, so ``real=True`` clears the step's
    ``c2c`` flag (disqualifying in-kernel RDMA butterfly fusion for that
    step only — the Y↔Z fold always wraps c2c compute).
    """
    return CommDAG(steps=(XY_STEP.replace(c2c=not real), YZ_STEP))
