"""2D pencil domain decomposition (paper §3.2.3, Fig. 3.2).

The N³ grid is distributed over a Pu×Pv process grid mapped onto mesh axes
(u → ``data``-like axes, v → ``model``-like axes). Layout convention (matching
P3DFFT and the thesis):

* **X-pencil** (physical space input): local ``(Ny/Pu, Nz/Pv, Nx)`` — the full
  X line is local, FFT runs over the last axis.
* **Y-pencil** (after the X↔Y fold): local ``(Nx/Pu, Nz/Pv, Ny)``.
* **Z-pencil** (after the Y↔Z fold, spectral output): local
  ``(Nx/Pu, Ny/Pv, Nz)`` — i.e. global ``(kx, ky, kz)`` natural order sharded
  ``P(u, v, None)``.

The forward transform therefore lands in natural (kx, ky, kz) order, and the
inverse retraces the pipeline back to X-pencils.
"""

from __future__ import annotations

import dataclasses
import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PencilGrid:
    """The Pu×Pv processor grid of the paper, bound to mesh axis names."""

    pu: int
    pv: int
    u_axes: tuple[str, ...] = ("data",)
    v_axes: tuple[str, ...] = ("model",)

    @classmethod
    def from_mesh(cls, mesh: jax.sharding.Mesh,
                  u_axes=("data",), v_axes=("model",)) -> "PencilGrid":
        u_axes, v_axes = tuple(u_axes), tuple(v_axes)
        pu = math.prod(mesh.shape[a] for a in u_axes)
        pv = math.prod(mesh.shape[a] for a in v_axes)
        return cls(pu=pu, pv=pv, u_axes=u_axes, v_axes=v_axes)

    @property
    def p(self) -> int:
        return self.pu * self.pv

    # ---- shardings -------------------------------------------------------
    def pencil_spec(self) -> P:
        """All three pencil layouts shard axes 0,1 over (u, v)."""
        u = self.u_axes if len(self.u_axes) > 1 else self.u_axes[0]
        v = self.v_axes if len(self.v_axes) > 1 else self.v_axes[0]
        return P(u, v, None)

    def sharding(self, mesh) -> NamedSharding:
        return NamedSharding(mesh, self.pencil_spec())

    # ---- local shapes ----------------------------------------------------
    def validate(self, n: tuple[int, int, int]) -> None:
        nx, ny, nz = n
        if ny % self.pu:
            raise ValueError(f"Ny={ny} not divisible by Pu={self.pu}")
        if nz % self.pv:
            raise ValueError(f"Nz={nz} not divisible by Pv={self.pv}")
        if nx % self.pu:
            raise ValueError(f"Nx={nx} not divisible by Pu={self.pu} (X<->Y fold)")
        if ny % self.pv:
            raise ValueError(f"Ny={ny} not divisible by Pv={self.pv} (Y<->Z fold)")

    def x_pencil_local(self, n):  # (Ny/Pu, Nz/Pv, Nx)
        nx, ny, nz = n
        return (ny // self.pu, nz // self.pv, nx)

    def y_pencil_local(self, n, kx: int | None = None):
        nx, ny, nz = n
        return ((kx or nx) // self.pu, nz // self.pv, ny)

    def z_pencil_local(self, n, kx: int | None = None):
        nx, ny, nz = n
        return ((kx or nx) // self.pu, ny // self.pv, nz)

    def padded_r2c_len(self, nx: int) -> int:
        """Shard-divisible length holding the N/2+1 significant bins.

        The paper keeps N/2+1 complex outputs of the real X transform
        (§3.2.5) and accepts the resulting slight imbalance; on a rigid SPMD
        mesh we instead pad to the next multiple of Pu (the padding carries
        zeros and is dropped by the inverse).
        """
        keep = nx // 2 + 1
        return ((keep + self.pu - 1) // self.pu) * self.pu

    # ---- data-volume model (paper §3.2.5) --------------------------------
    def local_volume_bytes(self, n, s: int = 8) -> int:
        """V = s·N³/P (Eq. 3.3)."""
        nx, ny, nz = n
        return s * nx * ny * nz // self.p

    def local_volume_after_x_bytes(self, n, s: int = 8) -> int:
        """V' = s(N³ + 2N²)/P (Eq. 3.4), N=Nx."""
        nx, ny, nz = n
        return s * (nx * ny * nz + 2 * ny * nz) // self.p
