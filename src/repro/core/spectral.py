"""Spectral-space operators for the pseudo-spectral CFD case study (§1.2).

All functions operate on Z-pencil spectral fields — local shape
``(..., Kx/Pu, Ny/Pv, Nz)`` inside ``shard_map`` — and therefore need the
*local* wavenumber slabs, which depend on the rank's (u, v) grid coordinates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core.fft3d import FFT3DPlan


_flat_index = compat.flat_axis_index


def local_wavenumbers(plan: FFT3DPlan, dtype=jnp.float64):
    """(kx, ky, kz) integer wavenumbers for this rank's Z-pencil slab.

    kx: slab of the padded spectral X axis (r2c keeps 0..N/2 then zeros);
    ky: slab of fftfreq-ordered Ny; kz: full fftfreq-ordered Nz.
    """
    nx, ny, nz = plan.n
    g = plan.grid
    u = _flat_index(g.u_axes)
    v = _flat_index(g.v_axes)

    def fftfreq_int(n):
        k = jnp.arange(n)
        return jnp.where(k <= n // 2 - 1 + (n % 2), k, k - n).astype(dtype)

    if plan.real:
        kx_full = jnp.arange(plan.kx, dtype=dtype)  # bins beyond keep are pad
    else:
        kx_full = fftfreq_int(nx)
    lx = plan.kx // g.pu
    kx = lax.dynamic_slice_in_dim(kx_full, u * lx, lx)

    ky_full = fftfreq_int(ny)
    ly = ny // g.pv
    ky = lax.dynamic_slice_in_dim(ky_full, v * ly, ly)

    kz = fftfreq_int(nz)
    return kx[:, None, None], ky[None, :, None], kz[None, None, :]


def pad_mask(plan: FFT3DPlan, dtype=jnp.float64):
    """1 on significant kx bins, 0 on the r2c shard padding."""
    g = plan.grid
    u = _flat_index(g.u_axes)
    lx = plan.kx // g.pu
    idx = u * lx + jnp.arange(lx)
    return (idx < plan.kx_keep).astype(dtype)[:, None, None]


def dealias_mask(plan: FFT3DPlan, dtype=jnp.float64):
    """2/3-rule mask for the pseudo-spectral nonlinear term."""
    kx, ky, kz = local_wavenumbers(plan, dtype)
    nx, ny, nz = plan.n
    m = ((jnp.abs(kx) < nx / 3.0)
         & (jnp.abs(ky) < ny / 3.0)
         & (jnp.abs(kz) < nz / 3.0))
    out = m.astype(dtype)
    if plan.real:
        out = out * pad_mask(plan, dtype)
    return out


def k_squared(plan: FFT3DPlan, dtype=jnp.float64):
    kx, ky, kz = local_wavenumbers(plan, dtype)
    return kx * kx + ky * ky + kz * kz


def poisson_solve(plan: FFT3DPlan, fr, fi):
    """∇²φ = f  ⇒  φ̂ = −f̂ / k² (zero-mean gauge; k=0 mode zeroed)."""
    k2 = k_squared(plan, fr.dtype)
    inv = jnp.where(k2 > 0, -1.0 / jnp.maximum(k2, 1e-30), 0.0)
    if plan.real:
        inv = inv * pad_mask(plan, fr.dtype)
    return fr * inv, fi * inv


def gradient(plan: FFT3DPlan, fr, fi):
    """∂/∂(x,y,z) in spectral space: multiply by i·k (planar complex)."""
    kx, ky, kz = local_wavenumbers(plan, fr.dtype)
    outs = []
    for k in (kx, ky, kz):
        outs.append((-k * fi, k * fr))  # i*k*(fr + i fi) = -k fi + i k fr
    return outs


def project_divergence_free(plan: FFT3DPlan, vr, vi):
    """Leray projection: v̂ ← v̂ − k (k·v̂)/k² for a 3-component field.

    vr/vi: (3, ...) planar spectral velocity. Used by the Navier–Stokes
    driver to enforce incompressibility.
    """
    kx, ky, kz = local_wavenumbers(plan, vr.dtype)
    ks = (kx, ky, kz)
    k2 = k_squared(plan, vr.dtype)
    dot_r = sum(ks[c] * vr[c] for c in range(3))
    dot_i = sum(ks[c] * vi[c] for c in range(3))
    inv = jnp.where(k2 > 0, 1.0 / jnp.maximum(k2, 1e-30), 0.0)
    pr = jnp.stack([vr[c] - ks[c] * dot_r * inv for c in range(3)])
    pi = jnp.stack([vi[c] - ks[c] * dot_i * inv for c in range(3)])
    return pr, pi


def energy_spectrum_total(plan: FFT3DPlan, vr, vi):
    """Total kinetic energy Σ|v̂|² over local slab (psum over the grid)."""
    g = plan.grid
    e = jnp.sum(vr * vr + vi * vi)
    axes = tuple(g.u_axes) + tuple(g.v_axes)
    if axes:
        e = lax.psum(e, axes if len(axes) > 1 else axes[0])
    return e
