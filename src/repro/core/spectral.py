"""Spectral-space operators for FFT-based pseudo-spectral solvers (§1.2).

All functions operate on Z-pencil spectral fields — local shape
``(..., Kx/Pu, Ny/Pv, Nz)`` inside ``shard_map`` — and therefore need the
*local* wavenumber slabs, which depend on the rank's (u, v) grid coordinates.

Complex spectral fields are carried as planar ``(re, im)`` array pairs.
``dtype=None`` arguments resolve to :func:`repro.core.precision
.default_real_dtype` — the widest real dtype this process actually computes
in — instead of silently demoting a hardcoded float64.

These operators are the shared vocabulary of ``repro.solvers``: every
concrete solver's "spectral computation" stage (the middle of the paper's
FFT → spectral → iFFT → local cycle) is built from them.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core import precision
from repro.core.fft3d import FFT3DPlan, fft3d_vector_local, ifft3d_vector_local


_flat_index = compat.flat_axis_index


def _dtype(dtype):
    return precision.default_real_dtype() if dtype is None else dtype


def local_wavenumbers(plan: FFT3DPlan, dtype=None):
    """(kx, ky, kz) integer wavenumbers for this rank's Z-pencil slab.

    kx: slab of the padded spectral X axis (r2c keeps 0..N/2 then zeros);
    ky: slab of fftfreq-ordered Ny; kz: full fftfreq-ordered Nz.
    """
    dtype = _dtype(dtype)
    nx, ny, nz = plan.n
    g = plan.grid
    u = _flat_index(g.u_axes)
    v = _flat_index(g.v_axes)

    def fftfreq_int(n):
        k = jnp.arange(n)
        return jnp.where(k <= n // 2 - 1 + (n % 2), k, k - n).astype(dtype)

    if plan.real:
        kx_full = jnp.arange(plan.kx, dtype=dtype)  # bins beyond keep are pad
    else:
        kx_full = fftfreq_int(nx)
    lx = plan.kx // g.pu
    kx = lax.dynamic_slice_in_dim(kx_full, u * lx, lx)

    ky_full = fftfreq_int(ny)
    ly = ny // g.pv
    ky = lax.dynamic_slice_in_dim(ky_full, v * ly, ly)

    kz = fftfreq_int(nz)
    return kx[:, None, None], ky[None, :, None], kz[None, None, :]


def pad_mask(plan: FFT3DPlan, dtype=None):
    """1 on significant kx bins, 0 on the r2c shard padding."""
    dtype = _dtype(dtype)
    g = plan.grid
    u = _flat_index(g.u_axes)
    lx = plan.kx // g.pu
    idx = u * lx + jnp.arange(lx)
    return (idx < plan.kx_keep).astype(dtype)[:, None, None]


def dealias_mask(plan: FFT3DPlan, dtype=None):
    """2/3-rule mask for the pseudo-spectral nonlinear term."""
    dtype = _dtype(dtype)
    kx, ky, kz = local_wavenumbers(plan, dtype)
    nx, ny, nz = plan.n
    m = ((jnp.abs(kx) < nx / 3.0)
         & (jnp.abs(ky) < ny / 3.0)
         & (jnp.abs(kz) < nz / 3.0))
    out = m.astype(dtype)
    if plan.real:
        out = out * pad_mask(plan, dtype)
    return out


def k_squared(plan: FFT3DPlan, dtype=None):
    kx, ky, kz = local_wavenumbers(plan, _dtype(dtype))
    return kx * kx + ky * ky + kz * kz


def invert_laplacian(plan: FFT3DPlan, fr, fi, *, mean: float = 0.0):
    """Solve ∇²φ = f in spectral space: φ̂ = −f̂ / k².

    The inverse Laplacian is defined only up to a constant — the k=0 mode
    carries the domain mean, and −f̂/k² is singular there. ``mean`` fixes
    the gauge: the returned field's mean is set to it (``0.0`` reproduces
    the classic zero-mean Poisson gauge). Only the rank owning the k=0 bin
    touches it, expressed uniformly via the local ``k² == 0`` mask.
    """
    k2 = k_squared(plan, fr.dtype)
    inv = jnp.where(k2 > 0, -1.0 / jnp.maximum(k2, 1e-30), 0.0)
    if plan.real:
        inv = inv * pad_mask(plan, fr.dtype)
    pr, pi = fr * inv, fi * inv
    if mean:
        ntot = plan.n[0] * plan.n[1] * plan.n[2]  # unnormalized forward FFT
        zero_mode = (k2 == 0)
        if plan.real:
            zero_mode = zero_mode & (pad_mask(plan, fr.dtype) > 0)
        pr = jnp.where(zero_mode, jnp.asarray(mean * ntot, pr.dtype), pr)
    return pr, pi


def poisson_solve(plan: FFT3DPlan, fr, fi):
    """∇²φ = f  ⇒  φ̂ = −f̂ / k² (zero-mean gauge; k=0 mode zeroed)."""
    return invert_laplacian(plan, fr, fi, mean=0.0)


def gradient(plan: FFT3DPlan, fr, fi):
    """∂/∂(x,y,z) in spectral space: multiply by i·k (planar complex)."""
    kx, ky, kz = local_wavenumbers(plan, fr.dtype)
    outs = []
    for k in (kx, ky, kz):
        outs.append((-k * fi, k * fr))  # i*k*(fr + i fi) = -k fi + i k fr
    return outs


def curl(plan: FFT3DPlan, vr, vi):
    """Vorticity ω̂ = i k × v̂ for a planar (3, ...) spectral field."""
    kx, ky, kz = local_wavenumbers(plan, vr.dtype)

    def cross_k(ar):
        return jnp.stack([ky * ar[2] - kz * ar[1],
                          kz * ar[0] - kx * ar[2],
                          kx * ar[1] - ky * ar[0]])

    # i*(k × v): (i k) × (vr + i vi) = -(k × vi) + i (k × vr)
    return -cross_k(vi), cross_k(vr)


def project_divergence_free(plan: FFT3DPlan, vr, vi):
    """Leray projection: v̂ ← v̂ − k (k·v̂)/k² for a 3-component field.

    Used by the Navier–Stokes solver to enforce incompressibility.
    """
    kx, ky, kz = local_wavenumbers(plan, vr.dtype)
    ks = (kx, ky, kz)
    k2 = k_squared(plan, vr.dtype)
    dot_r = sum(ks[c] * vr[c] for c in range(3))
    dot_i = sum(ks[c] * vi[c] for c in range(3))
    inv = jnp.where(k2 > 0, 1.0 / jnp.maximum(k2, 1e-30), 0.0)
    pr = jnp.stack([vr[c] - ks[c] * dot_r * inv for c in range(3)])
    pi = jnp.stack([vi[c] - ks[c] * dot_i * inv for c in range(3)])
    return pr, pi


def rotational_nonlinear_term(plan: FFT3DPlan, vr, vi, *,
                              vector_mode="streaming", project=True):
    """Dealiased rotational-form convection term \\widehat{u × ω}.

    The pseudo-spectral nonlinear stage shared by the incompressible
    Navier–Stokes solver (and any rotational-form momentum equation):
    inverse-transform velocity and vorticity, form u × ω pointwise in
    physical space, forward-transform, 2/3-dealias, and (optionally) Leray
    project. Exactly one forward + two inverse vector transforms — the cost
    model the tuning objective prices.
    """
    u = ifft3d_vector_local(plan, vr, vi, vector_mode=vector_mode)
    wr, wi = curl(plan, vr, vi)
    w = ifft3d_vector_local(plan, wr, wi, vector_mode=vector_mode)
    uxw = jnp.stack([u[1] * w[2] - u[2] * w[1],
                     u[2] * w[0] - u[0] * w[2],
                     u[0] * w[1] - u[1] * w[0]])
    nr, ni = fft3d_vector_local(plan, uxw, None, vector_mode=vector_mode)
    mask = dealias_mask(plan, nr.dtype)
    nr, ni = nr * mask, ni * mask
    if project:
        nr, ni = project_divergence_free(plan, nr, ni)
    return nr, ni


def grid_sum(plan: FFT3DPlan, x):
    """Sum of local scalar ``x`` over the whole Pu×Pv processor grid."""
    g = plan.grid
    axes = tuple(g.u_axes) + tuple(g.v_axes)
    if axes:
        x = lax.psum(x, axes if len(axes) > 1 else axes[0])
    return x


def grid_max(plan: FFT3DPlan, x):
    """Max of local scalar ``x`` over the whole Pu×Pv processor grid."""
    g = plan.grid
    axes = tuple(g.u_axes) + tuple(g.v_axes)
    if axes:
        x = lax.pmax(x, axes if len(axes) > 1 else axes[0])
    return x


def energy_spectrum_total(plan: FFT3DPlan, vr, vi):
    """Total kinetic energy Σ|v̂|² over local slab (psum over the grid)."""
    return grid_sum(plan, jnp.sum(vr * vr + vi * vi))


def max_divergence(plan: FFT3DPlan, vr, vi):
    """max |k·v̂| over the grid — the divergence-free diagnostic."""
    kx, ky, kz = local_wavenumbers(plan, vr.dtype)
    div = jnp.max(jnp.abs(kx * vr[0] + ky * vr[1] + kz * vr[2])) + \
        jnp.max(jnp.abs(kx * vi[0] + ky * vi[1] + kz * vi[2]))
    return grid_max(plan, div)
