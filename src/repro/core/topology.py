"""Network topology characterization (paper §3.2.6, §5.5).

The 2D processor grid puts X↔Y traffic on rows and Y↔Z traffic on columns —
"rows and columns never exchange data traffic and can live on separated
networks". This module sizes those networks for both fabrics of the thesis
and answers the scalability question of Figs 5.11/5.12.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import perfmodel as pm
from repro.core.engine_spec import EngineSpec

LINK_CAPS_GBPS = (100.0, 200.0, 400.0)      # thesis reference lines
FREQS_MHZ = (180.0, 250.0, 380.0)           # slow / standard / very fast engine

#: TransposeEngine → fabric it must be sized for: the switched engine needs
#: the full-bisection row/column switches of Fig. 5.10; every ring engine
#: (plain torus, the compute-overlapped ring, the RDMA ring, and the
#: bidirectional two-NIC ring) rides the 2D torus links of Fig. 5.9 —
#: overlap and direction change *when* blocks move, not how many links
#: exist (the torus node already owns both ±u links the bidi ring drives).
ENGINE_FABRIC = pm.ENGINE_FABRIC


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    """Sizing of one fabric choice for a √P×√P grid.

    ``engine``/``chunks`` are filled by :meth:`for_spec`: the engine the
    fabric serves and — when the problem size ``n`` is known — the
    engine-aware optimal slab count from ``perfmodel.optimal_chunks``
    (finer slabs need no extra links, but they decide how many messages
    the NICs must post per fold, which is what the per-engine message
    overhead of the chunk model prices).
    """
    topology: str           # "switched" | "torus"
    p: int
    r: int
    f_mhz: float
    engine: str = ""        # TransposeEngine this fabric is sized for
    chunks: int = 0         # model-optimal slab count (0 = problem unknown)

    @classmethod
    def for_spec(cls, spec: EngineSpec, p: int, r: int, f_mhz: float,
                 *, n=None, mu: int = 1, pu: int = 0, pv: int = 0,
                 pu_axes=None, pv_axes=None) -> "NetworkPlan":
        """Fabric sizing for an :class:`~repro.core.engine_spec.EngineSpec`.

        With a problem size ``n`` (int or (nx, ny, nz)), the plan also
        carries the engine-aware optimal ``chunks`` — the slab count the
        NIC schedule should run at on this fabric. Pass the actual pencil
        grid via ``pu``/``pv`` (must multiply to ``p``); by default the
        closest-to-square factorization of ``p`` is used (exactly √P×√P
        when ``p`` is a perfect square, e.g. 8 → 4×2). On ≥2D meshes the
        per-mesh-axis factorizations ``pu_axes``/``pv_axes`` price each
        staged per-axis ring round separately.
        """
        topo = spec.fabric
        if pu or pv:
            if pu * pv != p:
                raise ValueError(f"pu*pv must equal p, got {pu}x{pv} != {p}")
        else:
            pv = next(q for q in range(max(int(math.isqrt(p)), 1), 0, -1)
                      if p % q == 0)
            pu = p // pv
        chunks = 0
        if n is not None:
            chunks = pm.optimal_chunks(n, pu, pv, spec=spec, mu=mu,
                                       r=r, f_hz=f_mhz * 1e6,
                                       pu_axes=pu_axes, pv_axes=pv_axes)
        return cls(topology=topo, p=p, r=r, f_mhz=f_mhz, engine=spec.engine,
                   chunks=chunks)

    @property
    def message_overhead_s(self) -> float:
        """Exposed per-message cost of the engine this plan serves (falls
        back to the fabric's serial engine when built without one). Uses
        the measured value when a ``repro.tuning.calibrate`` run is active
        on this substrate, else the built-in prior."""
        return pm.message_overhead_s(self.engine or self.topology)

    @property
    def nics_per_node(self) -> int:
        """Fig. 5.9/5.10: 4 links for the torus, 2 for the switched grid."""
        return 4 if self.topology == "torus" else 2

    @property
    def required_bw_bytes_s(self) -> float:
        fn = pm.b_net_switched if self.topology == "switched" else pm.b_net_torus
        return fn(self.p, self.r, self.f_mhz * 1e6)

    @property
    def required_bw_gbit_s(self) -> float:
        return self.required_bw_bytes_s * 8.0 / 1e9

    def fits(self, link_gbps: float) -> bool:
        return self.required_bw_gbit_s <= link_gbps

    @property
    def n_switches(self) -> int:
        """2·√P row/column switches for the switched mesh, 0 for the torus."""
        return 0 if self.topology == "torus" else 2 * int(math.sqrt(self.p))


def bandwidth_curves(topology: str, r_values=(1, 2, 4), freqs_mhz=FREQS_MHZ,
                     sqrt_p_values=range(2, 33)):
    """The curves of Fig. 5.11 (switched) / Fig. 5.12 (torus): required
    network bandwidth (Gbit/s) vs grid side √P, per (R, f)."""
    curves = {}
    for r in r_values:
        for f in freqs_mhz:
            curves[(r, f)] = [
                (q, NetworkPlan(topology, q * q, r, f).required_bw_gbit_s)
                for q in sqrt_p_values
            ]
    return curves


def scalability_summary(link_gbps: float = 200.0):
    """The thesis' conclusion quantified: torus is fine for √P ≤ 4; the
    switched fabric scales to √P ≤ 32 (32-port full-bisection switches)."""
    out = {}
    for topo in ("switched", "torus"):
        for r in (1, 2, 4):
            for f in FREQS_MHZ:
                out[(topo, r, f)] = pm.max_scalable_p(
                    r, f * 1e6, link_gbps * 1e9, topology=topo, sq_max=32)
    return out
