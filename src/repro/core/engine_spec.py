"""EngineSpec — the one way to say *how* the transposes run.

Historically the engine configuration leaked through three surfaces with
three spellings: ``comm.make_engine(name, grid, chunks, backend=..,
real=..)``, ``topology.NetworkPlan.for_engine(engine, ..., n=...)`` and the
kwarg tail of ``fft3d.make_fft3d`` (``backend=``, ``schedule=``,
``chunks=``, ``net=``, ``comm_engine=``, ``vector_mode=``,
``r2c_packed=``).  :class:`EngineSpec` collapses them into one frozen
dataclass consumed uniformly by ``core.comm`` (:func:`~repro.core.comm.
build_engine`), ``core.fft3d`` (``make_fft3d(..., spec=...)``),
``core.perfmodel`` (``estimate_plan_seconds(..., spec=...)``),
``core.topology`` (``NetworkPlan.for_spec``) and ``tuning.space``
(``Candidate.spec()`` / ``Candidate.from_spec``).

Migration table (old → new; the old spellings were removed after a
deprecation cycle)::

    comm.make_engine(name, grid, k, backend=b, real=r)
        → comm.build_engine(EngineSpec(engine=name, chunks=k,
                                       backend=b, real=r), grid)
    NetworkPlan.for_engine(name, p, r, f, n=n)
        → NetworkPlan.for_spec(EngineSpec(engine=name), p, r, f, n=n)
    make_fft3d(mesh, n, comm_engine=e, backend=b, schedule=s, chunks=k)
        → make_fft3d(mesh, n, spec=EngineSpec(engine=e, backend=b,
                                              schedule=s, chunks=k))
    engine.fold_phase(compute, arrs, fold="xy", slab_axis=-2)
        → engine.run_fold(step, compute, arrs) with a decomposition.CommStep

This module is deliberately **jax-free** (like ``core.perfmodel``, which
imports it): specs must be constructible in planning tools and on hosts
without an accelerator stack.
"""

from __future__ import annotations

import dataclasses

# Which network fabric each comm engine presumes (paper §4.2/§5.5): the
# switched engine models the Eq. 5.2 switched fabric, every ring engine the
# Eq. 5.3/5.4 torus.  Single source of truth for comm/perfmodel/topology.
ENGINE_FABRIC = {
    "switched": "switched",
    "torus": "torus",
    "overlap_ring": "torus",
    "pallas_ring": "torus",
    "bidi_ring": "torus",
}

SCHEDULES = ("sequential", "pipelined")
VECTOR_MODES = ("streaming", "parallel")
BACKENDS = ("jnp", "ref", "pallas", "mxu")


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """How the distributed transposes (and the compute between them) run.

    ``engine``      registered comm engine name (``ENGINE_FABRIC`` keys)
    ``backend``     1D-FFT compute backend (``jnp``/``ref``/``pallas``/``mxu``)
    ``schedule``    ``sequential`` or ``pipelined`` (chunked overlap)
    ``chunks``      pipeline depth; forced to 1 under ``sequential``
    ``real``        r2c data model (real input, Hermitian spectrum)
    ``r2c_packed``  pack the real transform into the half-spectrum layout
    ``vector_mode`` multi-component transforms: ``streaming`` or ``parallel``
    ``fused_roundtrip``  stream the Y↔Z roundtrip of diagonal spectral
                    operators as one slab pipeline (fold k+1 ∥ kernel k ∥
                    unfold k−1) instead of three barriered phases
    """

    engine: str = "switched"
    backend: str = "jnp"
    schedule: str = "sequential"
    chunks: int = 1
    real: bool = False
    r2c_packed: bool = False
    vector_mode: str = "streaming"
    fused_roundtrip: bool = False

    def __post_init__(self):
        if self.engine not in ENGINE_FABRIC:
            raise ValueError(f"unknown comm engine {self.engine!r}; "
                             f"have {sorted(ENGINE_FABRIC)}")
        if self.schedule not in SCHEDULES:
            raise ValueError(f"schedule must be one of {SCHEDULES}, "
                             f"got {self.schedule!r}")
        if self.vector_mode not in VECTOR_MODES:
            raise ValueError(f"vector_mode must be one of {VECTOR_MODES}, "
                             f"got {self.vector_mode!r}")
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")
        if self.schedule == "sequential" and self.chunks != 1:
            object.__setattr__(self, "chunks", 1)

    @property
    def fabric(self) -> str:
        """The network fabric this engine presumes (``switched``/``torus``)."""
        return ENGINE_FABRIC[self.engine]

    def replace(self, **changes) -> "EngineSpec":
        return dataclasses.replace(self, **changes)


DEFAULT_SPEC = EngineSpec()
