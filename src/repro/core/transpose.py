"""Distributed pencil transposes — the paper's "fold communications" (§3.2.4).

Two network models, mirroring §5.5:

* ``mode="switched"`` — a single ``lax.all_to_all`` along the processor-grid
  axis. This is the 2D switched fabric of Fig. 5.10: XLA lowers it to one
  full-bisection exchange; required bandwidth follows Eq. 5.5.
* ``mode="torus"``   — a ring algorithm of P−1 ``lax.ppermute`` rounds, round
  r carrying the block destined r hops away. On a TPU torus a shift-by-r
  collective-permute is routed over r ICI hops, reproducing the multi-hop
  degradation of Eq. 5.6 / Fig. 5.12 (APEnet-style DOR routing).

The torus ring also comes in a **bidirectional** flavor
(:func:`ring_exchange_bidi`): the paper's NIC drives both torus directions
at once (Fig. 5.9 — every node has a +u and a −u link), so the exchange
splits its blocks into a clockwise and a counter-clockwise stream and ships
one block per direction per round, finishing in ``ceil((P−1)/2)`` rounds
instead of P−1 (:func:`bidi_rounds` vs :func:`ring_rounds`).

All functions run *inside* ``shard_map`` over the FFT mesh axes. This module
is the shared block-exchange layer; scheduling (chunking, compute overlap)
belongs to the TransposeEngine implementations in ``core.comm``.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
from jax import lax

from repro import compat, obs

MODES = ("switched", "torus")


_flat_axis_index = compat.flat_axis_index
_axis_size = compat.axes_size
_ppermute = lax.ppermute   # one wire-hop primitive (patchable in unit tests)


def _meter_exchange(axes, p: int, rounds: int, arrs, *,
                    dispatch_kind: str, dispatches: int) -> None:
    """Trace-time wire accounting of one single-axis block exchange.

    Runs while jit traces the shard_map body, so it fires once per
    *compilation* from one rank's SPMD view — the analytically pinnable
    quantities: ``comm.exchange_rounds.<axis>`` (wire rounds this exchange
    costs), ``comm.exchanges.<axis>`` (exchange invocations, so tests can
    divide out chunking), ``comm.wire_bytes`` (bytes this rank ships:
    (p−1)/p of the payload), and per-primitive dispatch counters
    (``comm.ppermute_dispatches`` / ``comm.all_to_all_dispatches`` /
    ``comm.rdma_dispatches``). Shapes/dtypes are static under tracing, so
    this is pure Python on ints — and a no-op branch when obs is disabled.
    """
    if not obs.is_enabled():
        return
    ax = "*".join(axes)
    obs.metrics.inc(f"comm.exchanges.{ax}")
    obs.metrics.inc(f"comm.exchange_rounds.{ax}", rounds)
    obs.metrics.inc(f"comm.{dispatch_kind}_dispatches", dispatches)
    payload = sum(int(a.size) * a.dtype.itemsize for a in arrs)
    obs.metrics.inc("comm.wire_bytes", payload * (p - 1) // p)


def axis_sizes(axes) -> tuple[int, ...]:
    """Per-mesh-axis bound sizes of a tuple of axis names (static ints)."""
    return tuple(_axis_size((a,)) for a in axes)


def comm_axis_sizes(axes) -> tuple[int, ...]:
    """Sizes of the axes that actually communicate (size > 1).

    The per-axis ring round model sums over exactly these: a grid dimension
    spanning mesh axes of sizes (q₀, …) costs Σᵢ ``wire_rounds(qᵢ)`` rounds,
    not ``wire_rounds(Πqᵢ)``.
    """
    return tuple(q for q in axis_sizes(axes) if q > 1)


def ring_rounds(p: int) -> int:
    """Exchange rounds of the unidirectional ring: P−1 (Fig. 5.9, one NIC)."""
    return max(p - 1, 0)


def bidi_rounds(p: int) -> int:
    """Exchange rounds of the bidirectional ring: ``ceil((P−1)/2)``.

    Both torus directions carry one block per round; when P is even the
    farthest block (P/2 hops either way) goes clockwise only, which is
    exactly what makes ``ceil((P−1)/2) == P//2``. P=2 degenerates to one
    round — both directions name the same neighbor.
    """
    return max(p, 1) // 2


def all_to_all_blocks(x, axes: tuple[str, ...], *, split_axis: int,
                      concat_axis: int, mode: str = "switched"):
    """Exchange P equal blocks of ``x`` (split along ``split_axis``) so block
    j goes to rank j; received blocks concatenate along ``concat_axis``
    ordered by source rank. ``tiled`` all-to-all semantics."""
    assert mode in MODES, mode
    axes = tuple(axes)
    if not axes:  # Pu (or Pv) == 1: the exchange degenerates to identity
        return x
    if mode == "switched":
        name = axes if len(axes) > 1 else axes[0]
        _meter_exchange(axes, _axis_size(axes), 1, (x,),
                        dispatch_kind="all_to_all", dispatches=1)
        return lax.all_to_all(x, name, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)
    return _ring_all_to_all(x, axes, split_axis=split_axis,
                            concat_axis=concat_axis)


def stack_blocks(x, p: int, split_axis: int):
    """Cut ``x`` into P blocks along ``split_axis``, stacked on a fresh
    leading axis: (P, ..., blk, ...). Shared by every ring implementation
    (``ring_exchange`` below and the Pallas RDMA ring of
    ``kernels.ring_rdma``) so their wire layouts are identical."""
    n = x.shape[split_axis]
    assert n % p == 0, (n, p)
    xs = x.reshape(x.shape[:split_axis] + (p, n // p)
                   + x.shape[split_axis + 1:])
    return jnp.moveaxis(xs, split_axis, 0)


def merge_blocks(o, p: int, concat_axis: int):
    """Inverse of the receive side: fold the leading rank axis of ``o`` into
    ``concat_axis`` in rank-major block order (tiled all_to_all semantics)."""
    o = jnp.moveaxis(o, 0, concat_axis)
    return o.reshape(o.shape[:concat_axis]
                     + (p * o.shape[concat_axis + 1],)
                     + o.shape[concat_axis + 2:])


def staged_exchange(arrs, axes, *, split_axis: int, concat_axis: int,
                    exchange, interleave=None, **first_stage_kw):
    """Factor one tiled all-to-all over several mesh axes into sequential
    **per-axis** exchanges — the multi-axis contract of every ring engine.

    A flat ring over the product group Π qᵢ would route most hops across
    pods; running one ring per mesh axis keeps every hop a single-axis
    neighbor exchange (the wafer-scale "all communication is local" layout
    of Orenes-Vera et al.) and costs Σᵢ ``rounds(qᵢ)`` instead of
    ``rounds(Πqᵢ)`` rounds. Because :func:`compat.flat_axis_index` is
    row-major over ``axes``, staging the single-axis exchanges innermost
    axis first reproduces the flat tiled all-to-all **bit-exactly**: the
    result equals ``exchange(arrs, axes, ...)`` over the whole tuple.

    ``exchange(arrs, (axis,), *, split_axis, concat_axis, **kw)`` is the
    single-axis primitive (:func:`ring_exchange`, :func:`ring_exchange_bidi`,
    or the RDMA kernels of ``kernels.ring_rdma``). ``interleave`` and any
    ``first_stage_kw`` (e.g. a fusable RDMA ``payload``) ride the first
    executed stage only — later stages exchange already-transformed blocks.
    """
    axes = tuple(axes)
    sizes = axis_sizes(axes)
    p = math.prod(sizes)
    k = len(axes)
    xss = [stack_blocks(x, p, split_axis) for x in arrs]
    # leading flat-rank axis -> one axis per mesh axis, row-major like the
    # flat rank index, so axis i of the block grid addresses mesh axis i
    xss = [x.reshape(sizes + x.shape[1:]) for x in xss]
    follow, first = None, True
    for i in reversed(range(k)):
        if sizes[i] <= 1:
            continue
        cur = tuple(jnp.moveaxis(x, i, 0) for x in xss)
        kw = dict(first_stage_kw) if first else {}
        if first and interleave is not None:
            kw["interleave"] = interleave
        outs, fl = exchange(cur, (axes[i],), split_axis=0, concat_axis=0, **kw)
        if first:
            follow, first = fl, False
        xss = [jnp.moveaxis(o, 0, i) for o in outs]
    xss = [x.reshape((p,) + x.shape[k:]) for x in xss]
    return [merge_blocks(x, p, concat_axis) for x in xss], follow


def ring_exchange(arrs, axes, *, split_axis: int, concat_axis: int,
                  interleave=None):
    """P−1 ppermute rounds over same-shaped ``arrs``; round r ships the block
    for rank (me+r) mod P. The single ring primitive every ring engine shares
    (``torus`` and ``overlap_ring`` in ``core.comm`` — one implementation, so
    their relayouts cannot drift apart).

    When ``axes`` spans several communicating mesh axes the exchange is
    staged per axis (:func:`staged_exchange`): one ring per mesh axis, bit
    exact vs the flat multi-axis ring but with only neighbor hops per stage.

    ``interleave()`` — compute that is data-independent of the in-flight
    blocks — is emitted right after the first round's sends, so XLA's
    scheduler can run it underneath the remaining P−2 rounds (the
    block-granular overlap of paper Fig. 4.3). Returns
    ``(outs, interleave_result)``; the result is None when no callback ran.
    """
    axes = tuple(axes)
    if len(comm_axis_sizes(axes)) > 1:
        comm_axes = tuple(a for a, q in zip(axes, axis_sizes(axes)) if q > 1)
        return staged_exchange(arrs, comm_axes, split_axis=split_axis,
                               concat_axis=concat_axis, exchange=ring_exchange,
                               interleave=interleave)
    p = _axis_size(axes)
    me = _flat_axis_index(axes)
    name = axes if len(axes) > 1 else axes[0]
    _meter_exchange(axes, p, ring_rounds(p), arrs,
                    dispatch_kind="ppermute",
                    dispatches=ring_rounds(p) * len(arrs))

    xss = [stack_blocks(x, p, split_axis) for x in arrs]
    # own block stays local
    outs = [lax.dynamic_update_index_in_dim(
        jnp.zeros_like(xs),
        lax.dynamic_index_in_dim(xs, me, axis=0, keepdims=True), me, axis=0)
        for xs in xss]
    follow = None
    for r in range(1, p):
        perm = [(i, (i + r) % p) for i in range(p)]
        recvs = [_ppermute(
            lax.dynamic_index_in_dim(xs, (me + r) % p, axis=0, keepdims=True),
            name, perm) for xs in xss]
        if follow is None and interleave is not None:
            follow = interleave()
        outs = [lax.dynamic_update_index_in_dim(o, recv, (me - r) % p, axis=0)
                for o, recv in zip(outs, recvs)]

    return [merge_blocks(o, p, concat_axis) for o in outs], follow


def ring_exchange_bidi(arrs, axes, *, split_axis: int, concat_axis: int,
                       interleave=None):
    """The ring exchange over *both* torus directions at once (Fig. 5.9).

    Round r ships the block for rank (me+r) mod P clockwise and the block
    for rank (me−r) mod P counter-clockwise — two counter-rotating
    ``ppermute`` streams on opposite links, so all P−1 foreign blocks are
    on the wire after ``bidi_rounds(P) == ceil((P−1)/2)`` rounds instead of
    P−1. When P is even, the farthest block (r == P−r) is shared between
    the directions and goes clockwise only. Same contract, block order, and
    rank-major merge as :func:`ring_exchange` — the relayout is
    bit-identical; only the schedule (and the round count) changes.

    Multi-axis tuples stage per axis like :func:`ring_exchange`, with both
    directions driven within every stage.
    """
    axes = tuple(axes)
    if len(comm_axis_sizes(axes)) > 1:
        comm_axes = tuple(a for a, q in zip(axes, axis_sizes(axes)) if q > 1)
        return staged_exchange(arrs, comm_axes, split_axis=split_axis,
                               concat_axis=concat_axis,
                               exchange=ring_exchange_bidi,
                               interleave=interleave)
    p = _axis_size(axes)
    me = _flat_axis_index(axes)
    name = axes if len(axes) > 1 else axes[0]
    # ppermute dispatches: one clockwise stream per round, plus the
    # counter-clockwise stream except the shared-farthest-block round
    ccw = bidi_rounds(p) - (1 if p % 2 == 0 else 0)
    _meter_exchange(axes, p, bidi_rounds(p), arrs,
                    dispatch_kind="ppermute",
                    dispatches=(bidi_rounds(p) + ccw) * len(arrs))

    xss = [stack_blocks(x, p, split_axis) for x in arrs]
    # own block stays local
    outs = [lax.dynamic_update_index_in_dim(
        jnp.zeros_like(xs),
        lax.dynamic_index_in_dim(xs, me, axis=0, keepdims=True), me, axis=0)
        for xs in xss]
    follow = None
    for r in range(1, bidi_rounds(p) + 1):
        # clockwise stream: block me+r over the +r direction
        perm_cw = [(i, (i + r) % p) for i in range(p)]
        recvs_cw = [_ppermute(
            lax.dynamic_index_in_dim(xs, (me + r) % p, axis=0, keepdims=True),
            name, perm_cw) for xs in xss]
        # counter-clockwise stream: block me−r over the −r direction,
        # concurrently on the opposite links (skipped when it would be the
        # clockwise block again: P even, r == P−r)
        recvs_ccw = None
        if r != p - r:
            perm_ccw = [(i, (i - r) % p) for i in range(p)]
            recvs_ccw = [_ppermute(
                lax.dynamic_index_in_dim(xs, (me - r) % p, axis=0,
                                         keepdims=True),
                name, perm_ccw) for xs in xss]
        if follow is None and interleave is not None:
            follow = interleave()
        outs = [lax.dynamic_update_index_in_dim(o, recv, (me - r) % p, axis=0)
                for o, recv in zip(outs, recvs_cw)]
        if recvs_ccw is not None:
            outs = [lax.dynamic_update_index_in_dim(o, recv, (me + r) % p,
                                                    axis=0)
                    for o, recv in zip(outs, recvs_ccw)]

    return [merge_blocks(o, p, concat_axis) for o in outs], follow


def _ring_all_to_all(x, axes, *, split_axis: int, concat_axis: int):
    outs, _ = ring_exchange((x,), axes, split_axis=split_axis,
                            concat_axis=concat_axis)
    return outs[0]


# ---------------------------------------------------------------------------
# The two fold communications of the 3D FFT (hardware tasks C and G, §4.2).
# All operate on the LAST THREE axes; arbitrary leading (batch / μ-component)
# axes pass through untouched — this is what the paper's "parallel vector
# processing" (§4.4.1) rides on.
# ---------------------------------------------------------------------------

def permute_last3(a, perm: tuple[int, int, int]):
    """Apply a permutation of the LAST THREE axes; leading axes untouched.

    This is the ``CommStep.permute`` executor: ``(2, 1, 0)`` is the X↔Y
    fold's transpose (`_swap_last3`), ``(0, 2, 1)`` the Y↔Z fold's
    (`_swap_last2`).
    """
    d = a.ndim
    return a.transpose(tuple(range(d - 3)) + tuple(d - 3 + i for i in perm))


def _swap_last3(a):
    perm = tuple(range(a.ndim - 3)) + (a.ndim - 1, a.ndim - 2, a.ndim - 3)
    return a.transpose(perm)


def _swap_last2(a):
    perm = tuple(range(a.ndim - 3)) + (a.ndim - 3, a.ndim - 1, a.ndim - 2)
    return a.transpose(perm)


def xy_fold(a, u_axes, *, mode="switched"):
    """X-pencil → Y-pencil: (..., Ny/Pu, Nz/Pv, Kx) → (..., Kx/Pu, Nz/Pv, Ny).

    Data moves only among the Pu ranks of the same processor-grid row
    (§3.2.6) — rows and columns never exchange traffic.
    """
    d = a.ndim
    b = all_to_all_blocks(a, u_axes, split_axis=d - 1, concat_axis=d - 3, mode=mode)
    return _swap_last3(b)


def xy_unfold(a, u_axes, *, mode="switched"):
    """Y-pencil → X-pencil (inverse of xy_fold)."""
    d = a.ndim
    b = _swap_last3(a)  # (..., Ny, Nz/Pv, Kx/Pu)
    return all_to_all_blocks(b, u_axes, split_axis=d - 3, concat_axis=d - 1, mode=mode)


def yz_fold(a, v_axes, *, mode="switched"):
    """Y-pencil → Z-pencil: (..., Kx/Pu, Nz/Pv, Ny) → (..., Kx/Pu, Ny/Pv, Nz).

    Moves along the Pv ranks of the same grid column.
    """
    d = a.ndim
    b = all_to_all_blocks(a, v_axes, split_axis=d - 1, concat_axis=d - 2, mode=mode)
    return _swap_last2(b)


def yz_unfold(a, v_axes, *, mode="switched"):
    """Z-pencil → Y-pencil (inverse of yz_fold)."""
    d = a.ndim
    b = _swap_last2(a)  # (..., Kx/Pu, Nz, Ny/Pv)
    return all_to_all_blocks(b, v_axes, split_axis=d - 2, concat_axis=d - 1, mode=mode)
