"""Fault-tolerant checkpointing: atomic (tmp + rename) sharded saves, async
writer thread, keep-last-k GC, and **resharding restore** — a checkpoint
written on mesh A restores onto mesh B (elastic up/down-scaling), because
leaves are stored as full logical arrays and re-placed with the target
shardings at load.

Failure semantics (what ``repro.fleet`` leans on):

* a save is visible only after the atomic rename — a writer killed or
  raising mid-write leaves a ``step_*.tmp`` turd that :meth:`latest_step`
  and GC ignore, never a half-checkpoint;
* an exception in the **async** writer thread is captured, not swallowed:
  the next :meth:`wait` (or the implicit one at the head of the next
  :meth:`save`) re-raises it as :class:`CheckpointError`, so a failed save
  cannot masquerade as success;
* a torn ``LATEST`` pointer (or a pointer at an incomplete directory)
  falls back to scanning for the newest *complete* step directory.

Layout:  <dir>/step_<n>/   manifest.json  +  arrays.npz (flat path-keyed)
         <dir>/LATEST      (atomic pointer file)
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

from repro import obs


class CheckpointError(RuntimeError):
    """A (possibly async) checkpoint write failed; the save did not land."""


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self.last_save_bytes = 0       # host bytes of the latest save
        os.makedirs(directory, exist_ok=True)

    # ---- save -------------------------------------------------------------
    def save(self, step: int, tree, meta: dict | None = None, block: bool = False):
        """Snapshot to host memory synchronously, write to disk async.

        Raises :class:`CheckpointError` if a *previous* async write failed
        (before starting this one), or — with ``block=True`` or
        ``async_write=False`` — if this write fails."""
        flat, _ = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device->host copy
        self.last_save_bytes = sum(a.nbytes for a in host.values())
        obs.metrics.inc("checkpoint.saves")
        obs.metrics.inc("checkpoint.bytes", self.last_save_bytes)
        self.wait()                    # re-raises a prior async failure
        if not self.async_write:
            try:
                self._write(step, host, meta or {})
            except BaseException as e:
                obs.metrics.inc("checkpoint.write_errors")
                raise CheckpointError(
                    f"checkpoint write failed: "
                    f"{type(e).__name__}: {e}") from e
            return
        self._thread = threading.Thread(
            target=self._write_guarded, args=(step, host, meta or {}),
            daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        """Join the in-flight async write; re-raise its failure, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            obs.metrics.inc("checkpoint.write_errors")
            raise CheckpointError(
                f"async checkpoint write failed: "
                f"{type(err).__name__}: {err}") from err

    def _write_guarded(self, step: int, host: dict, meta: dict):
        try:
            self._write(step, host, meta)
        except BaseException as e:     # surfaces on the next wait()/save()
            self._error = e

    def _write(self, step: int, host: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        meta = dict(meta, step=step, time=time.time())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        ptr = os.path.join(self.dir, "LATEST.tmp")
        with open(ptr, "w") as f:
            f.write(os.path.basename(final))
        os.replace(ptr, os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir) if d.startswith("step_")
                       and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---- restore ------------------------------------------------------------
    def _complete_steps(self) -> list[int]:
        """Step numbers with a complete (manifest-bearing) directory."""
        out = []
        for d in os.listdir(self.dir):
            if not d.startswith("step_") or d.endswith(".tmp"):
                continue
            if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        ptr = os.path.join(self.dir, "LATEST")
        if os.path.exists(ptr):
            with open(ptr) as f:
                name = f.read().strip()
            if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                return int(name.split("_")[1])
        # torn pointer or incomplete dir — scan for the newest complete step
        steps = self._complete_steps()
        return steps[-1] if steps else None

    def restore(self, target_tree, step: int | None = None,
                shardings=None) -> tuple:
        """Restore into the structure of ``target_tree``; device_put with
        ``shardings`` (same structure) if given — this is the elastic path."""
        step = self.latest_step() if step is None else step
        assert step is not None, f"no checkpoint under {self.dir}"
        t0 = time.monotonic()
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        z = np.load(os.path.join(d, "arrays.npz"))
        flat, treedef = _flatten(target_tree)
        leaves = []
        shard_flat = None
        if shardings is not None:
            shard_flat, _ = _flatten(shardings)
        for k, tgt in flat.items():
            a = z[k]
            assert tuple(a.shape) == tuple(tgt.shape), (k, a.shape, tgt.shape)
            a = a.astype(tgt.dtype)
            if shard_flat is not None and shard_flat.get(k) is not None:
                a = jax.device_put(a, shard_flat[k])
            leaves.append(a)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        obs.metrics.inc("checkpoint.restores")
        obs.metrics.set_gauge("checkpoint.restore_us",
                              (time.monotonic() - t0) * 1e6)
        return tree, meta
