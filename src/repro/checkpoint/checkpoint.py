"""Fault-tolerant checkpointing: atomic (tmp + rename) sharded saves, async
writer thread, keep-last-k GC, and **resharding restore** — a checkpoint
written on mesh A restores onto mesh B (elastic up/down-scaling), because
leaves are stored as full logical arrays and re-placed with the target
shardings at load.

Layout:  <dir>/step_<n>/   manifest.json  +  arrays.npz (flat path-keyed)
         <dir>/LATEST      (atomic pointer file)
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ---- save -------------------------------------------------------------
    def save(self, step: int, tree, meta: dict | None = None, block: bool = False):
        """Snapshot to host memory synchronously, write to disk async."""
        flat, _ = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device->host copy
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host, meta or {}), daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        meta = dict(meta, step=step, time=time.time())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        ptr = os.path.join(self.dir, "LATEST.tmp")
        with open(ptr, "w") as f:
            f.write(os.path.basename(final))
        os.replace(ptr, os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir) if d.startswith("step_")
                       and not d.endswith(".tmp"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---- restore ------------------------------------------------------------
    def latest_step(self) -> int | None:
        ptr = os.path.join(self.dir, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            name = f.read().strip()
        if not os.path.exists(os.path.join(self.dir, name, "manifest.json")):
            return None  # torn write — fall back to scan
        return int(name.split("_")[1])

    def restore(self, target_tree, step: int | None = None,
                shardings=None) -> tuple:
        """Restore into the structure of ``target_tree``; device_put with
        ``shardings`` (same structure) if given — this is the elastic path."""
        step = self.latest_step() if step is None else step
        assert step is not None, f"no checkpoint under {self.dir}"
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        z = np.load(os.path.join(d, "arrays.npz"))
        flat, treedef = _flatten(target_tree)
        leaves = []
        shard_flat = None
        if shardings is not None:
            shard_flat, _ = _flatten(shardings)
        for k, tgt in flat.items():
            a = z[k]
            assert tuple(a.shape) == tuple(tgt.shape), (k, a.shape, tgt.shape)
            a = a.astype(tgt.dtype)
            if shard_flat is not None and shard_flat.get(k) is not None:
                a = jax.device_put(a, shard_flat[k])
            leaves.append(a)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, meta
