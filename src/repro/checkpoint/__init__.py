"""Fault-tolerant sharded checkpointing (atomic saves, resharding restore)."""
