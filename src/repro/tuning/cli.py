"""Autotuner command line.

    PYTHONPATH=src python -m repro.tuning.cli --n 64 --mesh 4x2
    PYTHONPATH=src python -m repro.tuning.cli --n 16 --mesh 4x2 \\
        --case navier_stokes --dtype float64
    PYTHONPATH=src python -m repro.tuning.cli --n 32 --mesh 4x2 \\
        --trace tune.trace.json    # tune/ span per timed candidate

Sweeps the ``FFT3DPlan`` space for the given problem on a Pu×Pv device mesh
(host devices are faked to Pu·Pv when the machine has fewer — the flag is set
before the XLA backend initializes), writes the winner to the persistent plan
cache, and emits the measured sweep as ``BENCH_fft.json`` rows
(``{name, us_per_call, config}``) for the CI perf-trajectory artifact.
A second invocation with the same problem is a cache hit and times nothing.

``--case <solver>`` switches the objective from the bare transform to a
registered ``repro.solvers`` case's *whole step* (µs/step; the real/
components shape then comes from the solver class, and ``--fwd-weight/
--inv-weight`` don't apply).
"""

from __future__ import annotations

import argparse
import json
import os
import sys


#: current bench-document schema: v2 rows may carry ``p50_us``/``p95_us``
#: (tail timing) and ``model_predicted_us``/``model_err`` (perf-model drift)
#: next to ``us_per_call``; readers accept both generations
BENCH_SCHEMA = "bench-fft/v2"
BENCH_SCHEMAS = ("bench-fft/v1", BENCH_SCHEMA)


def write_bench_json(path: str, rows: list, meta: dict) -> None:
    """Write/merge ``BENCH_fft.json``: same-name rows are replaced in place.

    Always writes the current schema; an existing v1 document's rows are
    merged and carried forward into the upgraded document.
    """
    doc = {"schema": BENCH_SCHEMA, "meta": meta, "rows": []}
    try:
        with open(path) as f:
            old = json.load(f)
        if old.get("schema") in BENCH_SCHEMAS and isinstance(old.get("rows"), list):
            doc["rows"] = [r for r in old["rows"]
                           if r.get("name") not in {x["name"] for x in rows}]
            doc["meta"] = {**old.get("meta", {}), **meta}
    except (FileNotFoundError, json.JSONDecodeError):
        pass
    doc["rows"].extend(rows)
    tmp = path + f".tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.tuning.cli",
        description="Autotune the distributed 3D-FFT plan for one problem.")
    ap.add_argument("--n", type=int, default=64, help="cubic grid extent N")
    ap.add_argument("--mesh", default="4x2", help="Pu x Pv pencil grid, e.g. 4x2")
    ap.add_argument("--case", default="",
                    help="tune a repro.solvers case's whole step instead of "
                         "the bare transform (poisson | heat | "
                         "navier_stokes | nls)")
    ap.add_argument("--real", action="store_true", help="real-to-complex input")
    ap.add_argument("--components", type=int, default=0,
                    help="μ vector components (0 = scalar field)")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--iters", type=int, default=3, help="timed calls/candidate")
    ap.add_argument("--fwd-weight", type=float, default=1.0,
                    help="objective weight of the forward transform time")
    ap.add_argument("--inv-weight", type=float, default=1.0,
                    help="objective weight of the inverse transform time "
                         "(0 = forward-only tuning)")
    ap.add_argument("--max-candidates", type=int, default=8,
                    help="model-pruned sweep size (default plan always added)")
    ap.add_argument("--cache", default=None,
                    help="plan-cache path (default: $REPRO_PLAN_CACHE or "
                         "~/.cache/repro/fft_plans.json)")
    ap.add_argument("--json", dest="json_path", default="BENCH_fft.json",
                    help="benchmark-rows output ('' disables)")
    ap.add_argument("--force", action="store_true",
                    help="ignore any cached plan and re-time")
    ap.add_argument("--trace", dest="trace_path", default="",
                    help="write a Chrome-trace JSON (Perfetto-loadable) of "
                         "the sweep: one tune/candidate span per timed "
                         "candidate plus the wire/cache counters")
    args = ap.parse_args(argv)

    if args.trace_path:
        from repro import obs
        obs.clear()
        obs.enable()

    from repro.launch.mesh import ensure_host_devices, parse_mesh_arg
    pu, pv = parse_mesh_arg(args.mesh)
    ensure_host_devices(pu * pv)

    import jax

    from repro import compat
    from repro.core import precision
    from repro.tuning import autotune
    from repro.tuning.autotune import speedup_vs_default

    if len(jax.devices()) < pu * pv:
        raise SystemExit(f"need {pu * pv} devices for mesh {args.mesh}, "
                         f"have {len(jax.devices())}")
    if args.case:
        import numpy as np
        if np.dtype(args.dtype).itemsize >= 8:
            precision.enable_x64()  # solver construction refuses silent f32
    mesh = compat.make_mesh((pu, pv), ("data", "model"))
    objective = (f"{args.case} step" if args.case else
                 f"{args.fwd_weight:g}*t_fwd+{args.inv_weight:g}*t_inv")
    print(f"autotune: N={args.n}^3 mesh={pu}x{pv} real={args.real} "
          f"components={args.components} dtype={args.dtype} "
          f"objective={objective} "
          f"[{jax.devices()[0].platform}:{len(jax.devices())} devices]",
          flush=True)
    try:
        if args.case:
            from repro.tuning.solver import autotune_solver_step
            result = autotune_solver_step(
                mesh, args.case, args.n, dtype=args.dtype,
                cache_path=args.cache, max_candidates=args.max_candidates,
                iters=args.iters, force=args.force, verbose=True)
        else:
            result = autotune(mesh, args.n, real=args.real,
                              components=args.components, dtype=args.dtype,
                              cache_path=args.cache,
                              max_candidates=args.max_candidates,
                              iters=args.iters, force=args.force,
                              fwd_weight=args.fwd_weight,
                              inv_weight=args.inv_weight, verbose=True)
    except ValueError as e:  # e.g. N not divisible by the pencil grid
        raise SystemExit(f"invalid problem for mesh {args.mesh}: {e}")

    from repro.tuning.cache import PlanCache

    src = "cache HIT (nothing re-timed)" if result.cache_hit else "measured sweep"
    unit = "us/step" if args.case else "us/call"
    print(f"selected [{src}]: {result.best.name}  {result.best_us:.1f} {unit}")
    sp = speedup_vs_default(result)
    if sp == sp:  # not nan
        print(f"speedup vs default (jnp/seq/switched): {sp:.2f}x")
    print(f"plan cache: {PlanCache(args.cache).path}  key={result.key}")

    if args.json_path:
        prefix = f"autotune/{result.key}"
        rows = [{"name": f"{prefix}/{r['name']}",
                 "us_per_call": r["us_per_call"], "config": r["config"]}
                for r in result.rows]
        rows.append({"name": f"{prefix}/selected",
                     "us_per_call": result.best_us,
                     "config": result.best_config})
        meta = {"jax": jax.__version__,
                "platform": jax.devices()[0].platform,
                "device_kind": jax.devices()[0].device_kind,
                "devices": len(jax.devices()),
                "argv": list(argv) if argv is not None else sys.argv[1:]}
        write_bench_json(args.json_path, rows, meta)
        print(f"wrote {args.json_path} ({len(rows)} rows)")
    if args.trace_path:
        from repro import obs
        obs.disable()
        obs.write_chrome_trace(args.trace_path, obs.tracer, obs.metrics)
        print(f"wrote trace {args.trace_path} "
              f"({len(obs.tracer.events())} spans)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
