"""Autotuner command line.

    PYTHONPATH=src python -m repro.tuning.cli --n 64 --mesh 4x2

Sweeps the ``FFT3DPlan`` space for the given problem on a Pu×Pv device mesh
(host devices are faked to Pu·Pv when the machine has fewer — the flag is set
before the XLA backend initializes), writes the winner to the persistent plan
cache, and emits the measured sweep as ``BENCH_fft.json`` rows
(``{name, us_per_call, config}``) for the CI perf-trajectory artifact.
A second invocation with the same problem is a cache hit and times nothing.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _parse_mesh(text: str) -> tuple[int, int]:
    try:
        pu, pv = (int(t) for t in text.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh must look like 4x2, got {text!r}")
    return pu, pv


def write_bench_json(path: str, rows: list, meta: dict) -> None:
    """Write/merge ``BENCH_fft.json``: same-name rows are replaced in place."""
    doc = {"schema": "bench-fft/v1", "meta": meta, "rows": []}
    try:
        with open(path) as f:
            old = json.load(f)
        if old.get("schema") == doc["schema"] and isinstance(old.get("rows"), list):
            doc["rows"] = [r for r in old["rows"]
                           if r.get("name") not in {x["name"] for x in rows}]
            doc["meta"] = {**old.get("meta", {}), **meta}
    except (FileNotFoundError, json.JSONDecodeError):
        pass
    doc["rows"].extend(rows)
    tmp = path + f".tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.tuning.cli",
        description="Autotune the distributed 3D-FFT plan for one problem.")
    ap.add_argument("--n", type=int, default=64, help="cubic grid extent N")
    ap.add_argument("--mesh", default="4x2", help="Pu x Pv pencil grid, e.g. 4x2")
    ap.add_argument("--real", action="store_true", help="real-to-complex input")
    ap.add_argument("--components", type=int, default=0,
                    help="μ vector components (0 = scalar field)")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--iters", type=int, default=3, help="timed calls/candidate")
    ap.add_argument("--fwd-weight", type=float, default=1.0,
                    help="objective weight of the forward transform time")
    ap.add_argument("--inv-weight", type=float, default=1.0,
                    help="objective weight of the inverse transform time "
                         "(0 = forward-only tuning)")
    ap.add_argument("--max-candidates", type=int, default=8,
                    help="model-pruned sweep size (default plan always added)")
    ap.add_argument("--cache", default=None,
                    help="plan-cache path (default: $REPRO_PLAN_CACHE or "
                         "~/.cache/repro/fft_plans.json)")
    ap.add_argument("--json", dest="json_path", default="BENCH_fft.json",
                    help="benchmark-rows output ('' disables)")
    ap.add_argument("--force", action="store_true",
                    help="ignore any cached plan and re-time")
    args = ap.parse_args(argv)

    pu, pv = _parse_mesh(args.mesh)
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={pu * pv} " + flags)

    import jax

    from repro import compat
    from repro.tuning import autotune
    from repro.tuning.autotune import speedup_vs_default

    if len(jax.devices()) < pu * pv:
        raise SystemExit(f"need {pu * pv} devices for mesh {args.mesh}, "
                         f"have {len(jax.devices())}")
    mesh = compat.make_mesh((pu, pv), ("data", "model"))
    print(f"autotune: N={args.n}^3 mesh={pu}x{pv} real={args.real} "
          f"components={args.components} dtype={args.dtype} "
          f"objective={args.fwd_weight:g}*t_fwd+{args.inv_weight:g}*t_inv "
          f"[{jax.devices()[0].platform}:{len(jax.devices())} devices]",
          flush=True)
    try:
        result = autotune(mesh, args.n, real=args.real,
                          components=args.components, dtype=args.dtype,
                          cache_path=args.cache,
                          max_candidates=args.max_candidates,
                          iters=args.iters, force=args.force,
                          fwd_weight=args.fwd_weight,
                          inv_weight=args.inv_weight, verbose=True)
    except ValueError as e:  # e.g. N not divisible by the pencil grid
        raise SystemExit(f"invalid problem for mesh {args.mesh}: {e}")

    from repro.tuning.cache import PlanCache

    src = "cache HIT (nothing re-timed)" if result.cache_hit else "measured sweep"
    print(f"selected [{src}]: {result.best.name}  {result.best_us:.1f} us/call")
    sp = speedup_vs_default(result)
    if sp == sp:  # not nan
        print(f"speedup vs default (jnp/seq/switched): {sp:.2f}x")
    print(f"plan cache: {PlanCache(args.cache).path}  key={result.key}")

    if args.json_path:
        prefix = f"autotune/{result.key}"
        rows = [{"name": f"{prefix}/{r['name']}",
                 "us_per_call": r["us_per_call"], "config": r["config"]}
                for r in result.rows]
        rows.append({"name": f"{prefix}/selected",
                     "us_per_call": result.best_us,
                     "config": result.best_config})
        meta = {"jax": jax.__version__,
                "platform": jax.devices()[0].platform,
                "device_kind": jax.devices()[0].device_kind,
                "devices": len(jax.devices()),
                "argv": list(argv) if argv is not None else sys.argv[1:]}
        write_bench_json(args.json_path, rows, meta)
        print(f"wrote {args.json_path} ({len(rows)} rows)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
