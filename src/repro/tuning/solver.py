"""Solver-step autotuning: pick the FFT plan by timing the *whole* step.

The bare-transform objective (``tuning.autotune``) weights forward and
inverse times, but a real workload's step also contains the spectral and
local stages, runs a case-specific mix of transforms (Navier–Stokes: three
vector transforms per RK substage; Poisson: one round trip), and exposes
different overlap opportunities to XLA. ``autotune_solver_step`` therefore
scores each candidate plan by building the actual
:class:`repro.solvers.SpectralSolver` on it and timing its jitted
``step`` — closing the ROADMAP item "tune the Navier–Stokes step
end-to-end rather than the bare transform".

Winners persist in the same plan cache, fingerprinted with the solver
``case`` and its physics params, so a step-tuned plan is never confused
with a bare-transform one (or another case's).
"""

from __future__ import annotations

import datetime

from repro import obs
from repro.core import perfmodel as pm
from repro.core.decomposition import PencilGrid
from repro.tuning.autotune import TuneResult, _estimate
from repro.tuning.cache import PlanCache, problem_fingerprint
from repro.tuning.space import DEFAULT_CANDIDATE, Candidate, candidate_space
from repro.tuning.timing import time_us


def _has_diagonal_kernel(cls) -> bool:
    """Whether the solver class declares a pointwise-diagonal spectral
    kernel (overrides ``SpectralSolver.spectral_kernel``) — the gate for
    sweeping the fused-roundtrip executor on its step."""
    from repro.solvers.base import SpectralSolver

    return cls.spectral_kernel is not SpectralSolver.spectral_kernel


def time_solver_step(mesh, case: str, n, cand: Candidate, *,
                     dtype="float64", params: dict | None = None,
                     iters: int = 3) -> float:
    """Measured µs per solver step for one candidate plan (compile excluded).

    Builds the solver on the candidate's plan config, initializes state
    once, and times the jitted step function on the sharded fields.
    """
    from repro.solvers import make_solver

    solver = make_solver(case, mesh, n, dtype=dtype,
                         plan_cfg=cand.config(), **(params or {}))
    state = solver.init_state()
    return time_us(solver._stepj, state.fields, iters=iters)


def autotune_solver_step(mesh, case: str, n, *, dtype="float64",
                         params: dict | None = None,
                         cache_path: str | None = None,
                         max_candidates: int = 6, iters: int = 3,
                         force: bool = False,
                         verbose: bool = False) -> TuneResult:
    """Pick the fastest ``FFT3DPlan`` for one solver case's full step.

    Same discipline as the bare-transform sweep: enumerate the valid plan
    space for the case's transform shape (real/complex, μ components),
    rank analytically, time the top ``max_candidates`` plus the hardcoded
    default, persist the winner keyed by a fingerprint that includes the
    case and its physics params. ``iters`` < 1, unknown cases, and a dtype
    this process cannot actually compute in (float64 with x64 off — the
    same gate solver construction applies) all fail fast. Solvers always
    decompose over the default ``("data", "model")`` mesh axes.
    """
    from repro.core import precision
    from repro.solvers import SOLVERS

    if case not in SOLVERS:
        raise ValueError(f"unknown solver case {case!r}; "
                         f"have {sorted(SOLVERS)}")
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    cls = SOLVERS[case]
    n = (n, n, n) if isinstance(n, int) else tuple(n)
    grid = PencilGrid.from_mesh(mesh)
    grid.validate(n)
    params = dict(params or {})
    dtype = precision.require_dtype(dtype, who="autotune_solver_step").name
    key, problem = problem_fingerprint(
        n, grid.pu, grid.pv, real=cls.real, components=cls.components,
        dtype=dtype, case=case, solver_params=params)
    cache = PlanCache(cache_path)
    if not force:
        entry = cache.get(key)
        if entry is not None:
            return TuneResult(best_config=entry["best"],
                              best_us=entry["us_per_call"], cache_hit=True,
                              key=key, rows=entry.get("rows", []))

    diagonal = _has_diagonal_kernel(cls)
    cands = candidate_space(n, grid.pu, grid.pv, real=cls.real,
                            components=cls.components, fused=diagonal)
    # the analytic transform model ranks candidates; the per-step transform
    # count is plan-independent, so the constant factor cancels in the order.
    # Diagonal-kernel cases rank on the roundtrip estimate instead, which
    # prices the fused executor's hidden kernel sweep (fused ≤ composed).
    if diagonal:
        cands.sort(key=lambda c: pm.estimate_roundtrip_seconds(
            n, grid.pu, grid.pv, spec=c.spec(real=cls.real),
            mu=max(cls.components, 1),
            pu_axes=grid.u_sizes, pv_axes=grid.v_sizes))
    else:
        cands.sort(key=lambda c: _estimate(c, n, grid, cls.components))
    keep = cands[:max(max_candidates, 1)]
    if DEFAULT_CANDIDATE not in keep:
        keep.append(DEFAULT_CANDIDATE)

    rows = []
    for cand in keep:
        try:
            with obs.span("tune/candidate", candidate=cand.name, case=case,
                          problem=key) if obs.is_enabled() else obs.NULL_SPAN:
                us = time_solver_step(mesh, case, n, cand, dtype=dtype,
                                      params=params, iters=iters)
            obs.metrics.inc("tuning.candidates_timed")
        except Exception as e:  # invalid on this substrate — drop, keep going
            if verbose:
                print(f"  tune {case}/{cand.name}: FAILED "
                      f"({type(e).__name__}: {e})")
            continue
        rows.append({"name": cand.name, "us_per_call": round(us, 3),
                     "config": cand.config()})
        if verbose:
            print(f"  tune {case}/{cand.name}: {us:.1f} us/step")
    if not rows:
        raise RuntimeError(f"autotune_solver_step: no candidate ran for "
                           f"problem {key}")

    best = min(rows, key=lambda r: r["us_per_call"])
    entry = {
        "problem": problem,
        "best": best["config"],
        "best_name": best["name"],
        "us_per_call": best["us_per_call"],
        "rows": rows,
        "created": datetime.datetime.now(datetime.timezone.utc).isoformat(),
    }
    cache.put(key, entry)
    return TuneResult(best_config=best["config"],
                      best_us=best["us_per_call"], cache_hit=False, key=key,
                      rows=rows)
