"""Measured calibration of the analytic performance model.

    PYTHONPATH=src python -m repro.tuning.calibrate [--quick] [--mesh 4x2]
    PYTHONPATH=src python -m repro.tuning.calibrate --quick --mesh 4x2 \\
        --trace calib.trace.json   # tune/ span per measurement stage

The perf model's pruning constants — ``ENGINE_MESSAGE_OVERHEAD_S`` (exposed
per-message dispatch cost of each TransposeEngine) and
``BACKEND_COMPUTE_WEIGHT`` (relative butterfly cost of each FFT backend) —
shipped as hand-tuned priors, which ROADMAP flagged as unmeasured: on a real
substrate the model can mis-rank autotuner candidates. This module measures
both tables with microbenchmarks on the *current* substrate and persists
them as a fingerprinted ``calibration.json`` (same discipline as the plan
cache: a calibration is only ever replayed on the exact substrate that
produced it — JAX version, platform, device kind, device count).

Once written, the calibration is picked up lazily by
``perfmodel.message_overhead_s`` / ``perfmodel.backend_compute_weight`` and
therefore flows through ``estimate_plan_seconds``, ``optimal_chunks`` /
``chunk_candidates``, ``tuning.space`` candidate enumeration, and
``topology.NetworkPlan`` — the hardcoded tables remain as fallback priors
for engines/backends the run could not measure.

Measurement method:

* **engine message overhead** — each engine's X↔Y fold is timed at two
  payload sizes through the real ``shard_map`` path; the per-message cost
  is the zero-payload extrapolation ``t(0)/messages`` of the linear model
  ``t(bytes) = overhead + bytes/bw`` (so wire bandwidth cancels out and
  only the dispatch/latency part remains). Needs a communicating mesh —
  on a 1×1 grid nothing can be measured and the priors stand.
* **wire bandwidth** — the *slope* of the same two-size fit,
  ``(bytes₂ − bytes₁)/(t₂ − t₁)``, is the bytes-per-second the fold
  actually moved; the median over the measured engines is persisted as
  ``link_bytes_per_s`` and consumed by ``perfmodel.link_bytes_per_s`` —
  the wire term of every ``estimate_plan_seconds`` /
  ``estimate_roundtrip_seconds`` / ``optimal_chunks`` query.
* **backend compute weight** — each backend's 1D c2c transform is timed on
  an identical planar batch; the weight is the ratio to ``jnp`` (XLA's
  native FFT, the 1.0 reference, exactly as the priors are normalized).

File location: ``$REPRO_CALIBRATION`` or ``~/.cache/repro/calibration.json``
(one document per substrate — writing atomically replaces the previous one).
"""

from __future__ import annotations

import argparse
import datetime
import json
import math
import os
import statistics

SCHEMA = "fft-calibration/v1"
ENV_VAR = "REPRO_CALIBRATION"

#: substrate identity keys a calibration must match to be replayed
FINGERPRINT_KEYS = ("jax_version", "platform", "device_kind", "device_count")

#: floor for a measured per-message overhead: the zero-payload extrapolation
#: is noise-sensitive, and a non-positive fit means the measurement carries
#: no signal (fall back to the prior rather than persisting nonsense)
MIN_OVERHEAD_S = 1e-9

#: floor for a measured backend weight (jnp is the 1.0 reference)
MIN_WEIGHT = 1e-3


def default_calibration_path() -> str:
    """``$REPRO_CALIBRATION`` if set, else ``~/.cache/repro/calibration.json``."""
    env = os.environ.get(ENV_VAR)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "calibration.json")


def substrate_fingerprint() -> dict:
    """Canonical identity of the measurement substrate (cf. the plan cache:
    a calibration must never be replayed where it would not transfer)."""
    import jax

    dev = jax.devices()[0]
    return {
        "jax_version": jax.__version__,
        "platform": dev.platform,
        "device_kind": dev.device_kind,
        "device_count": len(jax.devices()),
    }


# ---------------------------------------------------------------------------
# microbenchmarks
# ---------------------------------------------------------------------------

def measure_backend_weights(*, rows: int = 64, length: int = 256,
                            iters: int = 5, verbose: bool = False) -> dict:
    """Measured ``BACKEND_COMPUTE_WEIGHT`` replacement: per-backend 1D c2c
    wall time over an identical planar batch, normalized to ``jnp``.

    Backends that fail on this substrate are skipped (their priors stand).
    Returns ``{}`` when the ``jnp`` reference itself cannot be timed.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops as kops
    from repro.tuning.timing import time_us

    rng = np.random.RandomState(0)
    xr = jnp.asarray(rng.randn(rows, length).astype(np.float32))
    xi = jnp.zeros_like(xr)
    times: dict[str, float] = {}
    for backend in kops.BACKENDS:
        fn = jax.jit(lambda a, b, bk=backend: kops.fft1d(a, b, backend=bk))
        try:
            times[backend] = time_us(fn, xr, xi, iters=iters)
        except Exception as e:  # backend invalid here — keep its prior
            if verbose:
                print(f"  calibrate backend {backend}: FAILED "
                      f"({type(e).__name__}: {e})")
            continue
        if verbose:
            print(f"  calibrate backend {backend}: "
                  f"{times[backend]:.1f} us", flush=True)
    base = times.get("jnp")
    if not base or base <= 0:
        return {}
    return {b: max(round(t / base, 4), MIN_WEIGHT) for b, t in times.items()}


def _fold_sizes(pu: int, pv: int) -> tuple[int, int]:
    """Two pencil-divisible cubic extents for the zero-payload fit."""
    m = math.lcm(max(pu, 1), max(pv, 1))
    n1 = m * max(1, -(-8 // m))  # smallest multiple of m that is >= 8
    return n1, 2 * n1


def measure_engine_overheads(mesh, *, iters: int = 5,
                             verbose: bool = False) -> tuple[dict, float]:
    """Measured ``ENGINE_MESSAGE_OVERHEAD_S`` replacement, plus the wire
    bandwidth the same fit yields.

    Times every registered TransposeEngine's X↔Y fold (the real
    ``shard_map``-compiled exchange) at two payload sizes and extrapolates
    to zero payload: ``t(bytes) = c + bytes/bw`` gives the size-independent
    dispatch cost ``c = messages · t_msg`` as the intercept — and the
    bytes-per-second actually moved, ``bw = Δbytes/Δt``, as the slope.
    Returns ``(overheads, link_bytes_per_s)`` where the bandwidth is the
    median slope over the measured engines (0.0 when nothing measured).
    Engines whose fit is non-positive (noise) or that fail to build are
    skipped; a non-communicating mesh returns ``({}, 0.0)`` (nothing to
    measure — the priors stand).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import compat
    from repro.core import comm
    from repro.core import perfmodel as pm
    from repro.core.decomposition import PencilGrid
    from repro.tuning.timing import time_us

    grid = PencilGrid.from_mesh(mesh)
    if grid.pu <= 1:  # the X<->Y fold moves data along the Pu ranks only
        return {}, 0.0
    n1, n2 = _fold_sizes(grid.pu, grid.pv)
    spec = grid.pencil_spec()
    rng = np.random.RandomState(0)
    out: dict[str, float] = {}
    slopes: list[float] = []
    for name in comm.ENGINE_NAMES:
        msgs = pm.fold_messages(grid.pu, pm.ENGINE_FABRIC[name], name)
        if msgs <= 0:
            continue
        eng = comm.build_engine(comm.EngineSpec(engine=name), grid)
        fold = jax.jit(compat.shard_map(
            lambda a, e=eng: e.fold_xy(a), mesh=mesh, in_specs=(spec,),
            out_specs=spec, check_vma=False))
        try:
            ts = []
            for n in (n1, n2):
                x = jnp.asarray(rng.randn(n, n, n).astype(np.float32))
                ts.append(time_us(fold, x, iters=iters) * 1e-6)
        except Exception as e:  # engine invalid here — keep its prior
            if verbose:
                print(f"  calibrate engine {name}: FAILED "
                      f"({type(e).__name__}: {e})")
            continue
        b1, b2 = float(n1) ** 3 * 4, float(n2) ** 3 * 4
        t0 = ts[0] - b1 * (ts[1] - ts[0]) / (b2 - b1)  # zero-payload intercept
        t_msg = t0 / msgs
        slope = (b2 - b1) / (ts[1] - ts[0]) if ts[1] > ts[0] else 0.0
        if verbose:
            print(f"  calibrate engine {name}: t({n1}^3)={ts[0] * 1e6:.1f}us "
                  f"t({n2}^3)={ts[1] * 1e6:.1f}us -> "
                  f"t_msg={t_msg * 1e6:.3f}us ({msgs} msgs) "
                  f"bw={slope / 1e9:.2f} GB/s", flush=True)
        if t_msg >= MIN_OVERHEAD_S:
            out[name] = float(f"{t_msg:.3e}")
        if slope > 0 and math.isfinite(slope):
            slopes.append(slope)
    link = statistics.median(slopes) if slopes else 0.0
    return out, float(f"{link:.3e}") if link > 0 else 0.0


# ---------------------------------------------------------------------------
# document IO (mirrors the plan cache's atomic-write discipline)
# ---------------------------------------------------------------------------

def run_calibration(mesh, *, quick: bool = False, iters: int | None = None,
                    verbose: bool = False) -> dict:
    """Run both microbenchmarks and assemble the calibration document."""
    from repro.core.decomposition import PencilGrid

    from repro import obs

    if iters is None:
        iters = 2 if quick else 5
    rows, length = (16, 64) if quick else (64, 256)
    grid = PencilGrid.from_mesh(mesh)
    with obs.span("tune/calibrate.engines", mesh=f"{grid.pu}x{grid.pv}") \
            if obs.is_enabled() else obs.NULL_SPAN:
        overheads, link = measure_engine_overheads(mesh, iters=iters,
                                                   verbose=verbose)
    with obs.span("tune/calibrate.backends"):
        weights = measure_backend_weights(
            rows=rows, length=length, iters=iters, verbose=verbose)
    doc = {
        "schema": SCHEMA,
        "fingerprint": substrate_fingerprint(),
        "mesh": f"{grid.pu}x{grid.pv}",
        "quick": bool(quick),
        "iters": int(iters),
        "engine_message_overhead_s": overheads,
        "backend_compute_weight": weights,
        "created": datetime.datetime.now(datetime.timezone.utc).isoformat(),
    }
    if link > 0:
        doc["link_bytes_per_s"] = link
    return doc


def validate_calibration(doc) -> list[str]:
    """Well-formedness problems of a calibration document ([] = valid).

    Valid means: right schema, a complete substrate fingerprint, both
    measurement tables present as dicts of positive finite floats over
    *known* engine/backend names, an optional ``link_bytes_per_s`` scalar
    that is positive and finite when present, and at least one measured
    value overall (an all-empty calibration carries no signal worth
    persisting).
    """
    from repro.core import perfmodel as pm
    from repro.kernels.ops import BACKENDS

    problems = []
    if not isinstance(doc, dict):
        return [f"not a JSON object: {type(doc).__name__}"]
    if doc.get("schema") != SCHEMA:
        problems.append(f"schema: expected {SCHEMA!r}, got {doc.get('schema')!r}")
    fp = doc.get("fingerprint")
    if not isinstance(fp, dict):
        problems.append("fingerprint: missing or not an object")
    else:
        for key in FINGERPRINT_KEYS:
            if not fp.get(key):
                problems.append(f"fingerprint.{key}: missing or empty")
    known = {"engine_message_overhead_s": set(pm.ENGINE_MESSAGE_OVERHEAD_S),
             "backend_compute_weight": set(BACKENDS)}
    measured = 0
    for table, names in known.items():
        vals = doc.get(table)
        if not isinstance(vals, dict):
            problems.append(f"{table}: missing or not an object")
            continue
        for name, v in vals.items():
            if name not in names:
                problems.append(f"{table}.{name}: unknown name")
            elif not isinstance(v, (int, float)) or isinstance(v, bool) \
                    or not math.isfinite(v) or v <= 0:
                problems.append(f"{table}.{name}: not a positive finite "
                                f"number: {v!r}")
            else:
                measured += 1
    link = doc.get("link_bytes_per_s")
    if link is not None:
        if not isinstance(link, (int, float)) or isinstance(link, bool) \
                or not math.isfinite(link) or link <= 0:
            problems.append(f"link_bytes_per_s: not a positive finite "
                            f"number: {link!r}")
        else:
            measured += 1
    if not problems and measured == 0:
        problems.append("no measured values in either table")
    return problems


def save_calibration(doc: dict, path: str | None = None) -> str:
    """Atomically write ``doc`` (tmp file + ``os.replace``, like the plan
    cache) and return the path written."""
    path = path or default_calibration_path()
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + f".tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path


def load_calibration(path: str | None = None) -> dict | None:
    """The raw document at ``path`` (default location), or None."""
    path = path or default_calibration_path()
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError, OSError):
        return None


def load_active_calibration(path: str | None = None) -> dict | None:
    """The calibration the perf model should consult on *this* substrate.

    None unless the document exists, is well-formed, and its fingerprint
    matches the current process exactly — a calibration measured under a
    different JAX/platform/device configuration must not transfer (the
    plan-cache discipline). This is what ``perfmodel.active_calibration``
    loads lazily on first use.
    """
    doc = load_calibration(path)
    if doc is None or validate_calibration(doc):
        return None
    if doc["fingerprint"] != substrate_fingerprint():
        return None
    return doc


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.tuning.calibrate",
        description="Measure per-engine message overheads and per-backend "
                    "compute weights on this substrate and persist them as "
                    "a fingerprinted calibration.json the perf model "
                    "prefers over its built-in priors.")
    ap.add_argument("--mesh", default="4x2",
                    help="Pu x Pv pencil grid to measure the fold exchanges "
                         "on (host devices are faked up to Pu*Pv)")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode: fewer iterations, smaller batches")
    ap.add_argument("--iters", type=int, default=None,
                    help="timed calls per measurement (default 5, quick 2)")
    ap.add_argument("--out", default=None,
                    help="output path (default: $REPRO_CALIBRATION or "
                         "~/.cache/repro/calibration.json)")
    ap.add_argument("--trace", dest="trace_path", default="",
                    help="write a Chrome-trace JSON (Perfetto-loadable) of "
                         "the calibration run: one tune/ span per timed "
                         "measurement stage")
    args = ap.parse_args(argv)

    if args.trace_path:
        from repro import obs
        obs.clear()
        obs.enable()

    from repro.launch.mesh import ensure_host_devices, parse_mesh_arg
    pu, pv = parse_mesh_arg(args.mesh)
    ensure_host_devices(pu * pv)

    import jax

    from repro import compat
    from repro.core import perfmodel as pm

    if len(jax.devices()) < pu * pv:
        raise SystemExit(f"need {pu * pv} devices for mesh {args.mesh}, "
                         f"have {len(jax.devices())}")
    mesh = compat.make_mesh((pu, pv), ("data", "model"))
    print(f"calibrate: mesh={pu}x{pv} quick={args.quick} "
          f"[{jax.devices()[0].platform}:{len(jax.devices())} devices]",
          flush=True)
    doc = run_calibration(mesh, quick=args.quick, iters=args.iters,
                          verbose=True)
    if args.trace_path:
        from repro import obs
        obs.disable()
        obs.write_chrome_trace(args.trace_path, obs.tracer, obs.metrics)
        print(f"wrote trace {args.trace_path} "
              f"({len(obs.tracer.events())} spans)")
    problems = validate_calibration(doc)
    if problems:
        print("calibration NOT written — measurement produced an invalid "
              "document:")
        for p in problems:
            print(f"  {p}")
        return 2
    path = save_calibration(doc, args.out)
    if load_active_calibration(path) is None:
        print(f"calibration at {path} failed the replay check "
              "(fingerprint/round-trip mismatch)")
        return 2

    print(f"wrote {path}")
    for engine, t in sorted(doc["engine_message_overhead_s"].items()):
        prior = pm.ENGINE_MESSAGE_OVERHEAD_S[engine]
        print(f"  message overhead {engine:<13} {t * 1e6:8.3f} us  "
              f"(prior {prior * 1e6:.3f} us)")
    for backend, w in sorted(doc["backend_compute_weight"].items()):
        prior = pm.BACKEND_COMPUTE_WEIGHT.get(backend, 1.0)
        print(f"  compute weight   {backend:<13} {w:8.3f}     "
              f"(prior {prior:.1f})")
    link = doc.get("link_bytes_per_s")
    if link:
        print(f"  wire bandwidth   {'median slope':<13} "
              f"{link / 1e9:8.2f} GB/s (prior "
              f"{pm.LINK_BYTES_PER_S / 1e9:.1f} GB/s)")
    # this process measured fresh values — let its own model use them too
    pm.set_calibration(doc)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
