"""The autotuner: prune with the paper's model, time the survivors, cache.

``autotune(mesh, n, ...)`` is the programmatic entry point (used by
``make_fft3d(..., autotune=True)``); ``repro.tuning.cli`` wraps it for the
command line.
"""

from __future__ import annotations

import dataclasses
import datetime
import math

import numpy as np

from repro.core import perfmodel as pm
from repro.core.decomposition import PencilGrid
from repro.tuning.cache import PlanCache, problem_fingerprint
from repro.tuning.space import DEFAULT_CANDIDATE, Candidate, candidate_space
from repro.tuning.timing import time_us


@dataclasses.dataclass(frozen=True)
class TuneResult:
    best_config: dict          # kwargs subset for make_fft3d / FFT3DPlan
    best_us: float
    cache_hit: bool
    key: str
    rows: list                 # [{"name", "us_per_call", "config"}] timed sweep

    @property
    def best(self) -> Candidate:
        return Candidate.from_config(self.best_config)


def _estimate(cand: Candidate, n, grid: PencilGrid, components: int) -> float:
    return pm.estimate_plan_seconds(
        n, grid.pu, grid.pv, backend=cand.backend, schedule=cand.schedule,
        chunks=cand.chunks, net=cand.net, mu=max(components, 1),
        r2c_packed=cand.r2c_packed)


def time_candidate(mesh, n, cand: Candidate, *, real: bool = False,
                   components: int = 0, dtype="float32",
                   u_axes=("data",), v_axes=("model",), iters: int = 3) -> float:
    """Measured µs/forward-transform for one candidate (compile excluded)."""
    import jax.numpy as jnp

    from repro.core.fft3d import make_fft3d

    fwd, _inv, _plan = make_fft3d(
        mesh, n, u_axes=u_axes, v_axes=v_axes, real=real,
        components=components, backend=cand.backend, schedule=cand.schedule,
        chunks=cand.chunks, net=cand.net, vector_mode=cand.vector_mode,
        r2c_packed=cand.r2c_packed)
    nx, ny, nz = n
    shape = ((components,) if components else ()) + (ny, nz, nx)
    rng = np.random.RandomState(0)
    xr = jnp.asarray(rng.randn(*shape).astype(np.dtype(dtype)))
    if real:
        return time_us(fwd, xr, iters=iters)
    xi = jnp.zeros_like(xr)
    return time_us(fwd, xr, xi, iters=iters)


def autotune(mesh, n, *, real: bool = False, components: int = 0,
             dtype="float32", u_axes=("data",), v_axes=("model",),
             cache_path: str | None = None, max_candidates: int = 8,
             iters: int = 3, force: bool = False,
             verbose: bool = False) -> TuneResult:
    """Pick the fastest ``FFT3DPlan`` configuration for this problem.

    The sweep is ranked by the paper's analytic model and only the top
    ``max_candidates`` (plus the hardcoded default, which is always timed so
    the winner is never slower than the status quo) are measured. Results
    persist in the JSON plan cache; a repeat call with the same fingerprint
    returns without timing anything. ``force=True`` re-times and overwrites.
    """
    import jax

    n = (n, n, n) if isinstance(n, int) else tuple(n)
    grid = PencilGrid.from_mesh(mesh, u_axes, v_axes)
    grid.validate(n)
    # fingerprint the dtype JAX will actually compute in (x64 disabled
    # silently demotes float64 — the cache must not claim otherwise)
    dtype = str(jax.dtypes.canonicalize_dtype(np.dtype(dtype)))
    key, problem = problem_fingerprint(
        n, grid.pu, grid.pv, real=real, components=components, dtype=dtype,
        u_axes=u_axes, v_axes=v_axes)
    cache = PlanCache(cache_path)
    if not force:
        entry = cache.get(key)
        if entry is not None:
            return TuneResult(best_config=entry["best"],
                              best_us=entry["us_per_call"], cache_hit=True,
                              key=key, rows=entry.get("rows", []))

    cands = candidate_space(n, grid.pu, grid.pv, real=real,
                            components=components)
    cands.sort(key=lambda c: _estimate(c, n, grid, components))
    keep = cands[:max(max_candidates, 1)]
    if DEFAULT_CANDIDATE not in keep:
        keep.append(DEFAULT_CANDIDATE)

    rows = []
    for cand in keep:
        try:
            us = time_candidate(mesh, n, cand, real=real,
                                components=components, dtype=dtype,
                                u_axes=u_axes, v_axes=v_axes, iters=iters)
        except Exception as e:  # invalid on this substrate — drop, keep going
            if verbose:
                print(f"  tune {cand.name}: FAILED ({type(e).__name__}: {e})")
            continue
        rows.append({"name": cand.name, "us_per_call": round(us, 3),
                     "config": cand.config()})
        if verbose:
            print(f"  tune {cand.name}: {us:.1f} us")
    if not rows:
        raise RuntimeError(f"autotune: no candidate ran for problem {key}")

    best = min(rows, key=lambda r: r["us_per_call"])
    entry = {
        "problem": problem,
        "best": best["config"],
        "best_name": best["name"],
        "us_per_call": best["us_per_call"],
        "rows": rows,
        "created": datetime.datetime.now(datetime.timezone.utc).isoformat(),
    }
    cache.put(key, entry)
    return TuneResult(best_config=best["config"],
                      best_us=best["us_per_call"], cache_hit=False, key=key,
                      rows=rows)


def speedup_vs_default(result: TuneResult) -> float:
    """Measured default-plan time / best time (≥ 1.0 when the sweep timed
    the default; ``nan`` on a cache hit whose rows were not stored)."""
    for row in result.rows:
        if Candidate.from_config(row["config"]) == DEFAULT_CANDIDATE:
            return row["us_per_call"] / max(result.best_us, 1e-9)
    return math.nan
