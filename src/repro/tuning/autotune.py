"""The autotuner: prune with the paper's model, time the survivors, cache.

``autotune(mesh, n, ...)`` is the programmatic entry point (used by
``make_fft3d(..., autotune=True)``); ``repro.tuning.cli`` wraps it for the
command line.

The objective is inverse-aware: ``w_fwd·t_fwd + w_inv·t_inv`` (default 1:1 —
a spectral solver's time step runs both directions, Fig. 3.3). Set
``inv_weight=0`` to tune the forward transform alone; the weights are part
of the cache fingerprint, so differently-weighted tunings never collide.
"""

from __future__ import annotations

import dataclasses
import datetime
import math

import numpy as np

from repro import obs
from repro.core import perfmodel as pm
from repro.core.decomposition import PencilGrid
from repro.tuning.cache import PlanCache, problem_fingerprint
from repro.tuning.space import DEFAULT_CANDIDATE, Candidate, candidate_space
from repro.tuning.timing import time_us


@dataclasses.dataclass(frozen=True)
class TuneResult:
    best_config: dict          # kwargs subset for make_fft3d / FFT3DPlan
    best_us: float             # weighted objective of the winner (µs)
    cache_hit: bool
    key: str
    rows: list                 # [{"name", "us_per_call", "us_fwd", "us_inv",
                               #   "config"}] timed sweep

    @property
    def best(self) -> Candidate:
        return Candidate.from_config(self.best_config)


def _estimate(cand: Candidate, n, grid: PencilGrid, components: int) -> float:
    return pm.estimate_plan_seconds(
        n, grid.pu, grid.pv, spec=cand.spec(), mu=max(components, 1),
        pu_axes=grid.u_sizes, pv_axes=grid.v_sizes)


def time_candidate_pair(mesh, n, cand: Candidate, *, real: bool = False,
                        components: int = 0, dtype="float32",
                        u_axes=("data",), v_axes=("model",), iters: int = 3,
                        time_inverse: bool = True) -> tuple[float, float]:
    """Measured ``(us_fwd, us_inv)`` for one candidate (compile excluded).

    The plan is built and jitted once; the inverse is timed on the spectral
    field the forward warm-up already produced (``us_inv = 0.0`` when
    ``time_inverse`` is off).
    """
    import jax.numpy as jnp

    from repro.core.fft3d import make_fft3d

    fwd, inv, _plan = make_fft3d(
        mesh, n, u_axes=u_axes, v_axes=v_axes, real=real,
        components=components, spec=cand.spec(real=real))
    nx, ny, nz = n
    shape = ((components,) if components else ()) + (ny, nz, nx)
    rng = np.random.RandomState(0)
    xr = jnp.asarray(rng.randn(*shape).astype(np.dtype(dtype)))
    args = (xr,) if real else (xr, jnp.zeros_like(xr))
    us_fwd = time_us(fwd, *args, iters=iters)
    us_inv = 0.0
    if time_inverse:
        us_inv = time_us(inv, *fwd(*args), iters=iters)
    return us_fwd, us_inv


def time_candidate(mesh, n, cand: Candidate, *, inverse: bool = False,
                   **kw) -> float:
    """Measured µs/transform in one direction (see ``time_candidate_pair``)."""
    us_fwd, us_inv = time_candidate_pair(mesh, n, cand, time_inverse=inverse,
                                         **kw)
    return us_inv if inverse else us_fwd


def autotune(mesh, n, *, real: bool = False, components: int = 0,
             dtype="float32", u_axes=("data",), v_axes=("model",),
             cache_path: str | None = None, max_candidates: int = 8,
             iters: int = 3, force: bool = False,
             fwd_weight: float = 1.0, inv_weight: float = 1.0,
             verbose: bool = False) -> TuneResult:
    """Pick the fastest ``FFT3DPlan`` configuration for this problem.

    The sweep is ranked by the paper's analytic model and only the top
    ``max_candidates`` (plus the hardcoded default, which is always timed so
    the winner is never slower than the status quo) are measured. Each
    survivor is scored ``fwd_weight·t_fwd + inv_weight·t_inv`` (µs; the
    inverse timing is skipped entirely when ``inv_weight == 0``). Results
    persist in the JSON plan cache; a repeat call with the same fingerprint
    — which includes the weights — returns without timing anything.
    ``force=True`` re-times and overwrites.
    """
    import jax

    if fwd_weight < 0 or inv_weight < 0 or fwd_weight + inv_weight <= 0:
        raise ValueError(f"weights must be non-negative and not both zero, "
                         f"got fwd={fwd_weight} inv={inv_weight}")
    if iters < 1:  # fail before the sweep, not inside every candidate
        raise ValueError(f"iters must be >= 1, got {iters}")
    n = (n, n, n) if isinstance(n, int) else tuple(n)
    grid = PencilGrid.from_mesh(mesh, u_axes, v_axes)
    grid.validate(n)
    # fingerprint the dtype JAX will actually compute in (x64 disabled
    # silently demotes float64 — the cache must not claim otherwise)
    dtype = str(jax.dtypes.canonicalize_dtype(np.dtype(dtype)))
    key, problem = problem_fingerprint(
        n, grid.pu, grid.pv, real=real, components=components, dtype=dtype,
        u_axes=u_axes, v_axes=v_axes,
        fwd_weight=fwd_weight, inv_weight=inv_weight)
    cache = PlanCache(cache_path)
    if not force:
        entry = cache.get(key)
        if entry is not None:
            return TuneResult(best_config=entry["best"],
                              best_us=entry["us_per_call"], cache_hit=True,
                              key=key, rows=entry.get("rows", []))

    cands = candidate_space(n, grid.pu, grid.pv, real=real,
                            components=components,
                            pu_axes=grid.u_sizes, pv_axes=grid.v_sizes)
    cands.sort(key=lambda c: _estimate(c, n, grid, components))
    keep = cands[:max(max_candidates, 1)]
    if DEFAULT_CANDIDATE not in keep:
        keep.append(DEFAULT_CANDIDATE)

    rows = []
    for cand in keep:
        try:
            with obs.span("tune/candidate", candidate=cand.name,
                          problem=key) if obs.is_enabled() else obs.NULL_SPAN:
                us_fwd, us_inv = time_candidate_pair(
                    mesh, n, cand, real=real, components=components,
                    dtype=dtype, u_axes=u_axes, v_axes=v_axes, iters=iters,
                    time_inverse=inv_weight > 0)
            obs.metrics.inc("tuning.candidates_timed")
        except Exception as e:  # invalid on this substrate — drop, keep going
            if verbose:
                print(f"  tune {cand.name}: FAILED ({type(e).__name__}: {e})")
            continue
        objective = fwd_weight * us_fwd + inv_weight * us_inv
        rows.append({"name": cand.name, "us_per_call": round(objective, 3),
                     "us_fwd": round(us_fwd, 3), "us_inv": round(us_inv, 3),
                     "config": cand.config()})
        if verbose:
            print(f"  tune {cand.name}: {objective:.1f} us "
                  f"(fwd {us_fwd:.1f} + inv {us_inv:.1f})")
    if not rows:
        raise RuntimeError(f"autotune: no candidate ran for problem {key}")

    best = min(rows, key=lambda r: r["us_per_call"])
    entry = {
        "problem": problem,
        "best": best["config"],
        "best_name": best["name"],
        "us_per_call": best["us_per_call"],
        "rows": rows,
        "created": datetime.datetime.now(datetime.timezone.utc).isoformat(),
    }
    cache.put(key, entry)
    return TuneResult(best_config=best["config"],
                      best_us=best["us_per_call"], cache_hit=False, key=key,
                      rows=rows)


def speedup_vs_default(result: TuneResult) -> float:
    """Measured default-plan objective / best objective (≥ 1.0 when the sweep
    timed the default; ``nan`` on a cache hit whose rows were not stored)."""
    for row in result.rows:
        if Candidate.from_config(row["config"]) == DEFAULT_CANDIDATE:
            return row["us_per_call"] / max(result.best_us, 1e-9)
    return math.nan
