"""Enumeration of the valid ``FFT3DPlan`` configuration space."""

from __future__ import annotations

import dataclasses

from repro.core.engine_spec import EngineSpec
from repro.core.perfmodel import ENGINE_FABRIC, chunk_candidates
from repro.kernels.ref import is_pow2

CHUNK_CHOICES = (2, 4, 8)       # legacy engine-blind slab counts (no-comm)
ALL_BACKENDS = ("jnp", "ref", "pallas", "mxu")
ALL_ENGINES = tuple(ENGINE_FABRIC)  # kept in sync with core.comm.ENGINE_NAMES


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the plan space — exactly the tunable ``make_fft3d`` knobs."""

    backend: str = "jnp"
    schedule: str = "sequential"
    chunks: int = 1
    comm_engine: str = "switched"
    vector_mode: str = "streaming"
    r2c_packed: bool = False
    fused_roundtrip: bool = False

    @property
    def net(self) -> str:
        """The §5.5 fabric the engine runs on (legacy knob name)."""
        return ENGINE_FABRIC[self.comm_engine]

    @property
    def name(self) -> str:
        sched = "seq" if self.schedule == "sequential" else f"pipe{self.chunks}"
        bits = [self.backend, sched, self.comm_engine, self.vector_mode]
        if self.r2c_packed:
            bits.append("packed")
        if self.fused_roundtrip:
            bits.append("fused")
        return "/".join(bits)

    def config(self) -> dict:
        cfg = dataclasses.asdict(self)
        cfg["net"] = self.net  # derived fabric, kept for older readers
        return cfg

    @classmethod
    def from_config(cls, cfg: dict) -> "Candidate":
        cfg = normalize_config(cfg)
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in cfg.items() if k in fields})

    def spec(self, real: bool = False) -> EngineSpec:
        """The :class:`EngineSpec` this candidate configures."""
        return EngineSpec(engine=self.comm_engine, backend=self.backend,
                          schedule=self.schedule, chunks=self.chunks,
                          real=real, r2c_packed=self.r2c_packed,
                          vector_mode=self.vector_mode,
                          fused_roundtrip=self.fused_roundtrip)

    @classmethod
    def from_spec(cls, spec: EngineSpec) -> "Candidate":
        return cls(backend=spec.backend, schedule=spec.schedule,
                   chunks=spec.chunks, comm_engine=spec.engine,
                   vector_mode=spec.vector_mode, r2c_packed=spec.r2c_packed,
                   fused_roundtrip=spec.fused_roundtrip)


def normalize_config(cfg: dict) -> dict:
    """Copy of ``cfg`` with legacy knobs mapped onto the current ones.

    The one place that knows pre-engine configs (``net`` only, e.g. cache
    entries or bench rows written before the TransposeEngine layer) name
    their engine through the fabric knob.
    """
    cfg = dict(cfg)
    if not cfg.get("comm_engine") and "net" in cfg:
        cfg["comm_engine"] = cfg["net"]
    return cfg


DEFAULT_CANDIDATE = Candidate()  # the hardcoded status quo every caller used


def candidate_space(n, pu: int, pv: int, *, real: bool = False,
                    components: int = 0, backends=None, fused: bool = False,
                    pu_axes=None, pv_axes=None) -> list[Candidate]:
    """All valid candidates for the problem.

    Validity rules:

    * ``ref``/``pallas``/``mxu`` are radix-2 / four-step engines — power-of-two
      axis lengths only (``jnp`` delegates to XLA's general FFT).
    * the ring engines (``torus``/``overlap_ring``/``pallas_ring``) are only
      distinct from ``switched`` when a fold actually communicates
      (Pu > 1 or Pv > 1).
    * pipelined slab counts come from the engine-aware chunk model
      (``perfmodel.chunk_candidates``): each engine contributes its model
      optimum and the neighboring powers of two instead of an engine-blind
      global list — the per-message overhead of e.g. ``pallas_ring``'s
      NIC-doorbell sends supports finer slabs than the XLA rings.
    * on ≥2D meshes the per-mesh-axis factorizations ``pu_axes``/``pv_axes``
      (e.g. ``PencilGrid.u_sizes``) feed the chunk model, which prices each
      staged per-axis ring round instead of one flat P-rank ring.
    * ``vector_mode`` only matters for μ-component fields (``components>0``).
    * ``r2c_packed`` needs a real transform with even power-of-two Nx.
    * ``fused=True`` (solver-step tuning of a diagonal spectral operator)
      additionally enumerates each candidate with the fused-roundtrip
      executor on — only meaningful for workloads stepping through
      ``fft3d.spectral_roundtrip_local``, so off by default.
    """
    nx, ny, nz = (n, n, n) if isinstance(n, int) else tuple(n)
    pow2 = all(is_pow2(d) for d in (nx, ny, nz))
    if backends is None:
        backends = [b for b in ALL_BACKENDS if b == "jnp" or pow2]
    engines = ALL_ENGINES if (pu > 1 or pv > 1) else ("switched",)
    vmodes = ("streaming", "parallel") if components else ("streaming",)
    packed_opts = (False, True) if (real and pow2 and nx % 2 == 0) else (False,)
    fused_opts = (False, True) if fused else (False,)

    out = []
    for backend in backends:
        for engine in engines:
            chunks_for = chunk_candidates(n, pu, pv, engine,
                                          backend=backend, mu=max(components, 1),
                                          pu_axes=pu_axes, pv_axes=pv_axes)
            schedules = [("sequential", 1)] + [("pipelined", c)
                                               for c in chunks_for]
            for schedule, chunks in schedules:
                for vm in vmodes:
                    for packed in packed_opts:
                        for fr in fused_opts:
                            out.append(Candidate(
                                backend=backend, schedule=schedule,
                                chunks=chunks, comm_engine=engine,
                                vector_mode=vm, r2c_packed=packed,
                                fused_roundtrip=fr))
    return out
