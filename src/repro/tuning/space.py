"""Enumeration of the valid ``FFT3DPlan`` configuration space."""

from __future__ import annotations

import dataclasses

from repro.kernels.ref import is_pow2

CHUNK_CHOICES = (2, 4, 8)       # pipelined slab counts (1 = sequential)
ALL_BACKENDS = ("jnp", "ref", "pallas", "mxu")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the plan space — exactly the tunable ``make_fft3d`` knobs."""

    backend: str = "jnp"
    schedule: str = "sequential"
    chunks: int = 1
    net: str = "switched"
    vector_mode: str = "streaming"
    r2c_packed: bool = False

    @property
    def name(self) -> str:
        sched = "seq" if self.schedule == "sequential" else f"pipe{self.chunks}"
        bits = [self.backend, sched, self.net, self.vector_mode]
        if self.r2c_packed:
            bits.append("packed")
        return "/".join(bits)

    def config(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_config(cls, cfg: dict) -> "Candidate":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in cfg.items() if k in fields})


DEFAULT_CANDIDATE = Candidate()  # the hardcoded status quo every caller used


def candidate_space(n, pu: int, pv: int, *, real: bool = False,
                    components: int = 0,
                    backends=None) -> list[Candidate]:
    """All valid candidates for the problem.

    Validity rules:

    * ``ref``/``pallas``/``mxu`` are radix-2 / four-step engines — power-of-two
      axis lengths only (``jnp`` delegates to XLA's general FFT).
    * ``net="torus"`` is only distinct from ``"switched"`` when a fold
      actually communicates (Pu > 1 or Pv > 1).
    * ``vector_mode`` only matters for μ-component fields (``components>0``).
    * ``r2c_packed`` needs a real transform with even power-of-two Nx.
    """
    nx, ny, nz = (n, n, n) if isinstance(n, int) else tuple(n)
    pow2 = all(is_pow2(d) for d in (nx, ny, nz))
    if backends is None:
        backends = [b for b in ALL_BACKENDS if b == "jnp" or pow2]
    nets = ("switched", "torus") if (pu > 1 or pv > 1) else ("switched",)
    schedules = [("sequential", 1)] + [("pipelined", c) for c in CHUNK_CHOICES]
    vmodes = ("streaming", "parallel") if components else ("streaming",)
    packed_opts = (False, True) if (real and pow2 and nx % 2 == 0) else (False,)

    out = []
    for backend in backends:
        for schedule, chunks in schedules:
            for net in nets:
                for vm in vmodes:
                    for packed in packed_opts:
                        out.append(Candidate(
                            backend=backend, schedule=schedule, chunks=chunks,
                            net=net, vector_mode=vm, r2c_packed=packed))
    return out
