"""FFT plan autotuning (paper Ch. 4 + §5.5 as a search problem).

The paper's thesis is that *configuration* — task organization (sequential
vs. pipelined, Ch. 4), communication engine (switched all-to-all, torus
ring, or the compute-overlapped ring of ``core.comm``, §4.3/§5.5) and
vector mode (§4.4) — decides end-to-end 3D-FFT time. ``FFT3DPlan`` exposes
all of those knobs; this package picks them automatically for a concrete
``(n, mesh, real, components, dtype)`` problem:

1. enumerate the valid plan space        (``space.candidate_space``),
2. prune it with the paper's analytic model (``perfmodel.estimate_plan_seconds``,
   overlap-aware for the ``overlap_ring`` engine),
3. time the survivors with compile/warm-up discipline (``timing.time_us``),
   scoring ``fwd_weight·t_fwd + inv_weight·t_inv`` (default 1:1 — a
   spectral solver runs both directions every step),
4. persist the winner in a JSON plan cache keyed by a canonical problem
   fingerprint including JAX version, device kind, and the objective
   weights (``cache.PlanCache``), so repeat runs are free.

The analytic pruning of step 2 prefers *measured* model constants when a
``repro.tuning.calibrate`` run has been persisted for this substrate
(``python -m repro.tuning.calibrate``): per-engine message overheads and
per-backend compute weights live in a fingerprinted ``calibration.json``
with the same replay discipline as the plan cache, and the hardcoded
tables in ``perfmodel`` remain as fallback priors.

Entry points: ``autotune(...)``, ``make_fft3d(..., autotune=True)``,
``python -m repro.tuning.cli --n 64 --mesh 4x2``, and
``python -m repro.tuning.calibrate --quick``.
"""

from repro.tuning.autotune import (TuneResult, autotune, time_candidate,
                                   time_candidate_pair)
from repro.tuning.cache import PlanCache, default_cache_path, problem_fingerprint
from repro.tuning.calibrate import (default_calibration_path,
                                    load_active_calibration, run_calibration,
                                    save_calibration, validate_calibration)
from repro.tuning.solver import autotune_solver_step, time_solver_step
from repro.tuning.space import DEFAULT_CANDIDATE, Candidate, candidate_space
from repro.tuning.timing import time_us

__all__ = [
    "autotune", "time_candidate", "time_candidate_pair", "TuneResult",
    "autotune_solver_step", "time_solver_step",
    "Candidate", "DEFAULT_CANDIDATE", "candidate_space",
    "PlanCache", "default_cache_path", "problem_fingerprint",
    "default_calibration_path", "load_active_calibration", "run_calibration",
    "save_calibration", "validate_calibration",
    "time_us",
]
