"""Wall-clock timing with compile/warm-up discipline.

One call compiles and warms the function (excluded from the measurement),
then the timed loop runs. :func:`time_us` keeps the historical contract —
``iters`` calls back-to-back, one block at the end, mean per call — which is
the cheapest schedule but sees only the mean. :func:`time_stats` blocks every
call and returns the distribution (mean/p50/p95/min), which the benchmark
suite uses to expose tail behavior alongside the mean.

Both refuse functions that **donate** their input buffers: a jit with
``donate_argnums`` invalidates the caller's arrays on the first (warm-up)
call, and every timed call after that would silently recompile or crash on
deleted buffers. The guard detects it right after warm-up (the donated
``jax.Array`` reports ``is_deleted()``) and raises instead of timing garbage.
"""

from __future__ import annotations

import time


def _check_not_donated(fn, args) -> None:
    """Raise if the warm-up call consumed (donated) any input buffer."""
    for i, a in enumerate(args):
        deleted = getattr(a, "is_deleted", None)
        if callable(deleted) and deleted():
            raise ValueError(
                f"argument {i} was donated/deleted by {fn!r} during warm-up; "
                "timing loops need reusable inputs — drop donate_argnums or "
                "pass fresh copies")


def time_us(fn, *args, iters: int = 5) -> float:
    """Mean wall time per call of ``fn(*args)`` in microseconds."""
    import jax

    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    jax.block_until_ready(fn(*args))  # compile + warm
    _check_not_donated(fn, args)
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _percentile(sorted_us: list[float], q: float) -> float:
    """Nearest-rank percentile of an ascending sample (q in [0, 100])."""
    idx = max(0, min(len(sorted_us) - 1,
                     round(q / 100.0 * (len(sorted_us) - 1))))
    return sorted_us[idx]


def time_stats(fn, *args, iters: int = 5) -> dict:
    """Distribution of per-call wall times of ``fn(*args)``.

    Compiles and warms once (excluded), then times ``iters`` calls each
    blocked individually, and returns
    ``{"mean_us", "p50_us", "p95_us", "min_us", "iters"}`` (nearest-rank
    percentiles). Per-call blocking forgoes cross-call pipelining, so the
    mean here can sit slightly above :func:`time_us`'s on substrates with
    async dispatch — it buys per-call samples the batch schedule cannot see.
    """
    import jax

    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    jax.block_until_ready(fn(*args))  # compile + warm
    _check_not_donated(fn, args)
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    samples.sort()
    return {
        "mean_us": sum(samples) / len(samples),
        "p50_us": _percentile(samples, 50.0),
        "p95_us": _percentile(samples, 95.0),
        "min_us": samples[0],
        "iters": iters,
    }
