"""Wall-clock timing with compile/warm-up discipline.

One call compiles and warms the function (excluded from the measurement),
then the timed loop runs ``iters`` calls back-to-back and blocks once at the
end — the same discipline as ``benchmarks/run.py`` (which now imports this).
"""

from __future__ import annotations

import time


def time_us(fn, *args, iters: int = 5) -> float:
    """Mean wall time per call of ``fn(*args)`` in microseconds."""
    import jax

    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    jax.block_until_ready(fn(*args))  # compile + warm
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6
