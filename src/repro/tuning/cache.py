"""Persistent JSON plan cache.

Entries are keyed by a canonical *problem fingerprint* — the transform
(n, Pu×Pv grid, real/complex, μ components, dtype) plus the software/hardware
substrate (JAX version, platform, device kind) — so a cached winner is never
replayed on a machine where the measurement would not transfer.

File layout (one file, many problems)::

    {"schema": "fft-plan-cache/v1",
     "entries": {"<fingerprint>": {"problem": {...}, "best": {...},
                                   "us_per_call": 123.4, "rows": [...],
                                   "created": "..."}}}

Writes are atomic (tmp file + ``os.replace``) so concurrent benchmark jobs
cannot tear the file.
"""

from __future__ import annotations

import hashlib
import json
import os

SCHEMA = "fft-plan-cache/v1"
ENV_VAR = "REPRO_PLAN_CACHE"


def default_cache_path() -> str:
    """``$REPRO_PLAN_CACHE`` if set, else ``~/.cache/repro/fft_plans.json``."""
    env = os.environ.get(ENV_VAR)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "fft_plans.json")


def problem_fingerprint(n, pu: int, pv: int, *, real: bool = False,
                        components: int = 0, dtype: str = "float32",
                        u_axes=("data",), v_axes=("model",),
                        fwd_weight: float = 1.0,
                        inv_weight: float = 1.0,
                        case: str = "",
                        solver_params: dict | None = None) -> tuple[str, dict]:
    """(key, payload): canonical id of a tuning problem on this substrate.

    The objective weights (``w_fwd·t_fwd + w_inv·t_inv``) are part of the
    fingerprint: a forward-only winner must never be replayed for a solver
    that pays for both directions. For the solver-step objective, ``case``
    (the registered solver name) and its physics ``solver_params`` join the
    fingerprint too — a plan tuned against a bare transform or a different
    workload is never replayed for another case.
    """
    import jax

    dev = jax.devices()[0]
    nx, ny, nz = (n, n, n) if isinstance(n, int) else tuple(n)
    payload = {
        "schema": SCHEMA,
        "n": [int(nx), int(ny), int(nz)],
        "pu": int(pu), "pv": int(pv),
        "u_axes": list(u_axes), "v_axes": list(v_axes),
        "real": bool(real), "components": int(components),
        "dtype": str(dtype),
        "fwd_weight": float(fwd_weight), "inv_weight": float(inv_weight),
        "jax_version": jax.__version__,
        "platform": dev.platform,
        "device_kind": dev.device_kind,
    }
    if case:
        payload["case"] = str(case)
        payload["solver_params"] = dict(solver_params or {})
    digest = hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()[:16]
    kind = ("r2c" if real else "c2c") + (f"_mu{components}" if components else "")
    prefix = f"solver_{case}_" if case else ""
    key = (f"{prefix}n{nx}x{ny}x{nz}_p{pu}x{pv}_{kind}_"
           f"{payload['dtype']}_{digest}")
    return key, payload


class PlanCache:
    def __init__(self, path: str | None = None):
        self.path = path or default_cache_path()

    def _load(self) -> dict:
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return {"schema": SCHEMA, "entries": {}}
        if data.get("schema") != SCHEMA:
            return {"schema": SCHEMA, "entries": {}}
        return data

    def get(self, key: str) -> dict | None:
        from repro import obs
        entry = self._load()["entries"].get(key)
        obs.metrics.inc("plan_cache.hits" if entry is not None
                        else "plan_cache.misses")
        return entry

    def put(self, key: str, entry: dict) -> None:
        data = self._load()
        data["entries"][key] = entry
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        tmp = self.path + f".tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)

    def keys(self) -> list[str]:
        return sorted(self._load()["entries"])
