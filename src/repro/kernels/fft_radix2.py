"""Pallas TPU kernel: batched radix-2 DIF 1D FFT (the paper's FFT engine).

Paper mapping (§3.3–3.4, §5.1–5.3 → TPU):

* The FPGA engine is ``R`` rows of ``log2(N)`` pipelined butterfly stages with
  shift-register shufflers between stages and a twiddle ROM. On TPU the same
  dataflow becomes: load a tile of ``TB`` independent pencils into VMEM, run
  all ``log2(N)`` butterfly stages back-to-back *in VMEM* (no HBM round trips
  between stages — the analogue of the fully-pipelined chain), apply the
  bit-reversal reordering table, store the tile. The paper's row-parallelism
  ``R`` maps onto the 8×128 vector lanes via the ``TB``-deep batch tile.
* The twiddle ROM is a precomputed ``(log2 N, N/2)`` planar table passed as a
  kernel operand and resident in VMEM for the whole grid step.
* Complex data is planar ``(re, im)`` float32/float64 — Pallas TPU has no
  native complex dtype.

BlockSpec tiling: grid over the pencil batch; each grid step owns a
``(TB, N)`` block of ``x_re``/``x_im`` plus the full twiddle table. ``TB`` is
chosen so the working set (≈ 6 live ``(TB, N)`` planes + table, double
buffered) fits in 16 MB of VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import is_pow2, twiddle_table_np

VMEM_BUDGET_BYTES = 8 * 1024 * 1024  # conservative half of v5e VMEM


def pick_batch_tile(n: int, batch: int, itemsize: int) -> int:
    """Largest power-of-two TB so ~6 live (TB, N) planes fit the VMEM budget."""
    tb = 512
    while tb > 8 and 6 * tb * n * itemsize > VMEM_BUDGET_BYTES:
        tb //= 2
    return max(8, min(tb, max(8, batch)))


def butterfly_stages(xr, xi, twr, twi, n: int):
    """All log2(N) DIF butterfly stages + bit-reversal of (TB, N) values.

    The one copy of the paper's butterfly pipeline (Eq. 3.8) used *inside*
    Pallas kernels: the 1D engine kernel below and the fused RDMA ring
    kernel (``kernels/ring_rdma.py``) both call it, so the stand-alone
    engine and the communication-fused engine cannot drift apart.
    ``twr``/``twi`` are the planar ``(log2 N, N/2)`` twiddle table values.
    """
    stages = n.bit_length() - 1
    tb = xr.shape[0]
    for s in range(stages):  # unrolled: the butterfly pipeline
        half = n >> (s + 1)
        groups = 1 << s
        wr = twr[s, :].reshape(1, groups, half)
        wi = twi[s, :].reshape(1, groups, half)
        xr = xr.reshape(tb, groups, 2, half)
        xi = xi.reshape(tb, groups, 2, half)
        ar, br = xr[:, :, 0, :], xr[:, :, 1, :]
        ai, bi = xi[:, :, 0, :], xi[:, :, 1, :]
        tr, ti = ar + br, ai + bi          # butterfly top (Eq. 3.8)
        dr, di = ar - br, ai - bi
        ur = dr * wr - di * wi             # butterfly bottom * twiddle
        ui = dr * wi + di * wr
        xr = jnp.concatenate([tr[:, :, None, :], ur[:, :, None, :]], axis=2)
        xr = xr.reshape(tb, n)
        xi = jnp.concatenate([ti[:, :, None, :], ui[:, :, None, :]], axis=2)
        xi = xi.reshape(tb, n)
    # Bit-reversal "reordering table" via the (2,)*S transpose decomposition —
    # lowers to log2(N) sublane/lane shuffles instead of a lane gather.
    shp = (tb,) + (2,) * stages
    perm = (0,) + tuple(range(stages, 0, -1))
    xr = xr.reshape(shp).transpose(perm).reshape(tb, n)
    xi = xi.reshape(shp).transpose(perm).reshape(tb, n)
    return xr, xi


def _fft_kernel(xr_ref, xi_ref, twr_ref, twi_ref, or_ref, oi_ref, *, n: int):
    """One grid step: full DIF FFT of a (TB, N) tile of pencils."""
    yr, yi = butterfly_stages(xr_ref[...], xi_ref[...],
                              twr_ref[...], twi_ref[...], n)
    or_ref[...] = yr
    oi_ref[...] = yi


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def fft1d_pallas(x_re, x_im, *, tb: int | None = None, interpret: bool | None = None):
    """Batched 1D FFT over the last axis via the Pallas engine.

    Accepts any leading shape; pads the flattened pencil batch up to a
    multiple of the batch tile.
    """
    n = x_re.shape[-1]
    assert is_pow2(n) and n >= 2, f"N must be a power of two >= 2, got {n}"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    dtype = x_re.dtype
    lead = x_re.shape[:-1]
    xr = x_re.reshape(-1, n)
    xi = x_im.reshape(-1, n)
    b = xr.shape[0]
    tile = tb or pick_batch_tile(n, b, jnp.dtype(dtype).itemsize)
    pad = (-b) % tile
    if pad:
        xr = jnp.concatenate([xr, jnp.zeros((pad, n), dtype)], axis=0)
        xi = jnp.concatenate([xi, jnp.zeros((pad, n), dtype)], axis=0)
    bp = b + pad
    stages = n.bit_length() - 1
    twr_np, twi_np = twiddle_table_np(n, str(jnp.dtype(dtype)))
    twr = jnp.asarray(twr_np)
    twi = jnp.asarray(twi_np)

    grid = (bp // tile,)
    out_shape = [
        jax.ShapeDtypeStruct((bp, n), dtype),
        jax.ShapeDtypeStruct((bp, n), dtype),
    ]
    yr, yi = pl.pallas_call(
        functools.partial(_fft_kernel, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((stages, n // 2), lambda i: (0, 0)),
            pl.BlockSpec((stages, n // 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, twr, twi)
    yr = yr[:b].reshape(*lead, n)
    yi = yi[:b].reshape(*lead, n)
    return yr, yi


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def ifft1d_pallas(x_re, x_im, *, tb: int | None = None, interpret: bool | None = None):
    """Inverse FFT via the conjugate trick (paper §3.2.4), same engine."""
    n = x_re.shape[-1]
    yr, yi = fft1d_pallas(x_re, -x_im, tb=tb, interpret=interpret)
    scale = jnp.asarray(1.0 / n, dtype=x_re.dtype)
    return yr * scale, -yi * scale
