"""Pure-jnp oracles for the radix-2 DIF FFT engine (paper §3.3, Fig. 3.7).

Everything here operates on *planar complex* data — a pair ``(re, im)`` of real
arrays — because the Pallas TPU kernel cannot use native complex dtypes. The
reference implements exactly the algorithm the hardware engine implements:
``log2(N)`` decimation-in-frequency butterfly stages followed by the
bit-reversal reorder (the paper's "on-chip reordering table"), so kernel vs
reference comparisons are algorithm-identical, while correctness of the
algorithm itself is separately asserted against ``jnp.fft``.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np


def is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def bitrev_permutation(n: int) -> np.ndarray:
    """Indices p with p[k] = bit-reverse(k) for a log2(n)-bit index."""
    assert is_pow2(n)
    bits = n.bit_length() - 1
    p = np.arange(n)
    out = np.zeros(n, dtype=np.int64)
    for b in range(bits):
        out |= ((p >> b) & 1) << (bits - 1 - b)
    return out


@functools.lru_cache(maxsize=64)
def twiddle_table_np(n: int, dtype: str = "float64") -> tuple[np.ndarray, np.ndarray]:
    """The twiddle ROM (paper Fig. 3.8): rows s = stage, N/2 entries per row.

    Row ``s`` holds the stage-s twiddles ``W_{N/2^s}^j`` (j = 0..N/2^{s+1}-1)
    tiled across the 2^s butterfly groups, matching the flattened
    ``(groups, half)`` layout used by both the reference and the kernel.
    """
    assert is_pow2(n) and n >= 2
    stages = n.bit_length() - 1
    re = np.zeros((stages, n // 2), dtype=np.float64)
    im = np.zeros((stages, n // 2), dtype=np.float64)
    for s in range(stages):
        half = n >> (s + 1)          # butterfly span at this stage
        groups = 1 << s
        j = np.arange(half)
        ang = -2.0 * np.pi * j / (2 * half)
        re[s] = np.tile(np.cos(ang), groups)
        im[s] = np.tile(np.sin(ang), groups)
    return re.astype(dtype), im.astype(dtype)


def fft_dif_planar(x_re, x_im):
    """Radix-2 DIF FFT over the last axis; natural-order in and out.

    Reference for the Pallas kernel — same stage/shuffle/bit-reversal
    structure, expressed in pure jnp. Any float dtype.
    """
    n = x_re.shape[-1]
    assert is_pow2(n) and n >= 2, f"N must be a power of two >= 2, got {n}"
    stages = n.bit_length() - 1
    dtype = x_re.dtype
    tw_re_np, tw_im_np = twiddle_table_np(n, str(np.dtype(dtype)))
    lead = x_re.shape[:-1]

    xr = x_re.reshape((-1, n))
    xi = x_im.reshape((-1, n))
    for s in range(stages):
        half = n >> (s + 1)
        groups = 1 << s
        wr = jnp.asarray(tw_re_np[s].reshape(1, groups, half), dtype=dtype)
        wi = jnp.asarray(tw_im_np[s].reshape(1, groups, half), dtype=dtype)
        xr = xr.reshape(-1, groups, 2, half)
        xi = xi.reshape(-1, groups, 2, half)
        ar, br = xr[:, :, 0, :], xr[:, :, 1, :]
        ai, bi = xi[:, :, 0, :], xi[:, :, 1, :]
        # Butterfly (paper Eq. 3.8): top = a + b ; bot = (a - b) * W
        tr, ti = ar + br, ai + bi
        dr, di = ar - br, ai - bi
        ur = dr * wr - di * wi
        ui = dr * wi + di * wr
        xr = jnp.stack([tr, ur], axis=2).reshape(-1, n)
        xi = jnp.stack([ti, ui], axis=2).reshape(-1, n)
    # Output of the DIF tree is bit-reversed; reorder to natural order.
    perm = jnp.asarray(bitrev_permutation(n))
    xr = xr[:, perm].reshape(*lead, n)
    xi = xi[:, perm].reshape(*lead, n)
    return xr, xi


def ifft_dif_planar(x_re, x_im):
    """Inverse via conj trick: ifft(x) = conj(fft(conj(x))) / N (paper §3.2.4)."""
    n = x_re.shape[-1]
    yr, yi = fft_dif_planar(x_re, -x_im)
    scale = jnp.asarray(1.0 / n, dtype=x_re.dtype)
    return yr * scale, -yi * scale


def fft_oracle(x_re, x_im):
    """Ground truth via jnp.fft (complex math), returned planar."""
    y = jnp.fft.fft(x_re.astype(jnp.float64) + 1j * x_im.astype(jnp.float64))
    return y.real.astype(x_re.dtype), y.imag.astype(x_re.dtype)


def rfft_planar(x):
    """Real-input FFT over the last axis, keeping the N/2+1 significant bins.

    Paper §3.2.5: the X-phase transform is real→complex; by Hermitian symmetry
    only the first N/2+1 outputs are kept (the general complex engine is used,
    as in the thesis — no real-optimized datapath).
    """
    n = x.shape[-1]
    yr, yi = fft_dif_planar(x, jnp.zeros_like(x))
    return yr[..., : n // 2 + 1], yi[..., : n // 2 + 1]


def rfft_packed_planar(x):
    """Beyond-paper optimization: N-point real FFT via one N/2-point complex FFT.

    Packs even/odd samples as real/imag parts, then untangles with the
    standard split: halves butterfly work and VMEM traffic for the X phase.
    """
    n = x.shape[-1]
    assert n % 2 == 0
    h = n // 2
    ze = x[..., 0::2]
    zo = x[..., 1::2]
    zr, zi = fft_dif_planar(ze, zo)
    # Zc[k] = conj(Z[(h-k) mod h])
    idx = (-jnp.arange(h)) % h
    zcr, zci = zr[..., idx], -zi[..., idx]
    # E = (Z + Zc)/2 (DFT of evens), O = (Z - Zc)/(2i) (DFT of odds)
    er = 0.5 * (zr + zcr)
    ei = 0.5 * (zi + zci)
    o_r = 0.5 * (zi - zci)
    o_i = -0.5 * (zr - zcr)
    k = np.arange(h)
    wr = jnp.asarray(np.cos(-2 * np.pi * k / n), dtype=x.dtype)
    wi = jnp.asarray(np.sin(-2 * np.pi * k / n), dtype=x.dtype)
    # X[k] = E[k] + W_N^k O[k], k = 0..h-1 ; X[h] = E[0] - O[0]
    xr = er + (o_r * wr - o_i * wi)
    xi = ei + (o_r * wi + o_i * wr)
    xr = jnp.concatenate([xr, er[..., :1] - o_r[..., :1]], axis=-1)
    xi = jnp.concatenate([xi, ei[..., :1] - o_i[..., :1]], axis=-1)
    return xr, xi
