"""Beyond-paper Pallas kernel: four-step (Bailey) FFT as MXU matmuls.

The thesis' radix-2 butterfly engine is the faithful baseline
(``fft_radix2.py``); it maps to the TPU VPU (8×128 vector lanes,
~4 TFLOP/s f32). The four-step decomposition N = n1·n2 instead computes

    X = DFT_N(x)  via  B = A @ DFT_{n2};  C = B ∘ W (twiddle);
                       D = DFT_{n1}ᵀ @ C;  X = flatten(Dᵀ)

— three dense (n1, n2)-shaped complex matmuls per pencil, which run on the
MXU (197 TFLOP/s bf16 / ~99 f32). Napkin: N=4096 → n1=n2=64; matmul FLOPs
8·N·√N ≈ 2.1 MF vs radix-2's 5·N·log₂N ≈ 0.25 MF — 8.5× more arithmetic on
units with 25–50× the throughput ⇒ ~3–6× faster per pencil, with no
lane-shuffle reorder network at all (the bit-reversal disappears; the
transpose is an MXU-friendly relayout). This is the hardware-adaptation
argument of DESIGN.md §3 taken one step further than the paper.

Planar complex in/out; exact vs jnp.fft in tests (f32 ≤2e-4, f64 ≤1e-10).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import is_pow2
from repro.kernels.fft_radix2 import pick_batch_tile


@functools.lru_cache(maxsize=32)
def _plan(n: int, dtype: str):
    """(n1, n2, DFT_n2, twiddle, DFT_n1) planar numpy tables."""
    s = n.bit_length() - 1
    n1 = 1 << (s // 2)
    n2 = n // n1
    j2 = np.arange(n2)
    d2 = np.exp(-2j * np.pi * np.outer(j2, j2) / n2)
    j1 = np.arange(n1)
    d1 = np.exp(-2j * np.pi * np.outer(j1, j1) / n1)
    tw = np.exp(-2j * np.pi * np.outer(j1, np.arange(n2)) / n)
    cast = lambda a: (a.real.astype(dtype), a.imag.astype(dtype))
    return n1, n2, cast(d2), cast(tw), cast(d1)


def _cmul_mm(ar, ai, br, bi):
    """Complex matmul (planar): (ar+i·ai) @ (br+i·bi)."""
    return ar @ br - ai @ bi, ar @ bi + ai @ br


def _kernel(xr_ref, xi_ref, d2r_ref, d2i_ref, twr_ref, twi_ref,
            d1r_ref, d1i_ref, or_ref, oi_ref, *, n1: int, n2: int):
    tb = xr_ref.shape[0]
    xr = xr_ref[...].reshape(tb, n1, n2)
    xi = xi_ref[...].reshape(tb, n1, n2)
    d2r, d2i = d2r_ref[...], d2i_ref[...]
    twr, twi = twr_ref[...], twi_ref[...]
    d1r, d1i = d1r_ref[...], d1i_ref[...]
    # with x viewed as A[j1, j2] (n = j1·n2 + j2) and k = k1 + n1·k2:
    # X[k1 + n1·k2] = Σ_{j2} W_{n2}^{j2 k2} W_N^{j2 k1} Σ_{j1} A[j1,j2] W_{n1}^{j1 k1}
    # step 1: length-n1 DFTs along columns (batched MXU matmul)
    br = jnp.einsum("kj,bjl->bkl", d1r, xr) - jnp.einsum("kj,bjl->bkl", d1i, xi)
    bi = jnp.einsum("kj,bjl->bkl", d1r, xi) + jnp.einsum("kj,bjl->bkl", d1i, xr)
    # step 2: twiddle W_N^{k1·j2}
    cr = br * twr - bi * twi
    ci = br * twi + bi * twr
    # step 3: length-n2 DFTs along rows
    dr = cr @ d2r - ci @ d2i
    di = cr @ d2i + ci @ d2r
    # step 4: output index X[k1 + n1·k2] = D[k1,k2]  →  transpose
    or_ref[...] = dr.transpose(0, 2, 1).reshape(tb, n1 * n2)
    oi_ref[...] = di.transpose(0, 2, 1).reshape(tb, n1 * n2)


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def fft1d_mxu(x_re, x_im, *, tb: int | None = None, interpret: bool | None = None):
    """Batched 1D FFT over the last axis via the four-step MXU kernel."""
    n = x_re.shape[-1]
    assert is_pow2(n) and n >= 4, f"N must be a power of two >= 4, got {n}"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    dtype = x_re.dtype
    lead = x_re.shape[:-1]
    xr = x_re.reshape(-1, n)
    xi = x_im.reshape(-1, n)
    b = xr.shape[0]
    n1, n2, (d2r, d2i), (twr, twi), (d1r, d1i) = _plan(n, str(jnp.dtype(dtype)))
    tile = tb or pick_batch_tile(n, b, jnp.dtype(dtype).itemsize)
    pad = (-b) % tile
    if pad:
        xr = jnp.concatenate([xr, jnp.zeros((pad, n), dtype)], axis=0)
        xi = jnp.concatenate([xi, jnp.zeros((pad, n), dtype)], axis=0)
    bp = b + pad

    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    yr, yi = pl.pallas_call(
        functools.partial(_kernel, n1=n1, n2=n2),
        grid=(bp // tile,),
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            full((n2, n2)), full((n2, n2)),
            full((n1, n2)), full((n1, n2)),
            full((n1, n1)), full((n1, n1)),
        ],
        out_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bp, n), dtype),
                   jax.ShapeDtypeStruct((bp, n), dtype)],
        interpret=interpret,
    )(xr, xi, jnp.asarray(d2r), jnp.asarray(d2i), jnp.asarray(twr),
      jnp.asarray(twi), jnp.asarray(d1r), jnp.asarray(d1i))
    return yr[:b].reshape(*lead, n), yi[:b].reshape(*lead, n)


def fft_mxu_flops(n: int) -> float:
    """Complex-matmul FLOPs per pencil: 8·N·(n1 + n2)."""
    n1, n2 = _plan(n, "float32")[:2]
    return 8.0 * n * (n1 + n2)


def mxu_vs_butterfly_napkin(n: int, *, mxu_tflops=197e12, vpu_tflops=4e12):
    """The §Perf napkin: time per pencil on each unit (seconds)."""
    butterfly = 5.0 * n * math.log2(n) / vpu_tflops
    four_step = fft_mxu_flops(n) / mxu_tflops
    return {"butterfly_vpu_s": butterfly, "four_step_mxu_s": four_step,
            "speedup": butterfly / four_step}
