from repro.kernels import ops, ref
from repro.kernels.fft_radix2 import fft1d_pallas, ifft1d_pallas
from repro.kernels.attention import flash_attention

__all__ = ["ops", "ref", "fft1d_pallas", "ifft1d_pallas", "flash_attention"]
