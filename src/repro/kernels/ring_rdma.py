"""Pallas async-RDMA ring exchange — the paper's NIC offload (§4.2–4.3).

The thesis' central hardware claim is that the fold communications are
driven by a NIC that streams blocks *while* the butterfly engines compute
(Fig. 4.3, tasks C/G): the send of block r+1 is started, the current
block's butterflies run, and only then does the engine wait on the wire.
``overlap_ring`` (``core.comm``) emits that schedule at the XLA level and
*hopes* the latency-hiding scheduler honors it; this module makes the
schedule explicit in a Pallas kernel built on double-buffered
``pltpu.make_async_remote_copy`` neighbor DMA — the TPU rendition of the
FPGA NIC's APEnet-style RDMA engine.

Two lowerings behind one contract (``ring_exchange_rdma`` mirrors
``core.transpose.ring_exchange`` exactly — same block order, same
rank-major merge, bit-identical relayout):

* **TPU** — one fused kernel per exchange: P−1 direct-send rounds
  (``device_id`` = rank ``me+r``, routed over the torus exactly like the
  shift-by-r ``ppermute`` the plain ring uses, Eq. 5.6), each round
  starting the next RDMA before waiting the current one. When a planar
  ``payload`` pair rides along, its radix-2 butterflies
  (``fft_radix2.butterfly_stages`` — the same stage code as the 1D
  engine kernel) run *inside* the kernel between ``start`` and ``wait``,
  so send/compute overlap is explicit rather than hoped-for.
* **interpret (CPU/CI)** — this JAX has no cross-device DMA emulation, so
  the wire hop is ``lax.ppermute`` while the NIC's *local* data movement
  (staging the send block, landing the received block in its output slot)
  runs through Pallas kernels in interpret mode. Numerically this path is
  the torus ring relayout by construction; CI pins it bit-exact against
  ``torus`` on 4x2/2x4/8x1 meshes (``tests/_dist_transpose_check.py``).

``ring_exchange_bidi_rdma`` is the **two-NIC** variant (Fig. 5.9): each
round sends to *both* torus neighbors over per-direction semaphores, so the
exchange finishes in ``ceil((P−1)/2)`` double-buffered rounds instead of
P−1; off-TPU it lowers to the counter-rotating ``ppermute`` streams of
``transpose.ring_exchange_bidi``.

When a grid dimension spans several mesh axes (a ``CommStep`` whose
``grid_dim`` resolves to e.g. ``("pod", "data")``), both entry points run
**one ring per mesh axis** via ``transpose.staged_exchange``: every hop
stays a single-axis neighbor RDMA, the round count drops to
Σᵢ rounds(qᵢ), and the composition is bit-exact vs the flat ring over the
product group.

All entry points run *inside* ``shard_map`` over the FFT mesh axes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core import transpose as tr
from repro.kernels.fft_radix2 import butterfly_stages
from repro.kernels.ref import is_pow2, twiddle_table_np


def use_rdma() -> bool:
    """True when the real inter-chip RDMA lowering is available (TPU)."""
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# interpret path: Pallas NIC staging kernels + ppermute wire hop
# ---------------------------------------------------------------------------

def _nic_take_kernel(idx_ref, xs_ref, out_ref):
    """Stage block ``idx`` of the stacked (P, ...) buffer for the wire."""
    out_ref[0] = xs_ref[idx_ref[0]]


def _nic_place_kernel(idx_ref, blk_ref, out_in_ref, out_ref):
    """Land a received block in output slot ``idx`` (in-place via aliasing)."""
    del out_in_ref  # aliased with out_ref — the in-place landing buffer
    out_ref[idx_ref[0]] = blk_ref[0]


def _smem_index(idx):
    return jnp.reshape(jnp.asarray(idx, jnp.int32), (1,))


def nic_take(xs, idx):
    """Pallas-staged read of block ``idx`` from a stacked (P, ...) buffer,
    keeping the leading length-1 axis (the wire format of one block)."""
    return pl.pallas_call(
        _nic_take_kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((1,) + xs.shape[1:], xs.dtype),
        interpret=True,
    )(_smem_index(idx), xs)


def nic_place(out, blk, idx):
    """Pallas-staged write of one received (1, ...) block into slot ``idx``
    of the stacked output buffer (aliased — no copy of the full buffer)."""
    return pl.pallas_call(
        _nic_place_kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct(out.shape, out.dtype),
        input_output_aliases={2: 0},
        interpret=True,
    )(_smem_index(idx), blk, out)


def _ring_interpret(arrs, axes, *, split_axis: int, concat_axis: int,
                    interleave=None):
    """The RDMA ring's schedule with ``lax.ppermute`` standing in for the
    inter-chip hop (no DMA emulation off-TPU). Identical block order to
    ``transpose.ring_exchange``: round r ships the block for rank me+r and
    lands the block from rank me−r; ``interleave()`` is emitted right after
    the first round's sends (the Fig. 4.3 overlap window)."""
    p = compat.axes_size(axes)
    me = compat.flat_axis_index(axes)
    name = axes if len(axes) > 1 else axes[0]

    xss = [tr.stack_blocks(x, p, split_axis) for x in arrs]
    outs = [nic_place(jnp.zeros_like(xs), nic_take(xs, me), me) for xs in xss]
    follow = None
    for r in range(1, p):
        perm = [(i, (i + r) % p) for i in range(p)]
        recvs = [lax.ppermute(nic_take(xs, (me + r) % p), name, perm)
                 for xs in xss]
        if follow is None and interleave is not None:
            follow = interleave()
        outs = [nic_place(o, recv, (me - r) % p)
                for o, recv in zip(outs, recvs)]
    return [tr.merge_blocks(o, p, concat_axis) for o in outs], follow


# ---------------------------------------------------------------------------
# TPU path: fused double-buffered RDMA kernel
# ---------------------------------------------------------------------------

def _chunk_bounds(total: int, parts: int, i: int) -> tuple[int, int]:
    """Row range [off, off+cnt) of chunk ``i`` when ``total`` rows are cut
    into ``parts`` near-equal chunks (first ``total % parts`` get +1)."""
    base, rem = divmod(total, parts)
    off = i * base + min(i, rem)
    return off, base + (1 if i < rem else 0)


def _payload_chunk(pr_ref, pi_ref, twr_ref, twi_ref, diag_refs, *,
                   off, cnt, n_payload: int, inverse: bool):
    """Transform payload rows [off, off+cnt): the in-kernel compute of one
    ring round. Plain mode runs the forward (or conjugate-trick inverse)
    radix-2 butterflies; roundtrip mode (``diag_refs``) runs the *whole*
    spectral middle — forward butterflies, pointwise diagonal multiply,
    inverse butterflies — in one visit (the paper's NIC offload extended
    from butterflies to the spectral computation)."""
    cr = pr_ref[pl.ds(off, cnt), :]
    ci = pi_ref[pl.ds(off, cnt), :]
    if inverse:
        ci = -ci
    yr, yi = butterfly_stages(cr, ci, twr_ref[...], twi_ref[...], n_payload)
    if inverse:
        scale = jnp.asarray(1.0 / n_payload, yr.dtype)
        yr, yi = yr * scale, -(yi * scale)
    if diag_refs is not None:
        dr_ref, di_ref = diag_refs
        dr = dr_ref[pl.ds(off, cnt), :]
        di = di_ref[pl.ds(off, cnt), :]
        kr = yr * dr - yi * di
        ki = yr * di + yi * dr
        zr, zi = butterfly_stages(kr, -ki, twr_ref[...], twi_ref[...],
                                  n_payload)
        scale = jnp.asarray(1.0 / n_payload, zr.dtype)
        yr, yi = zr * scale, -(zi * scale)
    return yr, yi


def _rdma_ring_kernel(*refs, axis_name: str, p: int, n_arrays: int,
                      n_payload: int, payload_rows: int, inverse: bool,
                      roundtrip: bool):
    """P−1 direct-send RDMA rounds with in-kernel butterflies.

    Round r: start the round-r+1 send, run payload chunk r−1's butterfly
    stages while both copies are in flight, then wait round r. Per-round
    semaphore slots (no reuse) keep the one-ahead pipeline hazard-free.
    """
    fused = n_payload > 0
    xs = refs[:n_arrays]
    i = n_arrays
    diag_refs = None
    if fused:
        pr_ref, pi_ref, twr_ref, twi_ref = refs[i:i + 4]
        i += 4
        if roundtrip:
            diag_refs = refs[i:i + 2]
            i += 2
    outs = refs[i:i + n_arrays]
    i += n_arrays
    if fused:
        qr_ref, qi_ref = refs[i:i + 2]
        i += 2
    copy_sem, send_sem, recv_sem = refs[i:i + 3]

    me = lax.axis_index(axis_name)

    # own block never touches the wire: local async DMA x[me] -> out[me]
    for a in range(n_arrays):
        dma = pltpu.make_async_copy(xs[a].at[me], outs[a].at[me], copy_sem)
        dma.start()
        dma.wait()

    def start_round(r):
        dst = lax.rem(me + r, p)
        ops = []
        for a in range(n_arrays):
            rdma = pltpu.make_async_remote_copy(
                src_ref=xs[a].at[dst],       # block destined for rank me+r
                dst_ref=outs[a].at[me],      # lands in the remote slot "me"
                send_sem=send_sem.at[r - 1, a],
                recv_sem=recv_sem.at[r - 1, a],
                device_id=(dst,),
                device_id_type=pltpu.DeviceIdType.LOGICAL)
            rdma.start()
            ops.append(rdma)
        return ops

    in_flight = {1: start_round(1)}
    for r in range(1, p):
        if r + 1 < p:
            in_flight[r + 1] = start_round(r + 1)   # next block's send
        if fused:
            # current block's butterflies, while the copies fly (Fig. 4.3)
            off, cnt = _chunk_bounds(payload_rows, p - 1, r - 1)
            if cnt:
                yr, yi = _payload_chunk(pr_ref, pi_ref, twr_ref, twi_ref,
                                        diag_refs, off=off, cnt=cnt,
                                        n_payload=n_payload, inverse=inverse)
                qr_ref[pl.ds(off, cnt), :] = yr
                qi_ref[pl.ds(off, cnt), :] = yi
        for rdma in in_flight.pop(r):               # then wait
            rdma.wait()


def _rdma_bidi_kernel(*refs, axis_name: str, p: int, n_arrays: int,
                      n_payload: int, payload_rows: int, inverse: bool,
                      roundtrip: bool):
    """ceil((P−1)/2) double-buffered rounds over *both* torus directions.

    Round r starts the clockwise send (block me+r, routed +r) and the
    counter-clockwise send (block me−r, routed −r on the opposite links) —
    the paper's two-NIC node of Fig. 5.9 — then starts round r+1's pair,
    runs payload chunk r−1's butterflies while all copies fly, and waits
    round r. Semaphore slots are per (round, direction, array): dim 1 is
    0=clockwise, 1=counter-clockwise, so the counter-rotating streams never
    share a semaphore. Even rings skip the duplicate farthest hop
    (r == P−r) and ship that block clockwise only.
    """
    fused = n_payload > 0
    xs = refs[:n_arrays]
    i = n_arrays
    diag_refs = None
    if fused:
        pr_ref, pi_ref, twr_ref, twi_ref = refs[i:i + 4]
        i += 4
        if roundtrip:
            diag_refs = refs[i:i + 2]
            i += 2
    outs = refs[i:i + n_arrays]
    i += n_arrays
    if fused:
        qr_ref, qi_ref = refs[i:i + 2]
        i += 2
    copy_sem, send_sem, recv_sem = refs[i:i + 3]

    me = lax.axis_index(axis_name)
    rounds = tr.bidi_rounds(p)

    # own block never touches the wire: local async DMA x[me] -> out[me]
    for a in range(n_arrays):
        dma = pltpu.make_async_copy(xs[a].at[me], outs[a].at[me], copy_sem)
        dma.start()
        dma.wait()

    def start_round(r):
        dirs = [(0, lax.rem(me + r, p))]            # clockwise: +r
        if r != p - r:                              # ccw: −r (skip duplicate)
            dirs.append((1, lax.rem(me - r + p, p)))
        ops = []
        for d, dst in dirs:
            for a in range(n_arrays):
                rdma = pltpu.make_async_remote_copy(
                    src_ref=xs[a].at[dst],       # block destined for rank dst
                    dst_ref=outs[a].at[me],      # lands in the remote slot "me"
                    send_sem=send_sem.at[r - 1, d, a],
                    recv_sem=recv_sem.at[r - 1, d, a],
                    device_id=(dst,),
                    device_id_type=pltpu.DeviceIdType.LOGICAL)
                rdma.start()
                ops.append(rdma)
        return ops

    in_flight = {1: start_round(1)}
    for r in range(1, rounds + 1):
        if r + 1 <= rounds:
            in_flight[r + 1] = start_round(r + 1)   # next pair's sends
        if fused:
            off, cnt = _chunk_bounds(payload_rows, rounds, r - 1)
            if cnt:
                yr, yi = _payload_chunk(pr_ref, pi_ref, twr_ref, twi_ref,
                                        diag_refs, off=off, cnt=cnt,
                                        n_payload=n_payload, inverse=inverse)
                qr_ref[pl.ds(off, cnt), :] = yr
                qi_ref[pl.ds(off, cnt), :] = yi
        for rdma in in_flight.pop(r):               # then wait both streams
            rdma.wait()


def _ring_rdma_tpu(arrs, axes, *, split_axis: int, concat_axis: int,
                   payload=None, diag=None, inverse: bool = False,
                   bidi: bool = False):
    """Build and invoke the fused RDMA kernel for one exchange."""
    p = compat.axes_size(axes)
    axis_name = axes[0]
    xss = [tr.stack_blocks(x, p, split_axis) for x in arrs]
    dtype = xss[0].dtype

    fused = payload is not None
    operands = list(xss)
    out_shape = [jax.ShapeDtypeStruct(xs.shape, xs.dtype) for xs in xss]
    n_payload = payload_rows = 0
    n_vmem_in = 0
    lead = None
    if fused:
        pr, pi = payload
        lead = pr.shape[:-1]
        n_payload = pr.shape[-1]
        payload_rows = int(pr.size) // n_payload
        twr_np, twi_np = twiddle_table_np(n_payload, str(jnp.dtype(dtype)))
        operands += [pr.reshape(payload_rows, n_payload),
                     pi.reshape(payload_rows, n_payload),
                     jnp.asarray(twr_np), jnp.asarray(twi_np)]
        n_vmem_in = 4
        if diag is not None:
            # roundtrip mode: the diagonal multiplier rows ride along,
            # already broadcast to the payload's shape by the caller
            dgr, dgi = diag
            operands += [dgr.reshape(payload_rows, n_payload),
                         dgi.reshape(payload_rows, n_payload)]
            n_vmem_in = 6
        out_shape += [jax.ShapeDtypeStruct((payload_rows, n_payload), dtype)
                      for _ in range(2)]

    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    in_specs = [any_spec] * len(xss)
    out_specs = [any_spec] * len(xss)
    if fused:
        # payload + twiddles (+ diag rows) live in VMEM for the in-kernel
        # butterflies
        in_specs += [pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM)
                     ] * n_vmem_in
        out_specs += [pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.VMEM)] * 2

    kernel = functools.partial(
        _rdma_bidi_kernel if bidi else _rdma_ring_kernel,
        axis_name=axis_name, p=p, n_arrays=len(xss),
        n_payload=n_payload, payload_rows=payload_rows, inverse=inverse,
        roundtrip=diag is not None)
    # per-direction semaphore slots for the bidi kernel (dim 1: cw, ccw)
    sem_shape = ((max(tr.bidi_rounds(p), 1), 2, len(xss)) if bidi
                 else (max(p - 1, 1), len(xss)))
    results = pl.pallas_call(
        kernel,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA(sem_shape),
            pltpu.SemaphoreType.DMA(sem_shape),
        ],
        compiler_params=pltpu.TPUCompilerParams(collective_id=0),
    )(*operands)

    outs = [tr.merge_blocks(o, p, concat_axis) for o in results[:len(xss)]]
    follow = None
    if fused:
        qr, qi = results[len(xss):len(xss) + 2]
        follow = (qr.reshape(*lead, n_payload), qi.reshape(*lead, n_payload))
    return outs, follow


# ---------------------------------------------------------------------------
# public contract (mirrors transpose.ring_exchange)
# ---------------------------------------------------------------------------

def fusable_payload(payload) -> bool:
    """True when the TPU kernel can butterfly this payload in-kernel:
    a planar (re, im) pair with a power-of-two last axis."""
    if payload is None:
        return False
    pr, pi = payload
    return (pr.shape == pi.shape and pr.ndim >= 1
            and is_pow2(pr.shape[-1]) and pr.shape[-1] >= 2)


def ring_exchange_rdma(arrs, axes, *, split_axis: int, concat_axis: int,
                       interleave=None, payload=None, diag=None,
                       inverse: bool = False,
                       interpret: bool | None = None):
    """Tiled ring all-to-all of ``arrs`` through the async-RDMA NIC engine.

    Contract-compatible with ``transpose.ring_exchange``: returns
    ``(outs, follow)`` where ``follow`` is the ``interleave()`` result (the
    block-granular overlap thunk) or, on the fused TPU path, the
    butterflied ``payload`` pair. ``interleave`` and ``payload`` are
    mutually exclusive: a thunk is emitted between rounds at the JAX level
    (interpret path — XLA schedules it under the remaining hops), a payload
    is transformed *inside* the kernel between ``start`` and ``wait``
    (TPU path). ``inverse`` applies the conjugate-trick inverse FFT to the
    payload. ``diag`` (a planar multiplier pair broadcast to the payload's
    shape) switches the payload to **roundtrip** mode: forward
    butterflies, pointwise diagonal multiply, conjugate-trick inverse
    butterflies — the whole spectral middle of a fused solver step in one
    payload visit.

    A grid dimension spanning several communicating mesh axes is **staged
    per axis** (``transpose.staged_exchange``): one double-buffered RDMA
    ring kernel per mesh axis, each with a proper single-axis neighbor
    ``device_id`` — never the flat ``ppermute`` fallback. The payload (or
    thunk) rides the first stage; later stages relay the already
    transformed blocks. The composition is bit-exact vs the flat ring.
    """
    assert interleave is None or payload is None, \
        "interleave (JAX-level thunk) and payload (in-kernel) are exclusive"
    assert diag is None or (payload is not None and not inverse), \
        "diag (roundtrip payload mode) needs a forward payload"
    axes = tuple(axes)
    p = compat.axes_size(axes)
    if p <= 1:
        return [jnp.asarray(a) for a in arrs], None
    if interpret is None:
        interpret = not use_rdma()
    comm_axes = tuple(a for a in axes if compat.axes_size((a,)) > 1)
    if len(comm_axes) > 1:
        ex = functools.partial(ring_exchange_rdma, interpret=interpret)
        return tr.staged_exchange(arrs, comm_axes, split_axis=split_axis,
                                  concat_axis=concat_axis, exchange=ex,
                                  interleave=interleave, payload=payload,
                                  diag=diag, inverse=inverse)
    # both single-axis lowerings below bypass tr.ring_exchange, so the wire
    # metering happens here (one fused kernel dispatch covers all rounds)
    tr._meter_exchange(comm_axes, p, tr.ring_rounds(p), arrs,
                       dispatch_kind="rdma", dispatches=1)
    if not interpret:
        # the fused kernel is atomic — a JAX-level thunk can't run between
        # its rounds, so non-fusable compute is emitted before the kernel
        # (serialized; the chunk model prices this, and fusable compute
        # takes the in-kernel payload path instead). The contract still
        # returns the thunk's result so callers' slab pipelines advance.
        follow = interleave() if interleave is not None else None
        outs, fused = _ring_rdma_tpu(arrs, comm_axes,
                                     split_axis=split_axis,
                                     concat_axis=concat_axis, payload=payload,
                                     diag=diag, inverse=inverse)
        return outs, (fused if payload is not None else follow)
    if payload is not None:
        # no in-kernel butterflies off-TPU: degrade to the thunk contract
        raise ValueError("payload fusion requires the TPU RDMA lowering; "
                         "pass interleave= on the interpret path")
    return _ring_interpret(arrs, axes, split_axis=split_axis,
                           concat_axis=concat_axis, interleave=interleave)


def ring_exchange_bidi_rdma(arrs, axes, *, split_axis: int, concat_axis: int,
                            interleave=None, payload=None, diag=None,
                            inverse: bool = False,
                            interpret: bool | None = None):
    """Bidirectional (two-NIC) ring all-to-all through the async-RDMA engine.

    Contract-compatible with ``transpose.ring_exchange_bidi`` (and therefore
    with ``ring_exchange_rdma``): same block order, same rank-major merge,
    bit-identical relayout — only the schedule changes, finishing in
    ``ceil((P−1)/2)`` rounds by driving both torus directions per round
    (paper Fig. 5.9). On TPU the exchange is one fused kernel of
    double-buffered ``make_async_remote_copy`` sends to *both* neighbors
    per round with per-direction semaphores (``_rdma_bidi_kernel``); a
    fusable ``payload`` pair is butterflied in-kernel exactly like the
    unidirectional kernel (including the ``diag`` roundtrip payload mode).
    Off-TPU the exchange is the two counter-rotating
    ``ppermute`` streams of ``transpose.ring_exchange_bidi`` — the
    interpret-portable schedule CI pins bit-exact vs ``torus``. Multi-axis
    grid dimensions stage one bidirectional ring per mesh axis
    (``transpose.staged_exchange``), exactly like ``ring_exchange_rdma``.
    """
    assert interleave is None or payload is None, \
        "interleave (JAX-level thunk) and payload (in-kernel) are exclusive"
    assert diag is None or (payload is not None and not inverse), \
        "diag (roundtrip payload mode) needs a forward payload"
    axes = tuple(axes)
    p = compat.axes_size(axes)
    if p <= 1:
        return [jnp.asarray(a) for a in arrs], None
    if interpret is None:
        interpret = not use_rdma()
    comm_axes = tuple(a for a in axes if compat.axes_size((a,)) > 1)
    if len(comm_axes) > 1:
        ex = functools.partial(ring_exchange_bidi_rdma, interpret=interpret)
        return tr.staged_exchange(arrs, comm_axes, split_axis=split_axis,
                                  concat_axis=concat_axis, exchange=ex,
                                  interleave=interleave, payload=payload,
                                  diag=diag, inverse=inverse)
    if not interpret:
        # the fused kernel is atomic (see ring_exchange_rdma): non-fusable
        # compute is emitted before it, fusable compute rides the payload.
        # Only this branch meters: the interpret fallback below is
        # tr.ring_exchange_bidi, which meters its own rounds.
        tr._meter_exchange(comm_axes, p, tr.bidi_rounds(p), arrs,
                           dispatch_kind="rdma", dispatches=1)
        follow = interleave() if interleave is not None else None
        outs, fused = _ring_rdma_tpu(arrs, comm_axes,
                                     split_axis=split_axis,
                                     concat_axis=concat_axis, payload=payload,
                                     diag=diag, inverse=inverse, bidi=True)
        return outs, (fused if payload is not None else follow)
    if payload is not None:
        raise ValueError("payload fusion requires the TPU RDMA lowering; "
                         "pass interleave= on the interpret path")
    return tr.ring_exchange_bidi(arrs, axes, split_axis=split_axis,
                                 concat_axis=concat_axis,
                                 interleave=interleave)
