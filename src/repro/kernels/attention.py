"""Pallas TPU flash-attention forward kernel (GQA-aware).

The jnp-level chunked attention in ``models/layers.py`` is the portable
implementation (and what the CPU dry-run lowers); this kernel is the
TPU-native version of the same online-softmax dataflow, with explicit
BlockSpec VMEM tiling:

* grid = (batch·heads, S/blk_q, T/blk_k) — the kv dimension is the innermost
  (sequential) grid axis, so (m, l, acc) accumulators live in VMEM scratch
  across kv steps;
* K/V blocks are indexed per *kv-head* (grouped-query: q-head h reads kv-head
  h // group) so grouped heads never materialize repeated K/V;
* blocks strictly above the causal diagonal are masked (and contribute
  nothing); f32 accumulation, bf16/f32 inputs.

Validated in interpret mode against ``ref.flash_attention_ref`` and the
direct softmax oracle (tests/test_flash_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            blk_q: int, blk_k: int, causal: bool, scale: float, nk: int):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                  # (blk_q, d)
    k = k_ref[0]                                  # (blk_k, d)
    v = v_ref[0]
    s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T) * scale
    if causal:
        qpos = i * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = j * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v.astype(jnp.float32))
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "blk_q", "blk_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, blk_q: int = 256,
                    blk_k: int = 256, interpret: bool | None = None):
    """q: (B, S, H, D); k/v: (B, T, Hkv, D) with H % Hkv == 0 → (B, S, H, D)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    blk_q = min(blk_q, s)
    blk_k = min(blk_k, t)
    while s % blk_q:
        blk_q //= 2
    while t % blk_k:
        blk_k //= 2
    nq, nk = s // blk_q, t // blk_k
    scale = 1.0 / (d ** 0.5)

    qh = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kh = k.transpose(0, 2, 1, 3).reshape(b * hkv, t, d)
    vh = v.transpose(0, 2, 1, 3).reshape(b * hkv, t, d)

    def kv_index(bh, i, j):
        # grouped-query: q row (b*h + hh) reads kv row (b*hkv + hh//g)
        return (bh // h) * hkv + (bh % h) // g, j, 0

    out = pl.pallas_call(
        functools.partial(_kernel, blk_q=blk_q, blk_k=blk_k, causal=causal,
                          scale=scale, nk=nk),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, blk_k, d), kv_index),
            pl.BlockSpec((1, blk_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
