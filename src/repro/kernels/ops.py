"""Jit'd public wrappers around the FFT engine.

``fft1d(x_re, x_im, axis=..., backend=...)`` is the only entry point the rest
of the framework uses; ``backend`` selects:

* ``"pallas"`` — the Pallas radix-2 DIF engine (interpret mode off-TPU),
* ``"ref"``    — the pure-jnp oracle with the identical dataflow,
* ``"jnp"``    — ``jnp.fft`` (XLA's FFT), used as ground truth and as the
  fastest CPU path for large development runs,
* ``"mxu"``    — beyond-paper four-step FFT as MXU matmuls (fft_mxu.py).

All take/return planar complex (re, im) pairs, any float dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.fft_radix2 import fft1d_pallas, ifft1d_pallas

BACKENDS = ("pallas", "ref", "jnp", "mxu")


def _move_last(x, axis):
    return jnp.moveaxis(x, axis, -1)


def _unmove_last(x, axis):
    return jnp.moveaxis(x, -1, axis)


@functools.partial(jax.jit, static_argnames=("axis", "backend", "inverse"))
def fft1d(x_re, x_im, *, axis: int = -1, backend: str = "pallas", inverse: bool = False):
    """Complex-to-complex FFT along ``axis`` (planar in/out)."""
    assert backend in BACKENDS, backend
    xr, xi = _move_last(x_re, axis), _move_last(x_im, axis)
    if backend == "jnp":
        z = xr.astype(jnp.complex64 if xr.dtype == jnp.float32 else jnp.complex128)
        z = z + 1j * xi.astype(z.dtype)
        z = jnp.fft.ifft(z) if inverse else jnp.fft.fft(z)
        yr, yi = z.real.astype(xr.dtype), z.imag.astype(xr.dtype)
    elif backend == "ref":
        f = _ref.ifft_dif_planar if inverse else _ref.fft_dif_planar
        yr, yi = f(xr, xi)
    elif backend == "mxu":
        from repro.kernels.fft_mxu import fft1d_mxu
        if inverse:
            yr, yi = fft1d_mxu(xr, -xi)
            scale = jnp.asarray(1.0 / xr.shape[-1], xr.dtype)
            yr, yi = yr * scale, -yi * scale
        else:
            yr, yi = fft1d_mxu(xr, xi)
    else:
        f = ifft1d_pallas if inverse else fft1d_pallas
        yr, yi = f(xr, xi)
    return _unmove_last(yr, axis), _unmove_last(yi, axis)


@functools.partial(jax.jit, static_argnames=("axis", "backend", "packed"))
def rfft1d(x, *, axis: int = -1, backend: str = "pallas", packed: bool = False):
    """Real-to-complex FFT keeping N/2+1 bins (paper §3.2.5).

    ``packed=True`` enables the beyond-paper even/odd packing (one N/2-point
    complex FFT instead of an N-point one). The faithful default mirrors the
    thesis: run the general complex engine on (x, 0). Packing requires an
    even length — the even/odd split assumes ``n % 2 == 0``; odd lengths
    raise at trace time rather than silently mangling the spectrum.
    """
    xr = _move_last(x, axis)
    n = xr.shape[-1]
    if packed and n % 2:
        raise ValueError(
            f"rfft1d(packed=True) requires an even transform length (the "
            f"even/odd packing splits n into two n/2 streams), got n={n}; "
            f"use packed=False for odd lengths")
    if packed:
        yr, yi = _ref.rfft_packed_planar(xr) if backend != "pallas" else _rfft_packed_pallas(xr)
    else:
        zr, zi = fft1d(xr, jnp.zeros_like(xr), axis=-1, backend=backend)
        yr, yi = zr[..., : n // 2 + 1], zi[..., : n // 2 + 1]
    return _unmove_last(yr, axis), _unmove_last(yi, axis)


def _rfft_packed_pallas(x):
    """Packed R2C on top of the Pallas engine (untangle stays in jnp)."""
    import numpy as np

    n = x.shape[-1]
    h = n // 2
    zr, zi = fft1d_pallas(x[..., 0::2], x[..., 1::2])
    idx = (-jnp.arange(h)) % h
    zcr, zci = zr[..., idx], -zi[..., idx]
    er, ei = 0.5 * (zr + zcr), 0.5 * (zi + zci)
    o_r, o_i = 0.5 * (zi - zci), -0.5 * (zr - zcr)
    k = np.arange(h)
    wr = jnp.asarray(np.cos(-2 * np.pi * k / n), dtype=x.dtype)
    wi = jnp.asarray(np.sin(-2 * np.pi * k / n), dtype=x.dtype)
    yr = er + (o_r * wr - o_i * wi)
    yi = ei + (o_r * wi + o_i * wr)
    yr = jnp.concatenate([yr, er[..., :1] - o_r[..., :1]], axis=-1)
    yi = jnp.concatenate([yi, ei[..., :1] - o_i[..., :1]], axis=-1)
    return yr, yi


@functools.partial(jax.jit, static_argnames=("axis", "backend", "n"))
def irfft1d(x_re, x_im, *, n: int, axis: int = -1, backend: str = "pallas"):
    """Complex-to-real inverse, reconstructing the Hermitian upper half."""
    xr, xi = _move_last(x_re, axis), _move_last(x_im, axis)
    k = xr.shape[-1]
    assert k == n // 2 + 1, (k, n)
    # rebuild bins n/2+1 .. n-1 by conjugate symmetry
    idx = jnp.arange(n // 2 - 1, 0, -1)
    fr = jnp.concatenate([xr, xr[..., idx]], axis=-1)
    fi = jnp.concatenate([xi, -xi[..., idx]], axis=-1)
    yr, _ = fft1d(fr, fi, axis=-1, backend=backend, inverse=True)
    return _unmove_last(yr, axis)
