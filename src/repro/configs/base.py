"""Architecture config schema + the assigned input-shape set.

Every assigned architecture gets one ``configs/<id>.py`` exporting CONFIG
(exact literature numbers) and SMOKE (reduced same-family config for CPU
tests). Shapes are global; the launcher maps them onto the mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_norm_topk: bool = True
    every: int = 1              # MoE every k-th layer (jamba: 2)
    first_dense: int = 0        # leading dense layers (deepseek-v2: 1)
    impl: str = "dense"         # "dense" (einsum) | "ep" (shard_map all_to_all)
    chunks: int = 1             # pipelined dispatch slabs (paper §4.3.2)


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"
    qkv_bias: bool = False
    rope_base: float = 10000.0
    norm: str = "rms"           # rms | ln
    norm_plus_one: bool = False  # gemma RMSNorm (1 + w)
    embed_scale: bool = False    # gemma scales embeddings by sqrt(d)
    attn_kind: str = "gqa"      # gqa | mla
    mla: Optional[MLACfg] = None
    moe: Optional[MoECfg] = None
    mixer: str = "attn"         # attn | rwkv | hybrid(jamba)
    hybrid_period: int = 8      # jamba: 1 attn per 8 layers
    hybrid_attn_pos: int = 4
    mamba: Optional[MambaCfg] = None
    encdec: bool = False        # whisper
    enc_layers: int = 0
    embed_mode: str = "tokens"  # tokens | embeds (vlm) | frames (audio stub)
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    remat: bool = True
    train_microbatches: int = 1   # gradient accumulation (memory-term knob)
    kv_quant: bool = False        # int8 KV cache for decode (uniform GQA path)

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM / hybrid only)"""
        return self.mixer in ("rwkv", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs have a decoder


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """Skip rules per the assignment: long_500k needs sub-quadratic attention;
    encoder-only archs would skip decode (none assigned are encoder-only)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "skipped_full_attention"
    return True, "ok"


def count_params(cfg: ArchConfig) -> int:
    """Analytic parameter count (total, incl. all experts)."""
    d, v, hd = cfg.d_model, cfg.vocab, cfg.head_dim_
    n_attn_per_layer = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
    if cfg.attn_kind == "mla":
        m = cfg.mla
        qd = m.qk_nope_dim + m.qk_rope_dim
        n_attn_per_layer = (d * cfg.n_heads * qd + d * m.kv_lora_rank
                            + d * m.qk_rope_dim
                            + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)
                            + cfg.n_heads * m.v_head_dim * d)
    glu = cfg.mlp_type in ("swiglu", "geglu")
    mlp = d * cfg.d_ff * (3 if glu else 2)
    total = 0
    if cfg.mixer == "rwkv":
        tm = 5 * d + d + 2 * 64 * d + d + 5 * d * d + 2 * d
        cm = 2 * d + 2 * d * cfg.d_ff + d * d
        total += cfg.n_layers * (tm + cm + 2 * d)
    elif cfg.mixer == "hybrid":
        from repro.models.mamba import MambaDims
        md = MambaDims(d, cfg.mamba.d_state, cfg.mamba.d_conv, cfg.mamba.expand)
        di = md.d_inner
        mam = (d * 2 * di + md.d_conv * di + di
               + di * (md.dt_rank + 2 * md.d_state) + md.dt_rank * di + di
               + di * md.d_state + di + di * d)
        n_attn = cfg.n_layers // cfg.hybrid_period
        n_mamba = cfg.n_layers - n_attn
        total += n_attn * n_attn_per_layer + n_mamba * mam
        n_moe = cfg.n_layers // (cfg.moe.every if cfg.moe else 1) if cfg.moe else 0
        n_dense = cfg.n_layers - n_moe
        moe_p = (d * cfg.moe.n_experts
                 + cfg.moe.n_experts * 3 * d * cfg.moe.d_ff_expert) if cfg.moe else 0
        total += n_moe * moe_p + n_dense * mlp + cfg.n_layers * 2 * d
    else:
        n_moe = 0
        if cfg.moe:
            n_moe = (cfg.n_layers - cfg.moe.first_dense) // cfg.moe.every
        n_dense = cfg.n_layers - n_moe
        moe_p = 0
        if cfg.moe:
            moe_p = (d * cfg.moe.n_experts
                     + cfg.moe.n_experts * 3 * d * cfg.moe.d_ff_expert)
            if cfg.moe.n_shared:
                moe_p += 3 * d * (cfg.moe.d_ff_shared or cfg.moe.n_shared * cfg.moe.d_ff_expert)
        total += (cfg.n_layers * (n_attn_per_layer + 2 * d)
                  + n_dense * mlp + n_moe * moe_p)
    if cfg.encdec:
        total += cfg.enc_layers * (n_attn_per_layer + mlp + 2 * d)
        total += cfg.n_layers * n_attn_per_layer  # cross attention
    total += v * d * (1 if cfg.tie_embeddings else 2) + d
    return total


def count_active_params(cfg: ArchConfig) -> int:
    """Active params per token (MoE: top_k + shared experts only)."""
    if not cfg.moe:
        return count_params(cfg)
    full = count_params(cfg)
    moe_all = cfg.moe.n_experts * 3 * cfg.d_model * cfg.moe.d_ff_expert
    moe_act = cfg.moe.top_k * 3 * cfg.d_model * cfg.moe.d_ff_expert
    if cfg.mixer == "hybrid":
        n_moe = cfg.n_layers // cfg.moe.every
    else:
        n_moe = (cfg.n_layers - cfg.moe.first_dense) // cfg.moe.every
    return full - n_moe * (moe_all - moe_act)
