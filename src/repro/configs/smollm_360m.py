"""smollm-360m — llama-arch small [hf:HuggingFaceTB/SmolLM; hf].
32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152; tied embeddings."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560,
    vocab=49152, tie_embeddings=True)

SMOKE = ArchConfig(
    arch_id="smollm-360m-smoke", family="dense",
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, d_ff=128, vocab=512,
    tie_embeddings=True, compute_dtype="float32", remat=False)
