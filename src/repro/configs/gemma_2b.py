"""gemma-2b — GeGLU, head_dim=256, MQA [arXiv:2403.08295; hf].
18L d_model=2048 8H (kv=1) d_ff=16384 vocab=256000; RMSNorm(1+w), embeds
scaled by sqrt(d); tied embeddings."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000, mlp_type="geglu", norm_plus_one=True,
    embed_scale=True, tie_embeddings=True)

SMOKE = ArchConfig(
    arch_id="gemma-2b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=32, d_ff=160,
    vocab=512, mlp_type="geglu", norm_plus_one=True, embed_scale=True,
    tie_embeddings=True, compute_dtype="float32", remat=False)
