"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].
48L d_model=2048 32H (GQA kv=4) expert d_ff=768 vocab=151936; head_dim 128."""
from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    arch_id="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936,
    moe=MoECfg(n_experts=128, top_k=8, d_ff_expert=768, router_norm_topk=True,
               impl="ep", chunks=4),
    train_microbatches=4)

SMOKE = ArchConfig(
    arch_id="qwen3-moe-30b-a3b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=64,
    vocab=512,
    moe=MoECfg(capacity_factor=8.0, n_experts=4, top_k=2, d_ff_expert=64, router_norm_topk=True),
    compute_dtype="float32", remat=False)
