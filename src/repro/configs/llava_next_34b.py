"""llava-next-34b — anyres tiling VLM [hf:llava-hf/llava-v1.6; unverified].
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000. Backbone only; the
vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (assignment spec)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000, embed_mode="embeds",
    train_microbatches=4)

SMOKE = ArchConfig(
    arch_id="llava-next-34b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=512, embed_mode="embeds", compute_dtype="float32", remat=False)
