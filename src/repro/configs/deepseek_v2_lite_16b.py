"""deepseek-v2-lite-16b — MLA kv_lora=512, 2 shared + 64 routed top-6
[arXiv:2405.04434; hf]. 27L d_model=2048 16H vocab=102400; expert d_ff=1408;
first layer dense (d_ff=10944)."""
from repro.configs.base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    arch_id="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
    vocab=102400, attn_kind="mla",
    mla=MLACfg(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
               d_ff_shared=2816, first_dense=1, router_norm_topk=False,
               impl="ep", chunks=4),
    train_microbatches=4)

SMOKE = ArchConfig(
    arch_id="deepseek-v2-lite-16b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=512,
    attn_kind="mla",
    mla=MLACfg(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    moe=MoECfg(capacity_factor=8.0, n_experts=4, top_k=2, d_ff_expert=32, n_shared=1,
               d_ff_shared=64, first_dense=1, router_norm_topk=False),
    compute_dtype="float32", remat=False)
