"""Assigned-architecture registry (--arch <id>) + the paper's FFT configs."""

from repro.configs.base import (ArchConfig, MLACfg, MambaCfg, MoECfg,
                                SHAPES, ShapeCfg, shape_applicable,
                                count_params, count_active_params)

__all__ = ["ArchConfig", "MLACfg", "MambaCfg", "MoECfg", "SHAPES",
           "ShapeCfg", "shape_applicable", "count_params",
           "count_active_params", "get_config", "REGISTRY",
           "SMOKE_REGISTRY", "ARCH_IDS"]

from repro.configs import (rwkv6_3b, llava_next_34b, smollm_360m, deepseek_7b,
                           qwen1_5_4b, gemma_2b, deepseek_v2_lite_16b,
                           qwen3_moe_30b_a3b, whisper_small,
                           jamba_1_5_large_398b)

_MODULES = [rwkv6_3b, llava_next_34b, smollm_360m, deepseek_7b, qwen1_5_4b,
            gemma_2b, deepseek_v2_lite_16b, qwen3_moe_30b_a3b, whisper_small,
            jamba_1_5_large_398b]

REGISTRY = {m.CONFIG.arch_id: m.CONFIG for m in _MODULES}
SMOKE_REGISTRY = {m.CONFIG.arch_id: m.SMOKE for m in _MODULES}
ARCH_IDS = list(REGISTRY)


def get_config(arch_id: str, smoke: bool = False) -> ArchConfig:
    reg = SMOKE_REGISTRY if smoke else REGISTRY
    if arch_id not in reg:
        raise KeyError(f"unknown arch '{arch_id}'; available: {sorted(reg)}")
    return reg[arch_id]
