"""jamba-1.5-large-398b — Mamba+attn 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf]. 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536; bf16 params + bf16 optimizer moments to fit 16 GB/chip HBM
(fit analysis in EXPERIMENTS.md §Dry-run)."""
from repro.configs.base import ArchConfig, MambaCfg, MoECfg

CONFIG = ArchConfig(
    arch_id="jamba-1.5-large-398b", family="hybrid", mixer="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536, hybrid_period=8, hybrid_attn_pos=4,
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
    moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=24576, every=2,
               impl="ep", chunks=4),
    param_dtype="bfloat16", opt_state_dtype="bfloat16",
    train_microbatches=8)

SMOKE = ArchConfig(
    arch_id="jamba-1.5-large-398b-smoke", family="hybrid", mixer="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=512, hybrid_period=8, hybrid_attn_pos=4,
    mamba=MambaCfg(d_state=8, d_conv=4, expand=2),
    moe=MoECfg(capacity_factor=8.0, n_experts=4, top_k=2, d_ff_expert=128, every=2),
    compute_dtype="float32", remat=False)
