"""qwen1.5-4b — QKV bias [hf:Qwen/Qwen1.5; hf].
40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, d_ff=6912,
    vocab=151936, qkv_bias=True,
    train_microbatches=2)

SMOKE = ArchConfig(
    arch_id="qwen1.5-4b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=512,
    qkv_bias=True, compute_dtype="float32", remat=False)
