"""The paper's own problem configurations: 3D FFT sizes N = 512..8192 on
P <= 1024 nodes (Table 5.7 grid), with engine parameters (R, Q, l_op, f)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class FFTProblem:
    n: int
    p: int                      # total processing elements (Pu*Pv)
    mu: int = 1                 # vector components
    r: int = 4                  # engine rows
    q: int = 4                  # engines per node (pipelined: 2X+Y+Z)
    l_op: int = 9
    f_mhz: float = 180.0
    schedule: str = "pipelined"
    net: str = "switched"
    real: bool = True           # physical fields are real-valued


PAPER_PROBLEMS = {
    f"fft{n}_p{p}": FFTProblem(n=n, p=p)
    for n in (512, 1024, 2048, 4096, 8192)
    for p in (1, 4, 16, 64, 256, 1024)
}
