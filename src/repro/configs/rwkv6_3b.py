"""rwkv6-3b — Finch, data-dependent decay [arXiv:2404.05892; hf].
32L d_model=2560 (attn-free), d_ff=8960, vocab=65536; head_size 64 -> 40 heads."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="rwkv6-3b", family="ssm", mixer="rwkv",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=8960,
    vocab=65536, norm="ln",
    train_microbatches=2)

SMOKE = ArchConfig(
    arch_id="rwkv6-3b-smoke", family="ssm", mixer="rwkv",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    norm="ln", compute_dtype="float32", remat=False)
