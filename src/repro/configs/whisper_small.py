"""whisper-small — enc-dec, conv frontend (stub) [arXiv:2212.04356].
12+12L d_model=768 12H d_ff=3072 vocab=51865; LayerNorm + GELU; the audio
frontend is a STUB: input_specs() provides precomputed frame embeddings."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-small", family="audio",
    n_layers=12, enc_layers=12, encdec=True, d_model=768, n_heads=12,
    n_kv_heads=12, d_ff=3072, vocab=51865, norm="ln", mlp_type="gelu",
    embed_mode="frames",
    train_microbatches=4)

SMOKE = ArchConfig(
    arch_id="whisper-small-smoke", family="audio",
    n_layers=2, enc_layers=2, encdec=True, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=512, norm="ln", mlp_type="gelu",
    embed_mode="frames", compute_dtype="float32", remat=False)
