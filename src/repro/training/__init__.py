"""Training step factory and loop."""
