"""Training step factory: FSDP×TP sharded AdamW step with remat,
microbatching (gradient accumulation), and optional compressed cross-pod
gradient sync (shard_map manual over the pod axis only)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ArchConfig
from repro.models.transformer import RunCfg, lm_loss
from repro.optim import adamw
from repro.distributed import compression as comp


@dataclasses.dataclass(frozen=True)
class TrainCfg:
    microbatches: int = 1
    grad_compression: bool = False   # cross-pod int8 + error feedback
    adamw: adamw.AdamWConfig = adamw.AdamWConfig()


def make_loss_fn(cfg: ArchConfig, run: RunCfg):
    def loss_fn(params, batch):
        return lm_loss(cfg, run, params, batch)
    return loss_fn


def make_train_step(cfg: ArchConfig, run: RunCfg, tcfg: TrainCfg):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    Jit it with in/out shardings from ``repro.distributed.sharding``.
    """
    loss_fn = make_loss_fn(cfg, run)

    def grads_of(params, batch):
        if tcfg.microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        # gradient accumulation over the leading batch dim
        def split(x):
            b = x.shape[0]
            mb = tcfg.microbatches
            return x.reshape(mb, b // mb, *x.shape[1:])
        parts = jax.tree.map(split, batch)

        def body(carry, mb_batch):
            acc_loss, acc_g = carry
            mb_loss, g = jax.value_and_grad(loss_fn)(params, mb_batch)
            return (acc_loss + mb_loss, jax.tree.map(jnp.add, acc_g, g)), None
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (tl, tg), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero), parts)
        inv = 1.0 / tcfg.microbatches
        return tl * inv, jax.tree.map(lambda g: g * inv, tg)

    def step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        new_params, new_opt, metrics = adamw.update(
            tcfg.adamw, grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    if not tcfg.grad_compression or run.mesh is None or \
            "pod" not in run.mesh.shape:
        return step

    # --- compressed cross-pod DP: manual over `pod`, auto inside ------------
    from jax.sharding import PartitionSpec as P

    def step_compressed(params, opt_state, residuals, batch):
        def inner(params, opt_state, residuals, batch):
            loss, grads = grads_of(params, batch)
            grads, residuals = comp.pod_sync_compressed(grads, residuals, "pod")
            loss = jax.lax.pmean(loss, "pod")
            new_params, new_opt, metrics = adamw.update(
                tcfg.adamw, grads, opt_state, params)
            return new_params, new_opt, residuals, dict(metrics, loss=loss)
        rep = jax.tree.map(lambda _: P(), params)
        return compat.shard_map(
            inner, mesh=run.mesh,
            in_specs=(rep, jax.tree.map(lambda _: P(), opt_state),
                      jax.tree.map(lambda _: P(), residuals),
                      jax.tree.map(lambda a: P("pod"), batch)),
            out_specs=(rep, jax.tree.map(lambda _: P(), opt_state),
                       jax.tree.map(lambda _: P(), residuals),
                       {"grad_norm": P(), "lr": P(), "loss": P()}),
            check_vma=False, axis_names={"pod"},
        )(params, opt_state, residuals, batch)

    return step_compressed
