"""Cross-pod distribution utilities: sharding rules, gradient compression."""
