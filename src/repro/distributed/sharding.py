"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Params carry logical axis names (the mirror tree built by ``init_model``);
this module turns them into ``PartitionSpec``s with two safeguards:

* a mesh axis is used at most once per spec (first logical axis wins);
* a dim must divide evenly by the mesh-axis size, else it falls back to
  replication (e.g. smollm's 15 heads on a 16-way model axis).

DP over (pod, data); FSDP = params' ``embed`` dim over ``data``; TP over
``model`` (heads / mlp / vocab / experts). The FFT subsystem maps its pencil
grid (Pu, Pv) onto the same axes (DESIGN.md §3).
"""

from __future__ import annotations

import math

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes (tuple entries mean "all of these")
PARAM_RULES = {
    "embed": ("data",),          # FSDP / ZeRO-3 param sharding
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "heads_x": ("model",),       # rwkv fused-head projections
    "mlp": ("model",),
    "expert_mlp": None,
    "experts": ("model",),       # expert parallelism
    "kv_lora": None,
    "embed_out": ("model",),
    "head_dim": None, "layers": None, "sub": None, "seq": None,
    "five": None, "two": None, "conv": None, "state": None, "lora": None,
}

ACT_RULES = {
    "batch": ("data",),
    "seq": None, "embed": None, "heads": ("model",), "kv_heads": ("model",),
    "mlp": ("model",), "experts": ("model",), "head_dim": None,
    "vocab": ("model",),
}


def multipod_rules(rules):
    """Extend DP/FSDP axes with the pod axis: batch over (pod, data)."""
    out = dict(rules)
    if "batch" in out:
        out["batch"] = ("pod", "data")
    return out


def _axes_size(mesh: Mesh, axes) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def spec_for(mesh: Mesh, logical: tuple, shape: tuple, rules) -> P:
    """PartitionSpec for one param given its logical axes and shape."""
    used: set = set()
    parts = []
    for dim, name in zip(shape, logical):
        rule = rules.get(name)
        if rule is None:
            parts.append(None)
            continue
        rule = tuple(a for a in rule if a in mesh.shape and a not in used)
        if not rule or dim % _axes_size(mesh, rule) != 0:
            parts.append(None)
            continue
        used.update(rule)
        parts.append(rule if len(rule) > 1 else rule[0])
    return P(*parts)


def tree_specs(mesh: Mesh, axes_tree, shapes_tree, rules=None):
    """Pytree of PartitionSpecs mirroring params."""
    rules = rules or PARAM_RULES
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    return jax.tree.map(
        lambda ax, sh: spec_for(mesh, ax, sh.shape, rules),
        axes_tree, shapes_tree, is_leaf=is_axes)


def tree_shardings(mesh: Mesh, axes_tree, shapes_tree, rules=None):
    specs = tree_specs(mesh, axes_tree, shapes_tree, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh: Mesh, ndim: int, rules=None) -> P:
    """Batch-leading activation spec: (batch, ...replicated)."""
    rules = rules or ACT_RULES
    b = tuple(a for a in rules.get("batch", ()) if a in mesh.shape)
    lead = b if len(b) > 1 else (b[0] if b else None)
    return P(*((lead,) + (None,) * (ndim - 1)))


def cache_specs(mesh: Mesh, cache_shapes, cfg, *, seq_shard: bool = False,
                rules=None):
    """Decode-cache shardings: batch over (pod,data), kv heads over model if
    divisible; ``seq_shard`` (long_500k) shards the time axis over data."""
    rules = rules or ACT_RULES
    b_axes = tuple(a for a in rules.get("batch", ("data",)) if a in mesh.shape)
    b_lead = b_axes if len(b_axes) > 1 else (b_axes[0] if b_axes else None)

    def one(path_leaf_shape):
        name, sh = path_leaf_shape
        if name == "len" or len(sh) == 0:
            return P()
        if name in ("k", "v", "xk", "xv", "k_scale", "v_scale"):  # (L,B,T,[H,]D)
            parts = [None] * len(sh)
            if seq_shard:
                if b_axes and sh[2] % _axes_size(mesh, b_axes) == 0:
                    parts[2] = b_lead
            else:
                if b_lead is not None and sh[1] % _axes_size(mesh, b_axes) == 0:
                    parts[1] = b_lead
            if len(sh) >= 5 and "model" in mesh.shape:
                if sh[3] % mesh.shape["model"] == 0:
                    parts[3] = "model"
                elif sh[4] % mesh.shape["model"] == 0:
                    # kv heads don't divide (e.g. 20 heads / 16-way): shard
                    # head_dim instead — replicating a 32k cache costs
                    # 16×of HBM (qwen1.5 decode_32k: 108 GiB observed)
                    parts[4] = "model"
            return P(*parts)
        # states (rwkv/mamba): (L, B, ...) or (L, sub, B, ...)
        parts = [None] * len(sh)
        bdim = 1 if name in ("x_tm", "wkv", "x_cm") else 2
        if b_lead is not None and len(sh) > bdim and sh[bdim] % _axes_size(mesh, b_axes) == 0:
            parts[bdim] = b_lead
        return P(*parts)

    return {k: (NamedSharding(mesh, one((k, tuple(v.shape)))) if hasattr(v, "shape")
                else NamedSharding(mesh, P()))
            for k, v in cache_shapes.items()}
