"""Gradient compression for cross-pod data parallelism.

Int8 per-tensor quantization with **error feedback** (the residual carries to
the next step, so compression error doesn't bias convergence). Applied to the
pod-axis gradient sync in the train step: inter-pod links (DCN) are the slow
fabric, so grads cross them at 1/4 width; intra-pod (ICI) reductions stay
full precision. ``simulate=True`` applies quantize→dequantize without the
collective, for single-pod convergence testing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat


def quantize_int8(g):
    """Per-tensor symmetric int8; returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, residuals):
    """Returns (quantized_tree, new_residuals). Residual = g - deq(q)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return (q, s), g32 - deq
    out = jax.tree.map(one, grads, residuals)
    qtree = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    rtree = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return qtree, rtree


def decompress(qtree, like):
    return jax.tree.map(lambda qs, g: dequantize_int8(*qs).astype(g.dtype),
                        qtree, like, is_leaf=lambda x: isinstance(x, tuple))


def pod_sync_compressed(grads, residuals, axis: str = "pod"):
    """Inside shard_map(manual over the pod axis): quantize per pod, psum the
    int8 payload (sum of quantized grads ≈ quantized sum; error feedback
    absorbs the difference), average, dequantize."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        new_r = g32 - deq
        tot = jax.lax.psum(deq, axis) / compat.axis_size(axis)
        return tot.astype(g.dtype), new_r
    out = jax.tree.map(one, grads, residuals)
    g2 = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    r2 = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return g2, r2


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
