"""Spectral-solver driver: run any registered case on any mesh.

    PYTHONPATH=src python -m repro.solvers.cli --case poisson --n 32 --mesh 4x2
    PYTHONPATH=src python -m repro.solvers.cli --case navier_stokes \\
        --n 16 --steps 4 --autotune
    PYTHONPATH=src python -m repro.solvers.cli --case heat --n 16 --steps 2 \\
        --mesh 4x2 --trace trace.json      # Perfetto-loadable span trace

Builds the Pu×Pv pencil mesh (faking host devices when needed), constructs
the solver — optionally on the plan ``repro.tuning.autotune_solver_step``
picked by timing the case's *whole* step — runs ``--steps`` cycles printing
the observables, and checks the case's analytic validation (non-zero exit
on failure). ``--trace PATH`` records the run through ``repro.obs``
(dispatch spans per step, wire counters) and writes a Chrome-trace JSON.
"""

from __future__ import annotations

import argparse
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.solvers.cli",
        description="Run a spectral-solver case on the distributed 3D FFT.")
    ap.add_argument("--case", required=True,
                    help="solver case (poisson | heat | navier_stokes | nls)")
    ap.add_argument("--n", type=int, default=32, help="cubic grid extent N")
    ap.add_argument("--steps", type=int, default=4, help="time steps to run")
    ap.add_argument("--mesh", default="4x2", help="Pu x Pv pencil grid")
    ap.add_argument("--dt", type=float, default=None,
                    help="time step (default: the case's own)")
    ap.add_argument("--dtype", default="float64",
                    help="state dtype; float64 enables x64 for the process")
    ap.add_argument("--nu", type=float, default=None,
                    help="viscosity (navier_stokes only)")
    ap.add_argument("--comm-engine", default="",
                    help="TransposeEngine for the fold communications "
                         "(switched | torus | overlap_ring | pallas_ring | "
                         "bidi_ring; default: the solver's own plan default)")
    ap.add_argument("--autotune", action="store_true",
                    help="pick the FFT plan by autotuning the whole solver "
                         "step instead of the pipelined/switched default")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the per-step observable lines")
    ap.add_argument("--trace", dest="trace_path", default="",
                    help="write a Chrome-trace JSON (Perfetto-loadable) of "
                         "the run: dispatch/solver.step spans with the "
                         "perf-model prediction plus the wire counters")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    if args.trace_path:
        from repro import obs
        obs.clear()
        obs.enable()

    from repro.launch.mesh import ensure_host_devices, parse_mesh_arg
    pu, pv = parse_mesh_arg(args.mesh)
    ensure_host_devices(pu * pv)

    import jax
    import numpy as np

    from repro import compat
    from repro.core import precision

    if np.dtype(args.dtype).itemsize >= 8:
        precision.enable_x64()
    if len(jax.devices()) < pu * pv:
        raise SystemExit(f"need {pu * pv} devices for mesh {args.mesh}, "
                         f"have {len(jax.devices())}")
    mesh = compat.make_mesh((pu, pv), ("data", "model"))

    from repro.solvers import SOLVERS, make_solver
    if args.case not in SOLVERS:
        raise SystemExit(f"unknown case {args.case!r}; have {sorted(SOLVERS)}")

    phys: dict = {}
    if args.dt is not None:
        phys["dt"] = args.dt
    if args.nu is not None:
        if args.case != "navier_stokes":
            raise SystemExit("--nu only applies to --case navier_stokes")
        phys["nu"] = args.nu

    plan_cfg = None
    if args.autotune:
        from repro.tuning.solver import autotune_solver_step
        res = autotune_solver_step(mesh, args.case, args.n,
                                   dtype=args.dtype, params=phys,
                                   verbose=not args.quiet)
        plan_cfg = res.best_config
        hit = "cache hit" if res.cache_hit else "measured"
        print(f"autotuned solver step ({hit}): {res.best.name}  "
              f"{res.best_us:.1f} us/step")
    if args.comm_engine:
        # an explicit engine choice overrides whatever the default (or the
        # autotuned winner) would use for the fold communications
        plan_cfg = dict(plan_cfg or {}, comm_engine=args.comm_engine)

    try:
        solver = make_solver(args.case, mesh, args.n, dtype=args.dtype,
                             plan_cfg=plan_cfg, **phys)
    except ValueError as e:  # e.g. N not divisible by the pencil grid
        raise SystemExit(f"invalid problem for mesh {args.mesh}: {e}")
    print(f"case={args.case} N={args.n}^3 mesh={pu}x{pv} "
          f"dtype={solver.dtype.name} dt={solver.dt:g} "
          f"plan={solver.plan.backend}/{solver.plan.schedule}"
          f"/{solver.plan.comm_engine} "
          f"[{jax.devices()[0].platform}:{len(jax.devices())} devices]",
          flush=True)

    t0 = time.time()

    def show(state, obs):
        if args.quiet:
            return
        vals = "  ".join(f"{k} = {v:.6e}" for k, v in sorted(obs.items())
                         if k != "t")
        print(f"step {state.n_steps:3d}  t = {obs['t']:.4f}  {vals}",
              flush=True)

    state, history = solver.run(args.steps, callback=show)
    wall = time.time() - t0
    ok, lines = solver.validate(history)
    for line in lines:
        print(line)
    print(f"{args.case}: {'OK' if ok else 'FAILED'}   "
          f"{wall / max(args.steps, 1) * 1e3:.1f} ms/step "
          f"(incl. compile)")
    if args.trace_path:
        from repro import obs
        obs.disable()
        obs.write_chrome_trace(args.trace_path, obs.tracer, obs.metrics)
        print(f"wrote trace {args.trace_path} "
              f"({len(obs.tracer.events())} spans)")
        if not args.quiet:
            print(obs.summary_table(obs.tracer, obs.metrics))
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
