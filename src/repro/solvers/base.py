"""The ``SpectralSolver`` contract — the paper's simulation cycle as a class.

§1.2 frames the machine's purpose as the pseudo-spectral loop

    forward 3D FFT → spectral computation → inverse 3D FFT → local computation

over a distributed ``FFT3DPlan``. A :class:`SpectralSolver` packages one
such workload:

* ``init_state(plan)``   — build the t=0 :class:`SolverState`;
* ``step(state)``        — advance one Δt (one or more FFT cycles), jitted
  through ``shard_map`` over the plan's pencil grid;
* ``observables(state)`` — grid-reduced scalar diagnostics (energy, error
  norms, conserved quantities) as a ``{name: float}`` dict.

Concrete solvers implement the *local* hooks (``initial_fields`` /
``step_fields`` / ``observables_fields``) plus a ``validate`` check against
an analytic or NumPy reference; the base class owns plan construction
(including the x64/dtype gate), shard_map compilation, and the run loop.

The FFT plan knobs (backend / schedule / chunks / comm_engine /
vector_mode / r2c_packed / fused_roundtrip) come either from ``plan_cfg``
— e.g. the winner of ``repro.tuning.autotune_solver_step``, which times
*this class's whole step* per candidate — or from the same
pipelined/switched default the Navier–Stokes example always used.

Solvers whose spectral stage is a pointwise-diagonal k-space multiply
(heat, poisson, the NLS kinetic half-step) declare it via the
``spectral_kernel`` hook and step through
:func:`repro.core.fft3d.spectral_roundtrip_local`, which streams the
Y↔Z roundtrip as one slab pipeline when the plan's ``fused_roundtrip``
knob is on (and is the plain composed cycle when it is off).

The **batched** entry points (``batched_step`` / ``batched_observables``)
advance a stack of B independent instances of the same problem — the same
step body ``jax.vmap``-ed over an unsharded leading batch axis inside the
same ``shard_map`` — in one dispatch on the mesh; ``repro.serving`` builds
its request batching on them.
"""

from __future__ import annotations

import abc
import dataclasses
import functools
from typing import Any, ClassVar

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat, obs
from repro.core import perfmodel as pm, precision
from repro.core.decomposition import PencilGrid
from repro.core.fft3d import FFT3DPlan


@dataclasses.dataclass
class SolverState:
    """Evolving solver state: sharded field pytree + host-side clock."""

    fields: Any                # pytree of (possibly sharded) jax arrays
    t: float = 0.0             # physical time
    n_steps: int = 0


class SpectralSolver(abc.ABC):
    """Common contract every FFT-cycle simulation workload implements."""

    case: ClassVar[str]            # registry name (``--case`` on the CLI)
    real: ClassVar[bool] = True    # r2c transform (False: planar complex)
    components: ClassVar[int] = 0  # leading vector axis (0 = scalar field)

    def __init__(self, mesh, n, *, dt: float = 1e-2, dtype="float64",
                 plan_cfg: dict | None = None):
        self.mesh = mesh
        self.n = (n, n, n) if isinstance(n, int) else tuple(n)
        self.dt = float(dt)
        self.dtype = np.dtype(precision.require_dtype(
            dtype, who=f"solvers.{self.case}"))
        grid = PencilGrid.from_mesh(mesh)
        cfg = dict(schedule="pipelined", chunks=2, backend="jnp",
                   comm_engine="switched", r2c_packed=False,
                   fused_roundtrip=False)
        self.vector_mode = "streaming"
        if plan_cfg:
            from repro.tuning.space import normalize_config
            plan_cfg = normalize_config(plan_cfg)
            cfg.update({k: plan_cfg[k] for k in cfg if k in plan_cfg})
            self.vector_mode = plan_cfg.get("vector_mode", self.vector_mode)
        self.plan = FFT3DPlan(n=self.n, grid=grid, real=self.real,
                              dtype=self.dtype.name, **cfg)
        self._compile()

    # ---- solver-specific hooks ------------------------------------------
    @abc.abstractmethod
    def initial_fields(self):
        """Global t=0 field pytree (host-side; base shards it on first use)."""

    @abc.abstractmethod
    def step_fields(self, plan: FFT3DPlan, fields):
        """One Δt of the FFT→spectral→iFFT→local cycle (inside shard_map)."""

    @abc.abstractmethod
    def observables_fields(self, plan: FFT3DPlan, fields) -> dict:
        """Grid-reduced scalar diagnostics (inside shard_map)."""

    def spectral_kernel(self, plan: FFT3DPlan, dtype):
        """The solver's k-space stage as a ``fft3d.DiagonalKernel``, when
        it is a pointwise-diagonal multiply (heat's ``e^{−κk²Δt}``,
        poisson's ``−1/k²``, NLS' kinetic rotation). ``None`` (the
        default) means the stage is not diagonal — e.g. the Navier–Stokes
        nonlinear term — and the fused-roundtrip executor does not apply."""
        del plan, dtype
        return None

    @abc.abstractmethod
    def validate(self, history: list[dict]) -> tuple[bool, list[str]]:
        """(ok, report lines) judging a run against the analytic reference.

        ``history[i]`` is ``observables`` after i steps (``history[0]`` is
        t=0), each dict augmented with ``"t"``.
        """

    def params(self) -> dict:
        """Physics parameters identifying this problem (cache fingerprint)."""
        return {"dt": self.dt}

    # ---- compiled machinery ---------------------------------------------
    def field_spec(self) -> P:
        """PartitionSpec prefix applied to every leaf of ``fields``."""
        base = self.plan.grid.pencil_spec()
        return P(None, *base) if self.components else base

    def _compile(self):
        plan, mesh, spec = self.plan, self.mesh, self.field_spec()
        self._stepj = jax.jit(compat.shard_map(
            functools.partial(self.step_fields, plan), mesh=mesh,
            in_specs=(spec,), out_specs=spec, check_vma=False))
        self._obsj = jax.jit(compat.shard_map(
            functools.partial(self.observables_fields, plan), mesh=mesh,
            in_specs=(spec,), out_specs=P(), check_vma=False))
        self._batched_stepj = None   # built lazily by batched_step_fns()

    # ---- batched stepping (the serving layer's entry point) --------------
    def batch_spec(self) -> P:
        """``field_spec()`` with an unsharded leading batch axis prepended:
        B independent problem instances stacked along axis 0, each shard
        holding the full batch of its own pencil."""
        return P(None, *self.field_spec())

    def batched_step_fns(self):
        """``(step, observables)`` jitted over a leading batch axis.

        ``step`` maps a fields pytree whose leaves carry an extra leading
        axis of size B — B independent simulations of *this* problem,
        stacked — through one sharded solver step: the per-instance
        ``step_fields`` body is ``jax.vmap``-ed over the batch axis inside
        the same ``shard_map`` the solo path compiles, so the whole batch
        advances in a single dispatch on the mesh and each lane's
        trajectory is bitwise what the solo ``step()`` computes (CI pins
        this across the mesh × engine matrix). ``observables`` reduces the
        same stack to ``{name: (B,) array}``.

        Compiled lazily on first use and cached on the solver; jit's shape
        cache keys on B, so a given batch size compiles once per solver.
        """
        if self._batched_stepj is None:
            plan, mesh, bspec = self.plan, self.mesh, self.batch_spec()
            self._batched_stepj = jax.jit(compat.shard_map(
                jax.vmap(functools.partial(self.step_fields, plan)),
                mesh=mesh, in_specs=(bspec,), out_specs=bspec,
                check_vma=False))
            self._batched_obsj = jax.jit(compat.shard_map(
                jax.vmap(functools.partial(self.observables_fields, plan)),
                mesh=mesh, in_specs=(bspec,), out_specs=P(None),
                check_vma=False))
        return self._batched_stepj, self._batched_obsj

    def batched_step(self, fields):
        """One Δt for a leading-batch-axis stack of field pytrees."""
        return self.batched_step_fns()[0](fields)

    def batched_observables(self, fields) -> dict:
        """``{name: (B,) float array}`` diagnostics for a batched stack."""
        return self.batched_step_fns()[1](fields)

    def problem_key(self) -> str:
        """This solver's plan-cache fingerprint key — the canonical id of
        (case, shape, dtype, physics params, substrate) that
        ``repro.tuning`` keys tuned plans by and ``repro.serving`` groups
        batchable requests by."""
        from repro.tuning.cache import problem_fingerprint

        g = self.plan.grid
        key, _ = problem_fingerprint(
            self.n, g.pu, g.pv, real=self.real, components=self.components,
            dtype=self.dtype.name, u_axes=g.u_axes, v_axes=g.v_axes,
            case=self.case, solver_params=self.params())
        return key

    # ---- checkpoint contract (repro.fleet rides on this) -----------------
    def state_tree(self, state: SolverState):
        """``state`` as a checkpointable pytree for ``CheckpointManager``.

        Leaves are the (sharded) field arrays plus the host-side clock as
        0-d numpy scalars; flat tree paths are mesh-shape-independent, so a
        snapshot written here restores on any pencil grid of the same
        problem (:meth:`restore_state` is the inverse)."""
        return {"fields": state.fields,
                "t": np.float64(state.t),
                "n_steps": np.int64(state.n_steps)}

    def restore_state(self, manager, step: int | None = None
                      ) -> tuple[SolverState, dict]:
        """``(state, manifest meta)`` from ``manager``'s checkpoint.

        The elastic path of the fleet's retry loop: the snapshot may have
        been written by a solver of the same problem on a *different*
        submesh shape — leaves are stored as full logical arrays, and this
        method re-places them with **this** solver's shardings
        (``NamedSharding(self.mesh, self.field_spec())``). Restoring onto
        the same shape is bitwise; a different shape changes only the
        layout, so the continued trajectory matches to roundoff."""
        fields = self.initial_fields()         # shape/dtype template
        target = {"fields": fields, "t": np.float64(0.0),
                  "n_steps": np.int64(0)}
        sh = jax.sharding.NamedSharding(self.mesh, self.field_spec())
        shardings = {"fields": jax.tree.map(lambda _: sh, fields)}
        tree, meta = manager.restore(target, step=step, shardings=shardings)
        return SolverState(fields=tree["fields"], t=float(tree["t"]),
                           n_steps=int(tree["n_steps"])), meta

    # ---- public contract -------------------------------------------------
    def init_state(self, plan: FFT3DPlan | None = None) -> SolverState:
        assert plan is None or plan == self.plan, \
            "a solver steps the plan it was compiled for"
        return SolverState(fields=self.initial_fields(), t=0.0, n_steps=0)

    def predict_step_us(self) -> float:
        """The perf model's time for one ``step()`` of this solver's plan
        (µs). Diagonal-kernel solvers price the full spectral roundtrip of
        their plan (fused when the plan streams it); others price the same
        roundtrip composed — the absolute number is a nominal-substrate
        estimate either way, and the bench drift gate tracks its *error*
        against a baseline rather than trusting it outright."""
        cached = getattr(self, "_predict_step_us", None)
        if cached is None:
            g = self.plan.grid
            diagonal = (type(self).spectral_kernel
                        is not SpectralSolver.spectral_kernel)
            est = pm.estimate_roundtrip_seconds(
                self.n, g.pu, g.pv, spec=self.plan.spec(),
                fused=self.plan.fused_roundtrip and diagonal,
                mu=max(self.components, 1),
                pu_axes=g.u_sizes, pv_axes=g.v_sizes)
            cached = self._predict_step_us = round(est * 1e6, 3)
        return cached

    def step(self, state: SolverState) -> SolverState:
        if not obs.is_enabled():
            return SolverState(fields=self._stepj(state.fields),
                               t=state.t + self.dt, n_steps=state.n_steps + 1)
        with obs.span("dispatch/solver.step", case=self.case,
                      engine=self.plan.comm_engine,
                      model_predicted_us=self.predict_step_us()):
            fields = self._stepj(state.fields)
            jax.block_until_ready(fields)
        return SolverState(fields=fields, t=state.t + self.dt,
                           n_steps=state.n_steps + 1)

    def observables(self, state: SolverState) -> dict:
        with obs.span("dispatch/solver.observables"):
            out = {k: float(v) for k, v in self._obsj(state.fields).items()}
        out["t"] = state.t
        return out

    def run(self, steps: int, *, callback=None):
        """Advance ``steps`` Δt from t=0; returns (state, observable history)."""
        state = self.init_state()
        history = [self.observables(state)]
        if callback:
            callback(state, history[-1])
        for _ in range(steps):
            state = self.step(state)
            history.append(self.observables(state))
            if callback:
                callback(state, history[-1])
        return state, history

    def plan_config(self) -> dict:
        """The FFT-plan knobs this solver compiled against (bench metadata)."""
        p = self.plan
        return {"backend": p.backend, "schedule": p.schedule,
                "chunks": p.chunks, "comm_engine": p.comm_engine,
                "net": p.net, "vector_mode": self.vector_mode,
                "r2c_packed": p.r2c_packed,
                "fused_roundtrip": p.fused_roundtrip, "dtype": p.dtype}
