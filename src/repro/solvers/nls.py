"""Split-step nonlinear Schrödinger / Gross–Pitaevskii solver.

The MD-adjacent quantum workload: ``i ∂ψ/∂t = −½∇²ψ + g|ψ|²ψ`` on the 2π³
torus, advanced by Strang-split split-step Fourier — the classic spectral
integrator whose every step is literally the paper's cycle:

    local: ψ ← ψ·e^{−i g|ψ|² Δt/2}        (nonlinear half-kick, physical)
    forward 3D FFT (complex)
    spectral: ψ̂ ← ψ̂·e^{−i k² Δt/2}        (exact kinetic propagator)
    inverse 3D FFT
    local: ψ ← ψ·e^{−i g|ψ|² Δt/2}        (second half-kick)

Both sub-steps are pointwise phase rotations, so the wavefunction norm
``∫|ψ|²`` is conserved to roundoff — the validation check. The state is the
planar physical wavefunction ``(re ψ, im ψ)``; this is the one solver
exercising the complex (c2c) transform path.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import spectral as sp
from repro.core.fft3d import DiagonalKernel, spectral_roundtrip_local
from repro.solvers.base import SpectralSolver


class NLSSolver(SpectralSolver):
    case = "nls"
    real = False        # complex wavefunction: c2c transforms
    components = 0

    def __init__(self, mesh, n, *, g: float = 1.0, dt: float = 1e-3, **kw):
        self.g = float(g)
        super().__init__(mesh, n, dt=dt, **kw)

    def params(self) -> dict:
        return {"dt": self.dt, "g": self.g}

    def initial_fields(self):
        ny, nz, nx = self.n[1], self.n[2], self.n[0]
        x = np.linspace(0, 2 * np.pi, nx, endpoint=False)
        y = np.linspace(0, 2 * np.pi, ny, endpoint=False)
        z = np.linspace(0, 2 * np.pi, nz, endpoint=False)
        Y, Z, X = np.meshgrid(y, z, x, indexing="ij")  # (y, z, x) X-pencil
        # smooth condensate with a phase ramp and a density perturbation
        psi = (1.0 + 0.2 * np.cos(X) * np.cos(Y) * np.cos(Z)) \
            * np.exp(1j * np.sin(Z))
        return (jnp.asarray(psi.real.astype(self.dtype)),
                jnp.asarray(psi.imag.astype(self.dtype)))

    def _half_kick(self, pr, pi):
        """ψ ← ψ·e^{−i g|ψ|² Δt/2} — the local nonlinear phase rotation."""
        theta = -self.g * (pr * pr + pi * pi) * (self.dt / 2)
        c, s = jnp.cos(theta), jnp.sin(theta)
        return pr * c - pi * s, pr * s + pi * c

    def spectral_kernel(self, plan, dtype):
        """Exact kinetic propagator ``e^{−i k² Δt/2}`` as a complex
        diagonal: multiply by ``cos θ + i sin θ``, θ = −k²Δt/2."""
        theta = -0.5 * sp.k_squared(plan, dtype) * self.dt
        return DiagonalKernel(dr=jnp.cos(theta), di=jnp.sin(theta))

    def step_fields(self, plan, fields):
        pr, pi = self._half_kick(*fields)
        kern = self.spectral_kernel(plan, pr.dtype)
        pr, pi = spectral_roundtrip_local(plan, kern, pr, pi)
        return self._half_kick(pr, pi)

    def observables_fields(self, plan, fields):
        pr, pi = fields
        ntot = plan.n[0] * plan.n[1] * plan.n[2]
        dv = (2 * jnp.pi) ** 3 / ntot
        density = pr * pr + pi * pi
        return {"norm": sp.grid_sum(plan, jnp.sum(density)) * dv,
                "density_max": sp.grid_max(plan, jnp.max(density))}

    def validate(self, history):
        n0, nT = history[0]["norm"], history[-1]["norm"]
        drift = abs(nT - n0) / max(abs(n0), 1e-300)
        tol = 1e-10 if self.dtype == np.float64 else 1e-5
        ok = drift < tol
        return ok, [f"nls norm conservation: drift {drift:.2e} over "
                    f"{len(history) - 1} steps (< {tol:g}): {ok}"]
