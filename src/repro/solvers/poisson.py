"""Poisson benchmark solver ``∇²φ = f`` with a manufactured solution.

The FFT-offload workload of the ab-initio MD / electrostatics family: each
"step" is one forward transform, one spectral Laplacian inversion
(:func:`repro.core.spectral.invert_laplacian`, zero-mean gauge), and one
inverse transform. The manufactured solution

    φ(x, y, z) = sin(x)·cos(2y)·sin(3z),   f = ∇²φ = −14·φ

is resolved exactly on any grid with N ≥ 8, so the recovered φ must match
to near machine precision (~1e-10 in f64) — making this case both a
correctness gate and a clean per-step latency benchmark of the bare cycle.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import spectral as sp
from repro.core.fft3d import DiagonalKernel, spectral_roundtrip_local
from repro.solvers.base import SpectralSolver

_K2 = 1 + 4 + 9  # |k|² of the manufactured mode


class PoissonSolver(SpectralSolver):
    case = "poisson"
    real = True
    components = 0

    def __init__(self, mesh, n, *, dt: float = 1.0, **kw):
        super().__init__(mesh, n, dt=dt, **kw)

    def _exact(self):
        ny, nz, nx = self.n[1], self.n[2], self.n[0]
        x = np.linspace(0, 2 * np.pi, nx, endpoint=False)
        y = np.linspace(0, 2 * np.pi, ny, endpoint=False)
        z = np.linspace(0, 2 * np.pi, nz, endpoint=False)
        Y, Z, X = np.meshgrid(y, z, x, indexing="ij")  # (y, z, x) X-pencil
        return np.sin(X) * np.cos(2 * Y) * np.sin(3 * Z)

    def initial_fields(self):
        phi = self._exact().astype(self.dtype)
        f = (-_K2 * phi).astype(self.dtype)
        # fields: (source f, exact φ, current iterate φ — starts at 0)
        return (jnp.asarray(f), jnp.asarray(phi), jnp.zeros_like(phi))

    def spectral_kernel(self, plan, dtype):
        """``φ̂ = −f̂/k²`` in the zero-mean gauge (k=0 and r2c pad zeroed) —
        the multiplier of :func:`repro.core.spectral.invert_laplacian`."""
        k2 = sp.k_squared(plan, dtype)
        inv = jnp.where(k2 > 0, -1.0 / jnp.maximum(k2, 1e-30), 0.0)
        if plan.real:
            inv = inv * sp.pad_mask(plan, dtype)
        return DiagonalKernel(dr=inv)

    def step_fields(self, plan, fields):
        f, phi_exact, _ = fields
        kern = self.spectral_kernel(plan, f.dtype)
        phi = spectral_roundtrip_local(plan, kern, f)
        return (f, phi_exact, phi)

    def observables_fields(self, plan, fields):
        f, phi_exact, phi = fields
        err = jnp.abs(phi - phi_exact)
        return {"err_inf": sp.grid_max(plan, jnp.max(err)),
                "err_l2": jnp.sqrt(sp.grid_sum(plan, jnp.sum(err * err))),
                "phi_max": sp.grid_max(plan, jnp.max(jnp.abs(phi)))}

    def validate(self, history):
        if len(history) < 2:
            return False, ["poisson: needs at least one step to solve"]
        err = history[-1]["err_inf"]
        tol = 1e-10 if self.dtype == np.float64 else 1e-4
        ok = err < tol
        return ok, [f"poisson manufactured solution err_inf = {err:.2e} "
                    f"(< {tol:g}): {ok}"]
