"""Incompressible Navier–Stokes — the paper's §1.2 case study as a solver.

Pseudo-spectral rotational form on the 2π³ torus:

    ∂v̂/∂t = P( \\widehat{u × ω} ) − ν k² v̂,    ∇·v = 0

The state lives in spectral space (planar ``(vr, vi)``, 3 components); the
nonlinear stage is :func:`repro.core.spectral.rotational_nonlinear_term`
(two inverse + one forward vector FFT per evaluation), time stepping is the
shared integrating-factor RK4 (:func:`integrators.ifrk4`) — the stiff
viscous term is integrated exactly, RK4 handles convection. A Leray
projection after each step pins the velocity to the divergence-free
manifold.

Ported out of ``examples/navier_stokes.py`` (now a thin CLI wrapper).
The Taylor–Green vortex validation — monotone viscous energy decay and
``max|k·v̂|`` at roundoff — matches the example's historical checks.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import spectral as sp
from repro.core.fft3d import fft3d_vector_local
from repro.solvers import integrators
from repro.solvers.base import SpectralSolver


class NavierStokesSolver(SpectralSolver):
    case = "navier_stokes"
    real = True
    components = 3

    def __init__(self, mesh, n, *, nu: float = 0.1, dt: float = 2e-3, **kw):
        self.nu = float(nu)
        super().__init__(mesh, n, dt=dt, **kw)

    def params(self) -> dict:
        return {"dt": self.dt, "nu": self.nu}

    def initial_fields(self):
        """Taylor–Green vortex, transformed to spectral space."""
        import functools

        import jax

        from repro import compat

        nx = self.n[0]
        x = np.linspace(0, 2 * np.pi, nx, endpoint=False)
        Y, Z, X = np.meshgrid(x, x, x, indexing="ij")  # (y, z, x) layout
        u = np.cos(X) * np.sin(Y) * np.sin(Z)
        v = -np.sin(X) * np.cos(Y) * np.sin(Z)
        w = np.zeros_like(u)
        u0 = jnp.asarray(np.stack([u, v, w]).astype(self.dtype))
        spec = self.field_spec()
        fwd = jax.jit(compat.shard_map(
            functools.partial(fft3d_vector_local, self.plan,
                              vector_mode=self.vector_mode),
            mesh=self.mesh, in_specs=(spec, None), out_specs=(spec, spec),
            check_vma=False))
        return fwd(u0, None)

    def step_fields(self, plan, fields):
        decay = -self.nu * sp.k_squared(plan, fields[0].dtype)

        def nonlin(y):
            return sp.rotational_nonlinear_term(
                plan, y[0], y[1], vector_mode=self.vector_mode)

        vr, vi = integrators.ifrk4(nonlin, decay, fields, self.dt)
        return sp.project_divergence_free(plan, vr, vi)

    def observables_fields(self, plan, fields):
        vr, vi = fields
        return {"energy": sp.energy_spectrum_total(plan, vr, vi),
                "max_div": sp.max_divergence(plan, vr, vi)}

    def validate(self, history):
        energies = [h["energy"] for h in history]
        decays = all(b <= a * (1 + 1e-9) for a, b in zip(energies,
                                                         energies[1:]))
        div_tol = 1e-8 if self.dtype == np.float64 else 1e-3
        div_ok = all(h["max_div"] < div_tol for h in history)
        lines = [f"energy monotone decay: {decays}",
                 f"divergence-free (max|k.v| < {div_tol:g}): {div_ok}"]
        return decays and div_ok, lines
