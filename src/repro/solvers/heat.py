"""3D heat / diffusion equation ``∂u/∂t = κ ∇²u`` on the 2π³ torus.

Each step is one full FFT cycle: forward r2c transform, exact spectral
propagator ``e^{−κk²Δt}`` (a :class:`repro.core.fft3d.DiagonalKernel`
stepped through ``spectral_roundtrip_local`` — streamed per kx-slab when
the plan's ``fused_roundtrip`` knob is on), inverse transform. The
single-mode initial
condition ``u₀ = sin(m_x x)·cos(m_y y)·cos(m_z z)`` decays analytically as
``e^{−κ|m|²t}``, which ``validate`` checks to near machine precision.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import spectral as sp
from repro.core.fft3d import DiagonalKernel, spectral_roundtrip_local
from repro.solvers.base import SpectralSolver


class HeatSolver(SpectralSolver):
    case = "heat"
    real = True
    components = 0

    def __init__(self, mesh, n, *, kappa: float = 0.1, dt: float = 1e-2,
                 mode=(2, 1, 0), **kw):
        self.kappa = float(kappa)
        self.mode = tuple(int(m) for m in mode)
        super().__init__(mesh, n, dt=dt, **kw)

    def params(self) -> dict:
        return {"dt": self.dt, "kappa": self.kappa, "mode": list(self.mode)}

    def initial_fields(self):
        ny, nz, nx = self.n[1], self.n[2], self.n[0]
        x = np.linspace(0, 2 * np.pi, nx, endpoint=False)
        y = np.linspace(0, 2 * np.pi, ny, endpoint=False)
        z = np.linspace(0, 2 * np.pi, nz, endpoint=False)
        Y, Z, X = np.meshgrid(y, z, x, indexing="ij")  # (y, z, x) X-pencil
        mx, my, mz = self.mode
        u0 = np.sin(mx * X) * np.cos(my * Y) * np.cos(mz * Z)
        return (jnp.asarray(u0.astype(self.dtype)),)

    def spectral_kernel(self, plan, dtype):
        """Exact propagator of ``∂u = κ∇²u``: multiply by ``e^{−κk²Δt}``."""
        return DiagonalKernel(
            dr=jnp.exp(-self.kappa * sp.k_squared(plan, dtype) * self.dt))

    def step_fields(self, plan, fields):
        (u,) = fields
        kern = self.spectral_kernel(plan, u.dtype)
        return (spectral_roundtrip_local(plan, kern, u),)

    def observables_fields(self, plan, fields):
        (u,) = fields
        ntot = plan.n[0] * plan.n[1] * plan.n[2]
        return {"amp": sp.grid_max(plan, jnp.max(jnp.abs(u))),
                "mean": sp.grid_sum(plan, jnp.sum(u)) / ntot,
                "energy": sp.grid_sum(plan, jnp.sum(u * u))}

    def validate(self, history):
        k2 = float(sum(m * m for m in self.mode))
        lines, ok = [], True
        last = history[-1]
        expected = history[0]["amp"] * np.exp(-self.kappa * k2 * last["t"])
        rel = abs(last["amp"] - expected) / max(expected, 1e-300)
        tol = 1e-8 if self.dtype == np.float64 else 1e-4
        ok = rel < tol
        lines.append(f"heat decay rate: amp {last['amp']:.6e} vs analytic "
                     f"{expected:.6e} (rel err {rel:.2e} < {tol:g}): {ok}")
        return ok, lines
