"""Shared spectral time integrators.

All integrators operate on an arbitrary pytree of arrays (a solver's state
fields — typically planar ``(re, im)`` pairs, possibly with a leading
component axis) so every solver reuses the same stepping machinery:

* :func:`rk4` — classic explicit 4th-order Runge–Kutta on ``∂y = rhs(y)``.
* :func:`ifrk4` — integrating-factor RK4 for ``∂y = decay·y + N(y)``: the
  stiff diagonal linear term (e.g. spectral diffusion ``−νk²``) is
  integrated *exactly* through exponential factors, RK4 handles only the
  nonlinearity. With ``N ≡ 0`` this is the exact propagator, which is how
  the heat solver steps.
* :func:`exp_decay` — that exact linear propagator alone.

``decay`` is a single real array broadcastable against every leaf of ``y``
(spectral multipliers act identically on the re and im planes).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import tree_util


def _map(f, *trees):
    return tree_util.tree_map(f, *trees)


def _axpy(a, x, y):
    """y + a·x, leafwise."""
    return _map(lambda xi, yi: yi + a * xi, x, y)


def rk4(rhs, y, dt):
    """One classic RK4 step of ``∂y = rhs(y)`` on a pytree state."""
    k1 = rhs(y)
    k2 = rhs(_axpy(dt / 2, k1, y))
    k3 = rhs(_axpy(dt / 2, k2, y))
    k4 = rhs(_axpy(dt, k3, y))
    return _map(
        lambda yi, a, b, c, d: yi + (dt / 6) * (a + 2 * b + 2 * c + d),
        y, k1, k2, k3, k4)


def exp_decay(decay, y, dt):
    """Exact propagator of ``∂y = decay·y``: y ← e^{decay·dt} y."""
    e = jnp.exp(decay * dt)
    return _map(lambda yi: e * yi, y)


def ifrk4(nonlin, decay, y, dt):
    """Integrating-factor RK4 for ``∂y = decay·y + N(y)``.

    Substituting ``w = e^{-decay·t} y`` removes the stiff term exactly;
    RK4 on ``w`` then gives (E = e^{decay·dt/2}):

        k1 = N(y)
        k2 = N(E·(y + dt/2·k1))
        k3 = N(E·y + dt/2·k2)
        k4 = N(E²·y + dt·E·k3)
        y ← E²·y + dt/6·(E²·k1 + 2E·(k2 + k3) + k4)
    """
    e1 = jnp.exp(decay * (dt / 2))
    e2 = e1 * e1
    mul = lambda e, t: _map(lambda a: e * a, t)
    k1 = nonlin(y)
    k2 = nonlin(mul(e1, _axpy(dt / 2, k1, y)))
    k3 = nonlin(_axpy(dt / 2, k2, mul(e1, y)))
    k4 = nonlin(_axpy(dt, mul(e1, k3), mul(e2, y)))
    return _map(
        lambda yi, a, b, c, d: e2 * yi + (dt / 6) * (e2 * a + 2 * e1 * (b + c)
                                                     + d),
        y, k1, k2, k3, k4)
