"""``repro.solvers`` — FFT-based simulation workloads over ``FFT3DPlan``.

The paper builds its multi-FPGA 3D-FFT machine *for numerical simulations*
(§1.2); this package is that simulation layer. Every solver implements the
:class:`~repro.solvers.base.SpectralSolver` contract (``init_state / step /
observables``, each step the FFT → spectral → iFFT → local cycle) on top of
the distributed transform, sharing the spectral operator vocabulary of
``repro.core.spectral`` and the time integrators of
:mod:`repro.solvers.integrators`.

Registered cases:

* ``poisson``       — manufactured-solution Poisson benchmark (bare cycle),
* ``heat``          — 3D diffusion, exact exponential spectral propagator,
* ``navier_stokes`` — incompressible pseudo-spectral NS (Taylor–Green),
* ``nls``           — split-step nonlinear Schrödinger / Gross–Pitaevskii.

``python -m repro.solvers.cli --case <name>`` runs any case on any mesh;
``repro.tuning.autotune_solver_step`` tunes the FFT plan against a case's
whole step; ``benchmarks.run --only solvers`` puts per-step latencies on
the perf trajectory.
"""

from __future__ import annotations

from repro.solvers.base import SolverState, SpectralSolver
from repro.solvers.heat import HeatSolver
from repro.solvers.navier_stokes import NavierStokesSolver
from repro.solvers.nls import NLSSolver
from repro.solvers.poisson import PoissonSolver

SOLVERS: dict[str, type[SpectralSolver]] = {
    cls.case: cls
    for cls in (PoissonSolver, HeatSolver, NavierStokesSolver, NLSSolver)
}

__all__ = ["SolverState", "SpectralSolver", "SOLVERS", "make_solver",
           "PoissonSolver", "HeatSolver", "NavierStokesSolver", "NLSSolver"]


def make_solver(case: str, mesh, n, **kwargs) -> SpectralSolver:
    """Instantiate a registered solver case (``kwargs`` → its constructor)."""
    try:
        cls = SOLVERS[case]
    except KeyError:
        raise ValueError(f"unknown solver case {case!r}; "
                         f"have {sorted(SOLVERS)}") from None
    return cls(mesh, n, **kwargs)


__all__ = ["SOLVERS", "SolverState", "SpectralSolver", "HeatSolver",
           "NavierStokesSolver", "NLSSolver", "PoissonSolver", "make_solver"]
