"""JAX version-compatibility shim.

The codebase targets the modern sharding API (``jax.shard_map`` with
``check_vma`` / ``axis_names``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.AxisType``); older installs (e.g. JAX 0.4.x) ship the same
machinery under ``jax.experimental.shard_map.shard_map`` with ``check_rep`` /
``auto`` and a ``make_mesh`` without ``axis_types``. Every mesh/shard_map
call site routes through this module so the rest of the tree stays written
against one API.

Public surface:

* ``AxisType``            — ``jax.sharding.AxisType`` or an enum fallback.
* ``axis_types_kwargs(n)``— kwargs dict for ``jax.make_mesh`` (``{}`` on old JAX).
* ``make_mesh(shape, axis_names)`` — version-independent mesh constructor.
* ``shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...,
  axis_names=...)`` — the new-API signature on either JAX.
"""

from __future__ import annotations

import enum

import jax

try:  # JAX >= 0.5: first-class axis types
    AxisType = jax.sharding.AxisType
    HAS_AXIS_TYPES = True
except AttributeError:  # JAX 0.4.x: every mesh axis is implicitly "auto"

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPES = False

HAS_JAX_SHARD_MAP = hasattr(jax, "shard_map")


def axis_types_kwargs(n_axes: int, kind=None) -> dict:
    """``axis_types=`` kwargs for ``jax.make_mesh``; empty on old JAX."""
    if not HAS_AXIS_TYPES:
        return {}
    return {"axis_types": ((kind or AxisType.Auto),) * n_axes}


def make_mesh(axis_shapes, axis_names, **kwargs):
    """``jax.make_mesh`` with Auto axis types where the install supports them."""
    return jax.make_mesh(axis_shapes, axis_names,
                         **axis_types_kwargs(len(tuple(axis_names))), **kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on new JAX; on old JAX the ``Mesh`` object is itself the
    context manager that establishes the thread-resource mesh.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def axis_size(name) -> int:
    """``lax.axis_size`` (static int) inside shard_map on any supported JAX.

    Old JAX lacks ``lax.axis_size``; there ``lax.psum(1, name)`` of a Python
    literal is constant-folded to the bound axis size at trace time.
    """
    from jax import lax
    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)


def axes_size(axes) -> int:
    """Product of the bound sizes of a tuple of mesh axis names (static)."""
    out = 1
    for a in axes:
        out *= axis_size(a)
    return out


def flat_axis_index(axes):
    """Row-major flat rank over a tuple of mesh axes (0 for the empty tuple).

    The shared helper behind every multi-axis processor-grid dimension
    (``u_axes``/``v_axes`` spanning e.g. ``("pod", "data")``).
    """
    from jax import lax
    if not axes:
        return 0
    idx = lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * axis_size(a) + lax.axis_index(a)
    return idx


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, axis_names=None):
    """New-API ``jax.shard_map`` signature on any supported JAX.

    On old JAX, ``check_vma`` maps to ``check_rep`` and ``axis_names`` (the
    set of *manual* axes) maps to its complement ``auto`` (the mesh axes left
    automatic).
    """
    if HAS_JAX_SHARD_MAP:
        kw = {}
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
