"""Persistent compiled-engine registry: admission never recompiles a hot
shape.

The registry maps a request fingerprint (:func:`repro.serving.request.
request_key`) to a live :class:`~repro.solvers.base.SpectralSolver`
instance. The first admission of a fingerprint builds the solver — and,
when the request pins no explicit ``plan_cfg``, consults the persistent
plan cache (``repro.tuning.cache``) under the solver's own
``problem_key()`` so a previously autotuned plan is picked up without any
timing sweep at admission time. Every later admission of the same
fingerprint returns the same instance: its jitted step functions (solo and
batched) stay warm, so serving a hot shape costs one dispatch, zero
compiles. (Distinct *batch sizes* of a hot shape each compile once — jit's
shape cache keys on B.)

Counters: ``serving.engine_cache.hits`` / ``serving.engine_cache.misses``
(per admission lookup); the plan-cache consult shows up on the existing
``plan_cache.hits`` / ``plan_cache.misses``.
"""

from __future__ import annotations

import threading

from repro import obs
from repro.serving.request import SimRequest, request_key


class EngineRegistry:
    """Compiled solver engines for one device mesh, keyed by fingerprint."""

    def __init__(self, mesh, *, use_plan_cache: bool = True,
                 cache_path: str | None = None):
        self.mesh = mesh
        self.use_plan_cache = use_plan_cache
        self.cache_path = cache_path
        self._engines: dict[str, object] = {}
        self._lock = threading.Lock()

    def get(self, req: SimRequest, fingerprint: str | None = None):
        """The (possibly shared) compiled solver serving ``req``'s shape."""
        key = fingerprint or request_key(req)
        with self._lock:
            solver = self._engines.get(key)
        if solver is not None:
            obs.metrics.inc("serving.engine_cache.hits")
            return solver
        obs.metrics.inc("serving.engine_cache.misses")
        solver = self._build(req)
        with self._lock:
            # a racing admission may have built it first — keep the winner
            # so every requester of the fingerprint shares one jit cache
            solver = self._engines.setdefault(key, solver)
        return solver

    def _build(self, req: SimRequest):
        from repro.solvers import make_solver

        plan_cfg = dict(req.plan_cfg) if req.plan_cfg is not None else None
        solver = make_solver(req.case, self.mesh, req.n, dtype=req.dtype,
                             plan_cfg=plan_cfg, **dict(req.params))
        if plan_cfg is None and self.use_plan_cache:
            # reuse a step-autotuned plan when one is cached for exactly
            # this problem+substrate; solver construction is cheap (jit is
            # lazy), so probing with the default plan first costs no compile
            from repro.tuning.cache import PlanCache

            entry = PlanCache(self.cache_path).get(solver.problem_key())
            if entry is not None and entry.get("best"):
                solver = make_solver(req.case, self.mesh, req.n,
                                     dtype=req.dtype,
                                     plan_cfg=dict(entry["best"]),
                                     **dict(req.params))
        return solver

    def engines(self) -> dict[str, object]:
        """Snapshot of the live fingerprint → solver map."""
        with self._lock:
            return dict(self._engines)

    def __len__(self) -> int:
        with self._lock:
            return len(self._engines)
