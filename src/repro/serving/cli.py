"""Batched spectral-simulation serving driver.

    PYTHONPATH=src python -m repro.serving.cli --case heat --n 16 --mesh 4x2 \\
        --requests 8 --steps 3 --max-batch 4 --validate --trace serve.trace.json

Builds the Pu×Pv pencil mesh (faking host devices when needed), starts an
in-process :class:`~repro.serving.server.SimServer`, and drives it with a
load-generator schedule of ``--requests`` same-shape requests (initial
amplitudes spread per request so the lanes are distinct trajectories).
Prints the per-request latency table and the throughput/latency-tail
summary; ``--validate`` additionally replays each streamed history through
the case's analytic ``validate`` (non-zero exit on failure). ``--trace``
writes a Perfetto-loadable Chrome trace of the run — ``serve/admit``
admission spans, ``dispatch/serving.batch_step`` batch dispatches, and the
``serving.*`` queue/batch counters and gauges.

``python -m repro.launch.serve --sim ...`` forwards here, next to the LM
serving path.
"""

from __future__ import annotations

import argparse
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.serving.cli",
        description="Serve batched spectral-simulation requests on one mesh.")
    ap.add_argument("--case", default="heat",
                    help="solver case (poisson | heat | navier_stokes | nls)")
    ap.add_argument("--n", type=int, default=16, help="cubic grid extent N")
    ap.add_argument("--steps", type=int, default=3,
                    help="time steps per request")
    ap.add_argument("--mesh", default="4x2", help="Pu x Pv pencil grid")
    ap.add_argument("--dtype", default="float32",
                    help="state dtype; float64 enables x64 for the process")
    ap.add_argument("--requests", type=int, default=8,
                    help="load-generator request count")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="max same-fingerprint requests per sharded step")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="queue depth bound (backpressure; default unbounded)")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="arrival rate in requests/s (0 = burst all at once)")
    ap.add_argument("--comm-engine", default="",
                    help="pin the TransposeEngine for the fold "
                         "communications (switched | torus | overlap_ring | "
                         "pallas_ring | bidi_ring)")
    ap.add_argument("--validate", action="store_true",
                    help="replay each streamed history through the case's "
                         "analytic validate()")
    ap.add_argument("--quiet", action="store_true",
                    help="suppress the per-request latency lines")
    ap.add_argument("--trace", dest="trace_path", default="",
                    help="write a Chrome-trace JSON (Perfetto-loadable) of "
                         "the run: admission spans, batched step dispatches, "
                         "and the serving.* queue/batch metrics")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)

    if args.trace_path:
        from repro import obs
        obs.clear()
        obs.enable()

    from repro.launch.mesh import ensure_host_devices, parse_mesh_arg
    pu, pv = parse_mesh_arg(args.mesh)
    ensure_host_devices(pu * pv)

    import jax
    import numpy as np

    from repro import compat
    from repro.core import precision

    if np.dtype(args.dtype).itemsize >= 8:
        precision.enable_x64()
    if len(jax.devices()) < pu * pv:
        raise SystemExit(f"need {pu * pv} devices for mesh {args.mesh}, "
                         f"have {len(jax.devices())}")
    mesh = compat.make_mesh((pu, pv), ("data", "model"))

    from repro.serving import (SimRequest, SimServer, request_key, run_load)
    from repro.solvers import SOLVERS
    if args.case not in SOLVERS:
        raise SystemExit(f"unknown case {args.case!r}; have {sorted(SOLVERS)}")
    if args.requests < 1:
        raise SystemExit(f"--requests must be >= 1, got {args.requests}")

    plan_cfg = {"comm_engine": args.comm_engine} if args.comm_engine else None
    # distinct initial amplitudes: every lane is its own trajectory, but
    # all share one fingerprint so the scheduler batches them
    reqs = [SimRequest(case=args.case, n=args.n, steps=args.steps,
                       dtype=args.dtype, plan_cfg=plan_cfg,
                       scale=1.0 + 0.25 * i, request_id=f"req-{i}")
            for i in range(args.requests)]
    server = SimServer(mesh, max_batch=args.max_batch,
                       max_pending=args.max_pending)
    print(f"serve: case={args.case} N={args.n}^3 mesh={pu}x{pv} "
          f"dtype={args.dtype} requests={args.requests} "
          f"steps={args.steps} max_batch={args.max_batch} "
          f"rate={'burst' if args.rate <= 0 else f'{args.rate:g}/s'} "
          f"fingerprint={request_key(reqs[0])} "
          f"[{jax.devices()[0].platform}:{len(jax.devices())} devices]",
          flush=True)

    t0 = time.time()
    report = run_load(server, reqs, rate_hz=args.rate)
    wall = time.time() - t0

    failed = [r for r in report.results if not r.ok]
    for r in report.results:
        if args.quiet:
            continue
        tail = (f"FAILED: {r.error}" if not r.ok else
                f"{len(r.history) - 1} steps  "
                f"final t={r.history[-1]['t']:.4f}")
        print(f"  {r.request.request_id:8s} batch={r.batch_size}  "
              f"latency={r.latency_s * 1e3:8.2f} ms  {tail}", flush=True)
    s = report.stats()
    print(f"served {s['n_requests']} requests in {wall:.2f} s  "
          f"({s['requests_per_s']:.2f} req/s incl. compile)  "
          f"latency p50={s['p50_us'] / 1e3:.1f} ms "
          f"p95={s['p95_us'] / 1e3:.1f} ms p99={s['p99_us'] / 1e3:.1f} ms",
          flush=True)

    ok = not failed
    if args.validate and ok:
        for r in report.results:
            solver = server.registry.get(r.request)
            v_ok, lines = solver.validate(r.history)
            if not v_ok or not args.quiet:
                for line in lines:
                    print(f"  {r.request.request_id}: {line}")
            ok = ok and v_ok
        print(f"validate: {'OK' if ok else 'FAILED'} "
              f"({len(report.results)} streamed histories)")
    elif failed:
        print(f"serve: {len(failed)} request(s) FAILED "
              f"({failed[0].error})")

    if args.trace_path:
        from repro import obs
        obs.disable()
        obs.write_chrome_trace(args.trace_path, obs.tracer, obs.metrics)
        print(f"wrote trace {args.trace_path} "
              f"({len(obs.tracer.events())} spans)")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
