"""The serving contract: ``SimRequest`` in, streamed ``StepUpdate``s and a
``SimResult`` out.

A :class:`SimRequest` names one simulation to run — a registered
``repro.solvers`` case, its grid extent, dtype, physics parameters, how
many Δt steps to advance, and optionally an explicit FFT-plan config. The
server answers with a :class:`Ticket` whose event stream carries one
:class:`StepUpdate` per time step (the case's grid-reduced observables,
exactly what a solo ``SpectralSolver.run`` would record) and terminates
with a :class:`SimResult`.

**Batching semantics.** Requests are grouped by :func:`request_key` — the
canonical fingerprint of everything that shapes the *compiled step*:
``(case, n, dtype, params, plan_cfg)``. Same-key requests are batched into
one sharded solver step over a leading batch axis
(``SpectralSolver.batched_step``); they may differ only in the per-request
knobs that don't enter the fingerprint: ``steps`` (how far to run),
``scale`` (the initial-condition amplitude), and ``request_id``. Two
requests that spell the same physics differently (one passing a default
explicitly) get different keys and simply don't batch — correct, just less
shared work.

This module is jax-free; fingerprinting is pure hashing so the queue can
group requests without touching device state.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import queue as _queue
import time
from typing import Any


@dataclasses.dataclass(frozen=True)
class SimRequest:
    """One simulation to serve.

    ``case``/``n``/``dtype``/``params``/``plan_cfg`` identify the compiled
    engine (they form the batching fingerprint); ``steps``, ``scale`` and
    ``request_id`` are per-request and batch freely.
    """

    case: str                       # registered repro.solvers case name
    n: Any                          # cubic extent N or (nx, ny, nz)
    steps: int                      # Δt steps to advance (≥ 0)
    dtype: str = "float32"
    params: dict = dataclasses.field(default_factory=dict)   # physics kwargs
    plan_cfg: dict | None = None    # explicit FFT-plan knobs; None = registry
    scale: float = 1.0              # initial-condition amplitude multiplier
    request_id: str = ""            # caller's label, echoed in the result

    def shape(self) -> tuple[int, int, int]:
        n = self.n
        return (n, n, n) if isinstance(n, int) else tuple(int(d) for d in n)


def request_key(req: SimRequest) -> str:
    """Canonical batching fingerprint of a request's compiled engine.

    Hashes the step-shaping fields only — ``steps``/``scale``/``request_id``
    never enter, so requests differing only there share one compiled
    engine and batch together. ``plan_cfg`` is normalized through the
    tuning layer's legacy-knob mapping first (``net`` → ``comm_engine``)
    so equivalent spellings collide onto one key.
    """
    import numpy as np

    cfg = None
    if req.plan_cfg is not None:
        from repro.tuning.space import normalize_config
        cfg = normalize_config(req.plan_cfg)
        cfg.pop("net", None)        # folded into comm_engine by normalize
        cfg = {k: cfg[k] for k in sorted(cfg)}
    nx, ny, nz = req.shape()
    payload = {
        "case": str(req.case),
        "n": [nx, ny, nz],
        "dtype": np.dtype(req.dtype).name,
        "params": {k: req.params[k] for k in sorted(req.params)},
        "plan_cfg": cfg,
    }
    digest = hashlib.sha256(
        json.dumps(payload, sort_keys=True, default=str).encode()
    ).hexdigest()[:16]
    return f"{payload['case']}_n{nx}x{ny}x{nz}_{payload['dtype']}_{digest}"


@dataclasses.dataclass(frozen=True)
class StepUpdate:
    """One streamed time step: the observables a solo run would record."""

    step: int                       # 0 = the t=0 diagnostics
    t: float
    observables: dict               # {name: float}, "t" included


@dataclasses.dataclass
class SimResult:
    """Terminal event of a ticket's stream."""

    request: SimRequest
    fingerprint: str
    history: list                   # observables per step (len = steps + 1)
    batch_size: int = 1             # lanes in the batch that served this
    submitted_s: float = 0.0        # monotonic clocks for latency accounting
    finished_s: float = 0.0
    error: str = ""                 # non-empty = the batch failed

    @property
    def ok(self) -> bool:
        return not self.error

    @property
    def latency_s(self) -> float:
        """Submit → final-observable wall time (queue wait included)."""
        return max(self.finished_s - self.submitted_s, 0.0)


class Ticket:
    """The requester's handle: a thread-safe stream of per-step events.

    The scheduler thread pushes :class:`StepUpdate`s as the batch advances
    and a :class:`SimResult` last; the submitting thread consumes them with
    :meth:`updates` (a generator that ends when the result arrives) or
    blocks straight on :meth:`result`.
    """

    def __init__(self, request: SimRequest, fingerprint: str, seq: int):
        self.request = request
        self.fingerprint = fingerprint
        self.seq = seq                       # global arrival order
        self.submitted_s = time.monotonic()
        self._events: _queue.Queue = _queue.Queue()
        self._result: SimResult | None = None

    # -- scheduler side ----------------------------------------------------
    def _push(self, event) -> None:
        self._events.put(event)

    # -- requester side ----------------------------------------------------
    def updates(self, timeout: float | None = None):
        """Yield :class:`StepUpdate`s until the terminal result arrives.

        ``timeout`` bounds the wait for *each* event; ``queue.Empty``
        propagates when the server stops feeding the stream in time.
        """
        while self._result is None:
            event = self._events.get(timeout=timeout)
            if isinstance(event, SimResult):
                self._result = event
                return
            yield event

    def result(self, timeout: float | None = None) -> SimResult:
        """Drain the stream and return the terminal :class:`SimResult`."""
        for _ in self.updates(timeout=timeout):
            pass
        assert self._result is not None
        return self._result

    @property
    def done(self) -> bool:
        return self._result is not None
