"""The request queue: fingerprint-grouped batching with global FIFO
fairness and bounded-depth backpressure.

Pending tickets live in per-fingerprint FIFO lanes. A scheduling round
(:meth:`RequestQueue.next_batch`) picks the lane whose *head* is the
oldest request in the whole queue — so no fingerprint can starve another:
groups are served in arrival order of their oldest member — and drains up
to ``max_batch`` tickets from it in arrival order. Everything popped
together shares one compiled engine and becomes one leading-batch-axis
solver step.

Backpressure is a hard depth bound: when ``max_pending`` is set, a submit
that would exceed it raises :class:`QueueFullError` immediately (the
caller sheds load or retries; nothing blocks inside the scheduler). The
rejection carries a ``retry_after_hint`` — the queue's advice, in seconds,
on when a retry might find room (scaled by how overfull the queue is);
the load generator's bounded retry loop honors it.

Gauges: ``serving.queue_depth`` tracks the pending count on every submit
and every batch pull; ``serving.requests.rejected`` counts shed load.
"""

from __future__ import annotations

import collections
import threading

from repro import obs
from repro.serving.request import Ticket


class QueueFullError(RuntimeError):
    """Submit refused: the queue is at its ``max_pending`` depth bound.

    ``retry_after_hint`` (seconds) is the queue's advice on when to retry:
    a base hint scaled by the relative overfullness at rejection time.
    Purely advisory — the queue promises nothing about future depth."""

    def __init__(self, msg: str, retry_after_hint: float = 0.05):
        super().__init__(msg)
        self.retry_after_hint = float(retry_after_hint)


class RequestQueue:
    """Thread-safe pending-request store with fingerprint lanes."""

    def __init__(self, max_pending: int | None = None,
                 retry_hint_s: float = 0.05):
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.max_pending = max_pending
        self.retry_hint_s = float(retry_hint_s)
        self._lanes: dict[str, collections.deque[Ticket]] = {}
        self._depth = 0
        self._lock = threading.Lock()

    def submit(self, ticket: Ticket) -> None:
        """Append to the ticket's fingerprint lane (FIFO within the lane)."""
        with self._lock:
            if self.max_pending is not None and self._depth >= self.max_pending:
                obs.metrics.inc("serving.requests.rejected")
                hint = self.retry_hint_s * (self._depth / self.max_pending)
                raise QueueFullError(
                    f"queue at max_pending={self.max_pending} "
                    f"({self._depth} pending)", retry_after_hint=hint)
            self._lanes.setdefault(ticket.fingerprint,
                                   collections.deque()).append(ticket)
            self._depth += 1
            depth = self._depth
        obs.metrics.set_gauge("serving.queue_depth", depth)

    def next_batch(self, max_batch: int) -> list[Ticket]:
        """Up to ``max_batch`` same-fingerprint tickets, oldest lane first.

        Empty list when nothing is pending. The selected lane is the one
        holding the globally oldest ticket (min arrival ``seq`` over lane
        heads); tickets pop in arrival order.
        """
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        with self._lock:
            if not self._lanes:
                return []
            fp = min(self._lanes, key=lambda k: self._lanes[k][0].seq)
            lane = self._lanes[fp]
            batch = [lane.popleft() for _ in range(min(max_batch, len(lane)))]
            if not lane:
                del self._lanes[fp]
            self._depth -= len(batch)
            depth = self._depth
        obs.metrics.set_gauge("serving.queue_depth", depth)
        return batch

    @property
    def depth(self) -> int:
        """Total pending tickets across all lanes."""
        with self._lock:
            return self._depth

    def lanes(self) -> dict[str, int]:
        """{fingerprint: pending count} snapshot."""
        with self._lock:
            return {fp: len(lane) for fp, lane in self._lanes.items()}
