"""``repro.serving`` — the batched spectral-simulation serving layer.

From "request arrives" to "observables stream back": the continuous-
batching problem shape LLM inference serves, applied to the FFT-cycle
solver workloads of ``repro.solvers``. The pieces, each its own module:

* :mod:`~repro.serving.request` — the :class:`SimRequest` /
  :class:`SimResult` contract, the streamed :class:`StepUpdate` events, the
  requester's :class:`Ticket`, and :func:`request_key`, the batching
  fingerprint (case, shape, dtype, physics params, plan config).
* :mod:`~repro.serving.queue` — :class:`RequestQueue`: per-fingerprint
  FIFO lanes, globally-fair batch selection, bounded-depth backpressure
  (:class:`QueueFullError`).
* :mod:`~repro.serving.registry` — :class:`EngineRegistry`: persistent
  compiled solver engines, one per fingerprint, with tuned plans reused
  from the ``repro.tuning`` plan cache — admission never recompiles a hot
  shape.
* :mod:`~repro.serving.server` — :class:`SimServer`: the scheduling loop
  that advances each admitted batch as **one sharded solver step over a
  leading batch axis** (``SpectralSolver.batched_step``) and streams
  per-step observables back per lane, bitwise-identical to solo runs.
* :mod:`~repro.serving.loadgen` — :func:`run_load` / :class:`LoadReport`:
  burst and paced arrival schedules with requests/s and p50/p95/p99
  latency tails, feeding the ``serving_*`` bench rows.

``python -m repro.serving.cli`` (or ``python -m repro.launch.serve --sim``)
drives a server from the command line; ``docs/serving.md`` documents the
request lifecycle end to end.
"""

from __future__ import annotations

from repro.fleet.records import FailureRecord
from repro.serving.loadgen import LoadReport, percentile_us, run_load
from repro.serving.queue import QueueFullError, RequestQueue
from repro.serving.registry import EngineRegistry
from repro.serving.request import (SimRequest, SimResult, StepUpdate, Ticket,
                                   request_key)
from repro.serving.server import SimServer, scaled_initial_fields

__all__ = [
    "SimRequest", "SimResult", "StepUpdate", "Ticket", "request_key",
    "RequestQueue", "QueueFullError", "EngineRegistry", "SimServer",
    "scaled_initial_fields", "run_load", "LoadReport", "percentile_us",
    "FailureRecord",
]
