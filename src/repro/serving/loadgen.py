"""Load generator: drive a :class:`~repro.serving.server.SimServer` with a
request schedule and report throughput and latency tails.

Two arrival modes:

* **burst** (``rate_hz=0``) — submit everything up front, then drain. This
  measures the server's batching capacity: with K same-fingerprint
  requests and ``max_batch=B`` the scheduler runs ⌈K/B⌉ batches, and the
  per-request latencies include their queue wait.
* **paced** (``rate_hz>0``) — submit at a fixed open-loop rate against the
  *running* scheduler thread, the serving analogue of a steady request
  stream.

Backpressure is survived, not ignored: a submit rejected with
:class:`~repro.serving.queue.QueueFullError` is retried up to
``max_submit_retries`` times with exponential backoff floored at the
queue's ``retry_after_hint`` (in burst mode a drain pass frees room first,
keeping tests deterministic); a request still rejected after the budget is
recorded as a structured :class:`~repro.fleet.records.FailureRecord`
instead of silently dropping — a burst larger than ``max_pending`` no
longer loses requests without a trace.

The report carries per-request latencies (submit → final observable, queue
wait included), nearest-rank p50/p95/p99 tails, and requests/s over the
whole run — the numbers ``benchmarks.run --only serving`` puts on the perf
trajectory as ``serving_*`` rows.
"""

from __future__ import annotations

import dataclasses
import time

from repro.fleet.records import FailureRecord
from repro.serving.queue import QueueFullError
from repro.serving.request import SimRequest, SimResult
from repro.serving.server import SimServer


def percentile_us(latencies_us: list[float], frac: float) -> float:
    """Nearest-rank percentile (the ``tuning.timing.time_stats``
    convention), on an already-collected latency sample in µs."""
    if not latencies_us:
        return 0.0
    vals = sorted(latencies_us)
    rank = max(1, int(round(frac * len(vals) + 0.5)))
    return vals[min(rank, len(vals)) - 1]


@dataclasses.dataclass
class LoadReport:
    """Aggregate of one load-generator run."""

    results: list[SimResult]
    wall_s: float                   # first submit → last result
    rate_hz: float                  # requested arrival rate (0 = burst)
    rejected: list = dataclasses.field(default_factory=list)
    submit_retries: int = 0         # resubmissions after QueueFullError

    @property
    def n_requests(self) -> int:
        return len(self.results) + len(self.rejected)

    @property
    def n_failed(self) -> int:
        return sum(1 for r in self.results if not r.ok)

    @property
    def n_rejected(self) -> int:
        """Requests shed after exhausting the submit-retry budget."""
        return len(self.rejected)

    @property
    def requests_per_s(self) -> float:
        return self.n_requests / self.wall_s if self.wall_s > 0 else 0.0

    def latencies_us(self) -> list[float]:
        return [r.latency_s * 1e6 for r in self.results if r.ok]

    def stats(self) -> dict:
        """The bench-row payload: mean/p50/p95/p99 latency + throughput."""
        lat = self.latencies_us()
        mean = sum(lat) / len(lat) if lat else 0.0
        return {
            "n_requests": self.n_requests,
            "n_failed": self.n_failed,
            "n_rejected": self.n_rejected,
            "submit_retries": self.submit_retries,
            "requests_per_s": round(self.requests_per_s, 3),
            "mean_us": round(mean, 3),
            "p50_us": round(percentile_us(lat, 0.50), 3),
            "p95_us": round(percentile_us(lat, 0.95), 3),
            "p99_us": round(percentile_us(lat, 0.99), 3),
            "wall_s": round(self.wall_s, 6),
        }


def run_load(server: SimServer, requests: list[SimRequest], *,
             rate_hz: float = 0.0, max_submit_retries: int = 0,
             retry_backoff_s: float = 0.02) -> LoadReport:
    """Submit ``requests`` against ``server`` and wait for every result.

    Burst mode drains on the calling thread when no scheduler thread is
    running (deterministic for tests); paced mode starts the scheduler
    thread if needed and stops it again if this call started it.

    A :class:`QueueFullError` is retried up to ``max_submit_retries``
    times, sleeping ``max(hint, retry_backoff_s · 2^attempt)`` (capped at
    1 s) between tries — and, when no scheduler thread is draining, running
    one ``serve_pending()`` pass first so a retry can actually find room.
    Requests rejected after the budget land in ``LoadReport.rejected`` as
    :class:`FailureRecord`\\ s (kind ``rejected``).
    """
    started_here = False
    if rate_hz > 0 and not server.running:
        server.start()
        started_here = True
    t0 = time.monotonic()
    tickets = []
    rejected: list[FailureRecord] = []
    retries = 0
    for i, req in enumerate(requests):
        if rate_hz > 0 and i:
            # open-loop pacing against the schedule, not the previous send
            time.sleep(max(0.0, t0 + i / rate_hz - time.monotonic()))
        for attempt in range(max_submit_retries + 1):
            try:
                tickets.append(server.submit(req))
                break
            except QueueFullError as e:
                if attempt >= max_submit_retries:
                    rejected.append(FailureRecord(
                        kind="rejected", where="serving.queue",
                        job_id=req.request_id or f"req{i}", attempt=attempt,
                        detail=str(e), retryable=True, time_s=time.time()))
                    break
                retries += 1
                if not server.running:
                    server.serve_pending()    # free room deterministically
                time.sleep(min(max(e.retry_after_hint,
                                   retry_backoff_s * (2 ** attempt)), 1.0))
    if not server.running:
        server.serve_pending()
    results = [t.result() for t in tickets]
    wall = time.monotonic() - t0
    if started_here:
        server.stop()
    return LoadReport(results=results, wall_s=wall, rate_hz=rate_hz,
                      rejected=rejected, submit_retries=retries)
