"""The serving loop: admit a same-fingerprint batch, advance it as one
sharded step per Δt, stream each lane's observables back to its requester.

:class:`SimServer` ties the layer together. ``submit(request)`` returns a
:class:`~repro.serving.request.Ticket` immediately (or raises
:class:`~repro.serving.queue.QueueFullError` under backpressure); a
scheduling round pulls the oldest fingerprint lane from the queue, fetches
that shape's persistent compiled engine from the
:class:`~repro.serving.registry.EngineRegistry`, stacks the lanes' initial
fields along a leading batch axis, and then steps the whole batch through
``SpectralSolver.batched_step`` — one dispatch on the mesh per Δt, however
many requests ride in it. After every step the batched observables are
pulled once and fanned out as per-lane ``StepUpdate``s, so requesters see
their trajectory live, not at the end.

**Identity guarantee**: a lane's streamed history is exactly what a solo
``SpectralSolver`` run of the same request computes — the batched step is
the same ``shard_map`` body ``vmap``-ed over the batch axis, and the
clocks accumulate identically. ``tests/_dist_serving_check.py`` pins this
bitwise across the CI mesh × engine matrix.

**Run-to-longest batching**: lanes whose ``steps`` differ batch together;
the batch advances ``max(steps)`` times and a lane simply stops receiving
updates (and gets its result) once its own horizon is reached. Finished
lanes keep computing until the batch retires — wasted FLOPs bounded by the
step spread, zero recompiles (retiring a lane mid-flight would change the
batch shape and force a fresh XLA executable).

The server can run synchronously (``serve_pending()`` drains the queue on
the caller's thread — tests, batch jobs) or threaded (``start()`` spawns a
scheduler thread that wakes on submit — the load generator and the CLI).
All jax dispatch happens on whichever single thread runs the scheduling
rounds.
"""

from __future__ import annotations

import collections
import threading
import time

import jax

from repro import obs
from repro.fleet.records import FailureRecord
from repro.serving.queue import RequestQueue
from repro.serving.registry import EngineRegistry
from repro.serving.request import (SimRequest, SimResult, StepUpdate, Ticket,
                                   request_key)


def scaled_initial_fields(solver, scale: float):
    """The solver's t=0 fields with the request's amplitude applied.

    The one definition both the server and the solo-reference checks use,
    so "batched ≡ solo" compares identical initial conditions.
    """
    fields = solver.initial_fields()
    if scale == 1.0:
        return fields
    return jax.tree.map(lambda a: a * scale, fields)


class SimServer:
    """Batched spectral-simulation server bound to one device mesh."""

    def __init__(self, mesh, *, max_batch: int = 8,
                 max_pending: int | None = None,
                 registry: EngineRegistry | None = None,
                 use_plan_cache: bool = True,
                 cache_path: str | None = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.mesh = mesh
        self.max_batch = max_batch
        self.registry = registry or EngineRegistry(
            mesh, use_plan_cache=use_plan_cache, cache_path=cache_path)
        self.queue = RequestQueue(max_pending)
        # per-lane failure trail, same structured type the fleet uses
        # (bounded: serving failures are diagnostics, not campaign state)
        self.failures: collections.deque[FailureRecord] = collections.deque(
            maxlen=256)
        self._seq = 0
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # ---- submission ------------------------------------------------------
    def submit(self, req: SimRequest) -> Ticket:
        """Enqueue; returns the requester's streaming ticket immediately."""
        if req.steps < 0:
            raise ValueError(f"steps must be >= 0, got {req.steps}")
        fp = request_key(req)
        with self._lock:
            self._seq += 1
            ticket = Ticket(req, fp, self._seq)
        self.queue.submit(ticket)          # raises QueueFullError when full
        obs.metrics.inc("serving.requests.submitted")
        self._wake.set()
        return ticket

    # ---- scheduling rounds ----------------------------------------------
    def serve_once(self) -> int:
        """Admit and run one batch; returns the number of requests served."""
        batch = self.queue.next_batch(self.max_batch)
        if not batch:
            return 0
        self._run_batch(batch)
        return len(batch)

    def serve_pending(self) -> int:
        """Drain the queue on the calling thread; total requests served."""
        total = 0
        while True:
            served = self.serve_once()
            if not served:
                return total
            total += served

    def _run_batch(self, tickets: list[Ticket]) -> None:
        fp, req0 = tickets[0].fingerprint, tickets[0].request
        nbatch = len(tickets)
        try:
            with obs.span("serve/admit", fingerprint=fp, case=req0.case,
                          batch=nbatch) if obs.is_enabled() else obs.NULL_SPAN:
                solver = self.registry.get(req0, fingerprint=fp)
            obs.metrics.inc("serving.batches")
            obs.metrics.inc("serving.requests.admitted", nbatch)
            obs.metrics.set_gauge("serving.batch_size", nbatch)
            self._step_batch(solver, tickets)
        except Exception as e:  # fail every lane loudly, keep serving
            obs.metrics.inc("serving.batches_failed")
            err = f"{type(e).__name__}: {e}"
            now = time.monotonic()
            wall = time.time()
            for t in tickets:
                self.failures.append(FailureRecord(
                    kind="batch_error", where="serving.batch",
                    job_id=t.request.request_id or fp, detail=err,
                    retryable=False, time_s=wall))
                obs.metrics.inc("serving.requests.failed")
                t._push(SimResult(request=t.request, fingerprint=fp,
                                  history=[], batch_size=nbatch,
                                  submitted_s=t.submitted_s, finished_s=now,
                                  error=err))

    def _step_batch(self, solver, tickets: list[Ticket]) -> None:
        import jax.numpy as jnp

        nbatch = len(tickets)
        lanes = [scaled_initial_fields(solver, t.request.scale)
                 for t in tickets]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *lanes)
        histories: list[list] = [[] for _ in tickets]
        open_lanes = set(range(nbatch))

        def emit(step: int, t: float) -> None:
            # one batched observables dispatch, fanned out per open lane
            batched = solver.batched_observables(stacked)
            for i in sorted(open_lanes):
                o = {k: float(v[i]) for k, v in batched.items()}
                o["t"] = t
                histories[i].append(o)
                tickets[i]._push(StepUpdate(step=step, t=t, observables=o))
                if step >= tickets[i].request.steps:
                    self._finish(tickets[i], histories[i], nbatch)
                    open_lanes.discard(i)

        t = 0.0
        emit(0, t)
        steps_max = max(tk.request.steps for tk in tickets)
        for step in range(1, steps_max + 1):
            if obs.is_enabled():
                with obs.span("dispatch/serving.batch_step", case=tickets[0]
                              .request.case, batch=nbatch, step=step,
                              fingerprint=tickets[0].fingerprint):
                    stacked = solver.batched_step(stacked)
                    jax.block_until_ready(stacked)
            else:
                stacked = solver.batched_step(stacked)
            t = t + solver.dt             # same accumulation as solo step()
            emit(step, t)
        assert not open_lanes

    def _finish(self, ticket: Ticket, history: list, nbatch: int) -> None:
        obs.metrics.inc("serving.requests.completed")
        ticket._push(SimResult(
            request=ticket.request, fingerprint=ticket.fingerprint,
            history=history, batch_size=nbatch,
            submitted_s=ticket.submitted_s, finished_s=time.monotonic()))

    # ---- threaded mode ---------------------------------------------------
    def start(self) -> None:
        """Spawn the scheduler thread (idempotent)."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="sim-serve", daemon=True)
        self._thread.start()

    def stop(self, drain: bool = True) -> None:
        """Stop the scheduler thread; ``drain`` serves what's queued first."""
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if drain:
            self.serve_pending()

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def _loop(self) -> None:
        while not self._stop.is_set():
            if not self.serve_once():
                self._wake.wait(timeout=0.05)
                self._wake.clear()
