"""RWKV6 "Finch" block (arXiv:2404.05892): linear-attention WKV recurrence
with *data-dependent decay* (the defining v6 feature) + channel mixing.

State per layer: token-shift buffer (B, D) + WKV matrix state (B, H, K, V).
Decode is O(1) in sequence length — this is why rwkv6-3b runs ``long_500k``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class RWKVDims:
    d_model: int
    n_heads: int          # head_size = d_model // n_heads (64 for Finch)
    d_ff: int
    decay_lora: int = 64

    @property
    def head_size(self) -> int:
        return self.d_model // self.n_heads


def init_rwkv_time_mix(ini, r: RWKVDims):
    d = r.d_model
    p = {
        # static token-shift lerp coefficients (per stream)
        "mu": ini.param("mu", (5, d), ("five", "embed"), scale=0.5),
        # data-dependent decay lora: w_t = exp(-exp(w0 + tanh(xw A) B))
        "w0": ini.param("w0", (d,), ("embed",), mode="zeros"),
        "wA": ini.param("wA", (d, r.decay_lora), ("embed", "lora"), scale=0.01),
        "wB": ini.param("wB", (r.decay_lora, d), ("lora", "embed"), scale=0.01),
        "u": ini.param("u", (d,), ("embed",), scale=0.5),  # bonus
        "Wr": ini.param("Wr", (d, d), ("embed", "heads_x")),
        "Wk": ini.param("Wk", (d, d), ("embed", "heads_x")),
        "Wv": ini.param("Wv", (d, d), ("embed", "heads_x")),
        "Wg": ini.param("Wg", (d, d), ("embed", "heads_x")),
        "Wo": ini.param("Wo", (d, d), ("heads_x", "embed")),
        "ln_w": ini.param("ln_w", (d,), ("embed",), mode="ones"),
        "ln_b": ini.param("ln_b", (d,), ("embed",), mode="zeros"),
    }
    return p


def _group_norm(x, w, b, n_heads, eps=64e-5):
    """Per-head LayerNorm on (B, D) output (RWKV ln_x)."""
    bshape = x.shape
    x = x.reshape(bshape[:-1] + (n_heads, -1)).astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * lax.rsqrt(var + eps)
    x = x.reshape(bshape)
    return x * w.astype(jnp.float32) + b.astype(jnp.float32)


def rwkv_time_mix_step(p, r: RWKVDims, x_t, x_prev, state):
    """One token. x_t: (B, D); state: (B, H, K, V). Returns (y, new_state)."""
    b, d = x_t.shape
    h, hs = r.n_heads, r.head_size
    mu = p["mu"].astype(x_t.dtype)
    xs = [x_prev + mu[i] * (x_t - x_prev) for i in range(5)]  # r,k,v,w,g streams
    xr, xk, xv, xw, xg = xs
    rt = (xr @ p["Wr"]).reshape(b, h, hs)
    kt = (xk @ p["Wk"]).reshape(b, h, hs)
    vt = (xv @ p["Wv"]).reshape(b, h, hs)
    gt = jax.nn.silu(xg @ p["Wg"])
    # data-dependent decay (f32 for stability)
    ww = (p["w0"].astype(jnp.float32)
          + jnp.tanh(xw.astype(jnp.float32) @ p["wA"].astype(jnp.float32))
          @ p["wB"].astype(jnp.float32))
    w_t = jnp.exp(-jnp.exp(ww)).reshape(b, h, hs)            # decay per k-channel
    u = p["u"].astype(jnp.float32).reshape(h, hs)

    kf = kt.astype(jnp.float32)
    vf = vt.astype(jnp.float32)
    rf = rt.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]                 # (B,H,K,V)
    y = jnp.einsum("bhk,bhkv->bhv", rf, state + u[None, :, :, None] * kv)
    new_state = w_t[..., :, None] * state + kv
    y = _group_norm(y.reshape(b, d), p["ln_w"], p["ln_b"], h)
    y = (y * gt.astype(jnp.float32)).astype(x_t.dtype)
    return y @ p["Wo"], new_state


def rwkv_time_mix_seq(p, r: RWKVDims, x, x_prev0, state0):
    """Sequence scan. x: (B, S, D). Returns (y, (x_last, state)).

    The step is rematted: without it the backward saves the (B, H, K, V)
    outer product per timestep (~10 MB × S steps = 43 GiB/device on the
    rwkv6-3b train_4k cell)."""
    def step(carry, x_t):
        x_prev, st = carry
        y, st = rwkv_time_mix_step(p, r, x_t, x_prev, st)
        return (x_t, st), y
    from repro.models.mamba import chunked_time_scan
    (x_last, st), ys = chunked_time_scan(step, (x_prev0, state0),
                                         jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(ys, 0, 1), (x_last, st)


def init_rwkv_channel_mix(ini, r: RWKVDims):
    d = r.d_model
    return {
        "mu": ini.param("mu", (2, d), ("two", "embed"), scale=0.5),
        "Wk": ini.param("Wk", (d, r.d_ff), ("embed", "mlp")),
        "Wv": ini.param("Wv", (r.d_ff, d), ("mlp", "embed")),
        "Wr": ini.param("Wr", (d, d), ("embed", "embed_out")),
    }


def rwkv_channel_mix_seq(p, x, x_prev0):
    """x: (B, S, D); token-shifted squared-relu channel mixing."""
    xs = jnp.concatenate([x_prev0[:, None, :], x[:, :-1, :]], axis=1)
    mu = p["mu"].astype(x.dtype)
    xk = xs + mu[0] * (x - xs)
    xr = xs + mu[1] * (x - xs)
    k = jnp.square(jax.nn.relu(xk @ p["Wk"]))
    return jax.nn.sigmoid(xr @ p["Wr"]) * (k @ p["Wv"]), x[:, -1, :]


def rwkv_channel_mix_step(p, x_t, x_prev):
    mu = p["mu"].astype(x_t.dtype)
    xk = x_prev + mu[0] * (x_t - x_prev)
    xr = x_prev + mu[1] * (x_t - x_prev)
    k = jnp.square(jax.nn.relu(xk @ p["Wk"]))
    return jax.nn.sigmoid(xr @ p["Wr"]) * (k @ p["Wv"]), x_t
