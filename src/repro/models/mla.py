"""Multi-head Latent Attention (DeepSeek-V2-Lite): compressed KV cache
(kv_lora_rank + decoupled RoPE key) with the absorbed-projection decode path.

Cache per token is ``kv_lora_rank + rope_dim`` floats (512+64) instead of
``2·Hkv·D`` — the arch's defining serving optimization.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import rope_cos_sin, apply_rope


@dataclasses.dataclass(frozen=True)
class MLADims:
    d_model: int
    n_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_base: float = 10000.0


def init_mla(ini, m: MLADims):
    qd = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq": ini.param("wq", (m.d_model, m.n_heads, qd), ("embed", "heads", "head_dim")),
        "w_dkv": ini.param("w_dkv", (m.d_model, m.kv_lora_rank), ("embed", "kv_lora")),
        "w_kr": ini.param("w_kr", (m.d_model, m.qk_rope_dim), ("embed", "head_dim")),
        "kv_norm": ini.param("kv_norm", (m.kv_lora_rank,), ("kv_lora",), mode="ones"),
        "w_uk": ini.param("w_uk", (m.kv_lora_rank, m.n_heads, m.qk_nope_dim),
                          ("kv_lora", "heads", "head_dim")),
        "w_uv": ini.param("w_uv", (m.kv_lora_rank, m.n_heads, m.v_head_dim),
                          ("kv_lora", "heads", "head_dim")),
        "wo": ini.param("wo", (m.n_heads, m.v_head_dim, m.d_model),
                        ("heads", "head_dim", "embed")),
    }


def _rms(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    inv = lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * inv * w.astype(jnp.float32)).astype(x.dtype)


def _compress(p, m: MLADims, x, positions):
    """x -> (c_kv, k_rope): the only tensors the cache stores."""
    c_kv = _rms(x @ p["w_dkv"], p["kv_norm"])              # (B,S,R)
    k_r = x @ p["w_kr"]                                    # (B,S,dr)
    cos, sin = rope_cos_sin(positions, m.qk_rope_dim, m.rope_base)
    k_r = apply_rope(k_r[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_r


def _queries(p, m: MLADims, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_n = q[..., : m.qk_nope_dim]
    q_r = q[..., m.qk_nope_dim:]
    cos, sin = rope_cos_sin(positions, m.qk_rope_dim, m.rope_base)
    q_r = apply_rope(q_r, cos, sin)
    # absorb W_uk: q_n' = q_n @ W_uk^T  -> scores live in the latent space
    q_lat = jnp.einsum("bshk,rhk->bshr", q_n, p["w_uk"])
    return q_lat, q_r


def _attend(p, m: MLADims, q_lat, q_r, c_kv, k_r, mask):
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_dim + m.qk_rope_dim))
    logits = (jnp.einsum("bshr,btr->bhst", q_lat, c_kv)
              + jnp.einsum("bshk,btk->bhst", q_r, k_r)).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(c_kv.dtype)
    o_lat = jnp.einsum("bhst,btr->bshr", w, c_kv)          # attn over latents
    o = jnp.einsum("bshr,rhv->bshv", o_lat, p["w_uv"])     # decompress values
    return jnp.einsum("bshv,hvm->bsm", o, p["wo"])


def apply_mla(p, m: MLADims, x, positions):
    """Train / prefill; returns (out, (c_kv, k_rope)) for cache building.

    Long sequences run the flash-style chunked path *in the latent space*:
    queries [q_lat ; q_rope] against keys [c_kv ; k_rope] with values c_kv —
    attention never leaves the 512-dim latent, and no (S, T) matrix is
    materialized."""
    from repro.models.layers import CHUNK_THRESHOLD, _sdpa_chunked, AttnDims

    c_kv, k_r = _compress(p, m, x, positions)
    q_lat, q_r = _queries(p, m, x, positions)
    s, t = x.shape[1], c_kv.shape[1]
    if s > 1 and s * t > CHUNK_THRESHOLD ** 2:
        dq = m.kv_lora_rank + m.qk_rope_dim
        eff = m.qk_nope_dim + m.qk_rope_dim
        fix = jnp.sqrt(jnp.float32(dq) / jnp.float32(eff)).astype(q_lat.dtype)
        qq = jnp.concatenate([q_lat, q_r], axis=-1) * fix    # (B,S,H,dq)
        kk = jnp.concatenate([c_kv, k_r], axis=-1)[:, :, None, :]
        vv = c_kv[:, :, None, :]
        dims = AttnDims(d_model=m.d_model, n_heads=m.n_heads, n_kv_heads=1,
                        head_dim=dq)
        o_lat = _sdpa_chunked(qq, kk, vv, dims, causal=True)  # (B,S,H,R)
        o = jnp.einsum("bshr,rhv->bshv", o_lat, p["w_uv"])
        out = jnp.einsum("bshv,hvm->bsm", o, p["wo"])
        return out, (c_kv, k_r)
    mask = (jnp.arange(t)[None, :] <= jnp.arange(s)[:, None])[None, None]
    return _attend(p, m, q_lat, q_r, c_kv, k_r, mask), (c_kv, k_r)


def apply_mla_decode(p, m: MLADims, x, cache_ckv, cache_kr, cache_len, positions):
    """One-token decode over the compressed cache."""
    c_new, kr_new = _compress(p, m, x, positions)
    ck = lax.dynamic_update_slice_in_dim(cache_ckv, c_new.astype(cache_ckv.dtype),
                                         cache_len, axis=1)
    kr = lax.dynamic_update_slice_in_dim(cache_kr, kr_new.astype(cache_kr.dtype),
                                         cache_len, axis=1)
    q_lat, q_r = _queries(p, m, x, positions)
    t = ck.shape[1]
    mask = (jnp.arange(t)[None, :] <= cache_len)[None, None]
    return _attend(p, m, q_lat, q_r, ck, kr, mask), ck, kr
