"""Composable decoder stack covering all assigned architecture families:

* uniform decoders (smollm/deepseek/qwen/gemma/llava backbone) — GQA/MQA,
  SwiGLU/GeGLU, optional QKV bias, RoPE;
* MoE decoders (qwen3-moe; deepseek-v2-lite with MLA + shared experts and a
  first dense layer);
* RWKV6 (attention-free);
* Jamba hybrid (1:7 attn:mamba interleave, MoE every 2nd layer) via
  period-sized superblocks scanned over depth;
* Whisper encoder–decoder (frames-stub front end, cross-attention decoder).

Layers are stacked with ``lax.scan`` (+ optional ``jax.checkpoint`` remat) so
the compiled HLO is depth-independent — required for the 512-device dry-run
on a single-core host. ``init_model`` returns a params pytree plus a mirror
pytree of logical axis names (consumed by ``repro.distributed.sharding``);
run it under ``jax.eval_shape`` to get both without materializing weights.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.configs.base import ArchConfig
from repro.models import common as cm
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import rwkv as RW
from repro.models import mamba as MB
from repro.models.common import Initializer, stack_params, stack_axes


@dataclasses.dataclass(frozen=True)
class RunCfg:
    """Runtime distribution context (orthogonal to the arch config)."""
    mesh: Any = None
    data_axes: tuple = ("data",)
    model_axes: tuple = ("model",)
    seq_shard_kv: bool = False       # long_500k: KV time-sharded decode
    remat: bool = True


def _dt(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


def _cast_f(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        tree)


def attn_dims(cfg: ArchConfig, causal=True) -> L.AttnDims:
    return L.AttnDims(d_model=cfg.d_model, n_heads=cfg.n_heads,
                      n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
                      qkv_bias=cfg.qkv_bias, rope_base=cfg.rope_base,
                      causal=causal)


def mla_dims(cfg: ArchConfig) -> MLA.MLADims:
    m = cfg.mla
    return MLA.MLADims(d_model=cfg.d_model, n_heads=cfg.n_heads,
                       kv_lora_rank=m.kv_lora_rank, qk_nope_dim=m.qk_nope_dim,
                       qk_rope_dim=m.qk_rope_dim, v_head_dim=m.v_head_dim,
                       rope_base=cfg.rope_base)


def moe_dims(cfg: ArchConfig) -> MOE.MoEDims:
    m = cfg.moe
    return MOE.MoEDims(d_model=cfg.d_model, n_experts=m.n_experts,
                       top_k=m.top_k, d_ff_expert=m.d_ff_expert,
                       n_shared=m.n_shared, d_ff_shared=m.d_ff_shared,
                       capacity_factor=m.capacity_factor,
                       router_norm_topk=m.router_norm_topk,
                       mlp_type=cfg.mlp_type)


def mamba_dims(cfg: ArchConfig) -> MB.MambaDims:
    mc = cfg.mamba
    return MB.MambaDims(d_model=cfg.d_model, d_state=mc.d_state,
                        d_conv=mc.d_conv, expand=mc.expand)


def rwkv_dims(cfg: ArchConfig) -> RW.RWKVDims:
    return RW.RWKVDims(d_model=cfg.d_model, n_heads=cfg.n_heads, d_ff=cfg.d_ff)


# ---------------------------------------------------------------------------
# init (axes keys always == params dict keys)
# ---------------------------------------------------------------------------

def _norm_param(ini, cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "ln":
        return {"w": ini.param("w", (d,), ("embed",), mode="ones"),
                "b": ini.param("b", (d,), ("embed",), mode="zeros")}
    mode = "zeros" if cfg.norm_plus_one else "ones"
    return {"w": ini.param("w", (d,), ("embed",), mode=mode)}


def _apply_norm(p, x, cfg):
    if cfg.norm == "ln":
        return cm.layer_norm(x, p["w"], p["b"])
    return cm.rms_norm(x, p["w"], plus_one=cfg.norm_plus_one)


def _init_uniform_block(ini, cfg: ArchConfig, with_moe: bool):
    p = {"ln1": _norm_param(ini.sub("ln1"), cfg),
         "ln2": _norm_param(ini.sub("ln2"), cfg)}
    if cfg.attn_kind == "mla":
        p["attn"] = MLA.init_mla(ini.sub("attn"), mla_dims(cfg))
    else:
        p["attn"] = L.init_attention(ini.sub("attn"), attn_dims(cfg))
    if with_moe:
        p["ff"] = MOE.init_moe(ini.sub("ff"), moe_dims(cfg))
    else:
        p["ff"] = L.init_mlp(ini.sub("ff"), cfg.d_model, cfg.d_ff, cfg.mlp_type)
    return p


def _init_rwkv_block(ini, cfg: ArchConfig):
    return {"ln1": _norm_param(ini.sub("ln1"), cfg),
            "ln2": _norm_param(ini.sub("ln2"), cfg),
            "tm": RW.init_rwkv_time_mix(ini.sub("tm"), rwkv_dims(cfg)),
            "cm": RW.init_rwkv_channel_mix(ini.sub("cm"), rwkv_dims(cfg))}


def _init_whisper_dec_block(ini, cfg: ArchConfig):
    return {"ln1": _norm_param(ini.sub("ln1"), cfg),
            "lnx": _norm_param(ini.sub("lnx"), cfg),
            "ln2": _norm_param(ini.sub("ln2"), cfg),
            "attn": L.init_attention(ini.sub("attn"), attn_dims(cfg)),
            "xattn": L.init_attention(ini.sub("xattn"), attn_dims(cfg, causal=False)),
            "ff": L.init_mlp(ini.sub("ff"), cfg.d_model, cfg.d_ff, cfg.mlp_type)}


def _init_jamba_superblock(ini, cfg: ArchConfig):
    per = cfg.hybrid_period
    n_moe = per // cfg.moe.every
    p = {"ln1": ini.param("ln1", (per, cfg.d_model), ("sub", "embed"), mode="ones"),
         "ln2": ini.param("ln2", (per, cfg.d_model), ("sub", "embed"), mode="ones"),
         "attn": L.init_attention(ini.sub("attn"), attn_dims(cfg))}
    p["mamba"], ax = _stack_inits(ini, per - 1,
                                  lambda s: MB.init_mamba(s, mamba_dims(cfg)))
    ini.axes["mamba"] = stack_axes(ax, "sub")
    p["moe"], ax = _stack_inits(ini, n_moe, lambda s: MOE.init_moe(s, moe_dims(cfg)))
    ini.axes["moe"] = stack_axes(ax, "sub")
    p["mlp"], ax = _stack_inits(ini, per - n_moe, lambda s: L.init_mlp(
        s, cfg.d_model, cfg.d_ff, cfg.mlp_type))
    ini.axes["mlp"] = stack_axes(ax, "sub")
    return p


def _stack_inits(parent: Initializer, n: int, fn):
    trees, axes = [], None
    for _ in range(n):
        parent.key, k = jax.random.split(parent.key)
        sub = Initializer(key=k, dtype=parent.dtype, axes={})
        trees.append(fn(sub))
        axes = sub.axes
    return stack_params(trees), axes


def init_model(cfg: ArchConfig, key) -> tuple[dict, dict]:
    """Returns (params, logical_axes) pytrees of identical structure."""
    ini = Initializer(key=key, dtype=jnp.dtype(cfg.param_dtype))
    params: dict = {}
    axes: dict = ini.axes
    d = cfg.d_model

    params["embed"] = ini.param("embed", (cfg.vocab, d), ("vocab", "embed"),
                                scale=1.0 / d ** 0.5)
    params["final_norm"] = _norm_param(ini.sub("final_norm"), cfg)
    if not cfg.tie_embeddings:
        params["head"] = ini.param("head", (d, cfg.vocab), ("embed", "vocab"))

    if cfg.mixer == "rwkv":
        params["ln0"] = _norm_param(ini.sub("ln0"), cfg)
        params["blocks"], bax = _stack_inits(
            ini, cfg.n_layers, lambda s: _init_rwkv_block(s, cfg))
        axes["blocks"] = stack_axes(bax)
    elif cfg.mixer == "hybrid":
        nblocks = cfg.n_layers // cfg.hybrid_period
        params["blocks"], bax = _stack_inits(
            ini, nblocks, lambda s: _init_jamba_superblock(s, cfg))
        axes["blocks"] = stack_axes(bax)
    elif cfg.encdec:
        params["pos_embed"] = ini.param("pos_embed", (8192, d), ("seq", "embed"),
                                        scale=0.02)
        params["enc_blocks"], bax = _stack_inits(
            ini, cfg.enc_layers, lambda s: _init_uniform_block(s, cfg, False))
        axes["enc_blocks"] = stack_axes(bax)
        params["dec_blocks"], bax = _stack_inits(
            ini, cfg.n_layers, lambda s: _init_whisper_dec_block(s, cfg))
        axes["dec_blocks"] = stack_axes(bax)
        params["enc_norm"] = _norm_param(ini.sub("enc_norm"), cfg)
    else:
        nd = cfg.moe.first_dense if cfg.moe else 0
        if nd:
            params["first_blocks"], bax = _stack_inits(
                ini, nd, lambda s: _init_uniform_block(s, cfg, False))
            axes["first_blocks"] = stack_axes(bax)
        with_moe = cfg.moe is not None
        params["blocks"], bax = _stack_inits(
            ini, cfg.n_layers - nd, lambda s: _init_uniform_block(s, cfg, with_moe))
        axes["blocks"] = stack_axes(bax)
    return params, axes


def model_axes(cfg: ArchConfig) -> dict:
    """Logical-axes pytree without materializing any weights."""
    holder = {}

    def run(key):
        p, ax = init_model(cfg, key)
        holder["axes"] = ax
        return p

    jax.eval_shape(run, jax.random.PRNGKey(0))
    return holder["axes"]


# ---------------------------------------------------------------------------
# forward (train / prefill share the block bodies; decode below)
# ---------------------------------------------------------------------------

def _ff_apply(p, cfg: ArchConfig, run: RunCfg, x):
    if "router" in p:  # MoE block
        m = moe_dims(cfg)
        if cfg.moe.impl == "ep" and run.mesh is not None:
            dsz = 1
            for a in run.data_axes:
                dsz *= run.mesh.shape.get(a, 1)
            if x.shape[0] % dsz == 0:  # batch not splittable (e.g. B=1 decode)
                return MOE.apply_moe_ep(p, m, x, run.mesh,
                                        data_axes=run.data_axes,
                                        model_axes=run.model_axes,
                                        chunks=cfg.moe.chunks)
        return MOE.apply_moe(p, m, x)
    return L.apply_mlp(p, x, cfg.mlp_type)


def _uniform_block_fwd(p, cfg, run, x, positions):
    h = _apply_norm(p["ln1"], x, cfg)
    if cfg.attn_kind == "mla":
        a, kv = MLA.apply_mla(p["attn"], mla_dims(cfg), h, positions)
    else:
        a, kv = L.apply_attention(p["attn"], attn_dims(cfg), h, positions)
    x = x + a
    h = _apply_norm(p["ln2"], x, cfg)
    x = x + _ff_apply(p["ff"], cfg, run, h)
    x = cm.shard_act(x, ("batch", "seq", "embed"))
    return x, kv


def _rwkv_block_fwd(p, cfg, run, x, state):
    """state: dict(x_tm (B,D), wkv (B,H,K,V), x_cm (B,D))."""
    h = cm.rms_norm(x, p["ln1"]["w"]) if cfg.norm == "rms" else \
        cm.layer_norm(x, p["ln1"]["w"], p["ln1"]["b"])
    y, (x_tm, wkv) = RW.rwkv_time_mix_seq(p["tm"], rwkv_dims(cfg), h,
                                          state["x_tm"], state["wkv"])
    x = x + y
    h = _apply_norm(p["ln2"], x, cfg)
    y, x_cm = RW.rwkv_channel_mix_seq(p["cm"], h, state["x_cm"])
    x = x + y
    return x, {"x_tm": x_tm, "wkv": wkv, "x_cm": x_cm}


def _jamba_superblock_fwd(p, cfg, run, x, positions, states):
    """states: dict(conv (7,B,c-1,di), ssm (7,B,di,ds)); returns kv + states."""
    per = cfg.hybrid_period
    md = mamba_dims(cfg)
    new_conv, new_ssm = [], []
    kv = None
    mi = 0
    # each of the 8 sub-layers is rematted individually: the superblock is
    # one remat unit at the depth scan, so without this all 7 mamba layers'
    # time-scan residuals go live together during its backward
    mamba_ck = jax.checkpoint(
        lambda mp, h, c0, s0: MB.mamba_seq(mp, md, h, c0, s0))
    for j in range(per):
        h = cm.rms_norm(x, p["ln1"][j])
        if j == cfg.hybrid_attn_pos:
            a, kv = L.apply_attention(p["attn"], attn_dims(cfg), h, positions)
        else:
            mp = jax.tree.map(lambda t: t[mi], p["mamba"])
            a, (cs, ss) = mamba_ck(mp, h, states["conv"][mi], states["ssm"][mi])
            new_conv.append(cs)
            new_ssm.append(ss)
            mi += 1
        x = x + a
        h = cm.rms_norm(x, p["ln2"][j])
        if j % cfg.moe.every == 1 % cfg.moe.every:
            fp = jax.tree.map(lambda t: t[j // cfg.moe.every], p["moe"])
            x = x + _ff_apply(fp, cfg, run, h)
        else:
            fp = jax.tree.map(lambda t: t[j // cfg.moe.every], p["mlp"])
            x = x + L.apply_mlp(fp, h, cfg.mlp_type)
        x = cm.shard_act(x, ("batch", "seq", "embed"))
    return x, kv, {"conv": jnp.stack(new_conv), "ssm": jnp.stack(new_ssm)}


def _embed_in(params, cfg: ArchConfig, batch):
    cd = _dt(cfg)
    if cfg.embed_mode == "embeds":
        return batch["embeds"].astype(cd)
    if cfg.embed_mode == "frames":
        return batch["frames"].astype(cd)
    x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cd)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cd)
    return cm.shard_act(x, ("batch", "seq", "embed"))


def _head_out(params, cfg: ArchConfig, x):
    """Logits in COMPUTE dtype — the f32 upcast happens in the loss, so the
    backward cotangent through the whole stack stays bf16 (an f32 logits
    matmul promotes every downstream cotangent to f32 via f32×bf16
    promotion: +24 GiB/device of residual stacks on qwen3 train_4k;
    §Perf iteration M5)."""
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return x @ w.astype(x.dtype)


def _remat_group(n: int, target: int = 8) -> int:
    for g in range(min(target, n), 0, -1):
        if n % g == 0:
            return g
    return 1


def _scan_blocks(blocks, x, body, remat: bool, with_aux: bool = True):
    """Depth scan with two-level (√L-style) remat: the outer scan saves one
    residual per *group* of layers instead of per layer (§Perf iteration M6);
    each group's forward is recomputed once during its backward."""
    def f(carry, bp):
        y, aux = body(bp, carry)
        return y, aux if with_aux else None

    nl = jax.tree.leaves(blocks)[0].shape[0]
    group = _remat_group(nl) if remat else 1
    if not remat or group <= 1 or nl == group:
        if remat:
            f = jax.checkpoint(f)
        return lax.scan(f, x, blocks)

    regrouped = jax.tree.map(
        lambda a: a.reshape((nl // group, group) + a.shape[1:]), blocks)
    f_in = jax.checkpoint(f)   # bound live intermediates to ONE layer

    @jax.checkpoint
    def outer(carry, bgroup):  # save one residual per GROUP of layers
        return lax.scan(f_in, carry, bgroup)

    x, auxs = lax.scan(outer, x, regrouped)
    if with_aux and auxs is not None:
        auxs = jax.tree.map(
            lambda a: a.reshape((nl,) + a.shape[2:]), auxs)
    return x, auxs


def forward(cfg: ArchConfig, run: RunCfg, params, batch, *, collect_cache=False):
    """Full-sequence forward. Returns (logits, cache|None).

    cache (when collected) is the prefill KV/state pytree used by decode.
    """
    cd = _dt(cfg)
    x = _embed_in(params, cfg, batch)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.arange(s)[None, :] * jnp.ones((b, 1), jnp.int32)

    if cfg.mixer == "rwkv":
        x = _apply_norm(params["ln0"], x, cfg)
        hdim = rwkv_dims(cfg)
        st0 = {"x_tm": jnp.zeros((b, cfg.d_model), cd),
               "wkv": jnp.zeros((b, cfg.n_heads, hdim.head_size, hdim.head_size),
                                jnp.float32),
               "x_cm": jnp.zeros((b, cfg.d_model), cd)}

        def body(bp, carry):
            bp = _cast_f(bp, cd)
            y, st = _rwkv_block_fwd(bp, cfg, run, carry, st0)
            return y, st
        x, states = _scan_blocks(params["blocks"], x, body,
                                 run.remat and cfg.remat,
                                 with_aux=collect_cache)
        cache = states if collect_cache else None
    elif cfg.mixer == "hybrid":
        md = mamba_dims(cfg)
        per = cfg.hybrid_period
        st0 = {"conv": jnp.zeros((per - 1, b, md.d_conv - 1, md.d_inner), cd),
               "ssm": jnp.zeros((per - 1, b, md.d_inner, md.d_state), jnp.float32)}

        def body(bp, carry):
            bp = _cast_f(bp, cd)
            y, kv, st = _jamba_superblock_fwd(bp, cfg, run, carry, positions, st0)
            return y, (kv, st)
        x, aux = _scan_blocks(params["blocks"], x, body,
                              run.remat and cfg.remat, with_aux=collect_cache)
        cache = None
        if collect_cache:
            kvs, states = aux
            cache = {"k": kvs[0], "v": kvs[1], "states": states}
    elif cfg.encdec:
        enc = batch["frames"].astype(cd) + cm.sinusoid_positions(
            batch["frames"].shape[1], cfg.d_model, cd)[None]

        def enc_body(bp, carry):
            bp = _cast_f(bp, cd)
            h = _apply_norm(bp["ln1"], carry, cfg)
            a, _ = L.apply_attention(bp["attn"], attn_dims(cfg, causal=False), h, None)
            y = carry + a
            h = _apply_norm(bp["ln2"], y, cfg)
            return y + L.apply_mlp(bp["ff"], h, cfg.mlp_type), None
        enc, _ = _scan_blocks(params["enc_blocks"], enc, enc_body,
                              run.remat and cfg.remat, with_aux=False)
        enc = _apply_norm(params["enc_norm"], enc, cfg)

        sd = batch["tokens"].shape[1]
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cd)
        x = x + params["pos_embed"][:sd].astype(cd)[None]

        def dec_body(bp, carry):
            bp = _cast_f(bp, cd)
            h = _apply_norm(bp["ln1"], carry, cfg)
            a, kv = L.apply_attention(bp["attn"], attn_dims(cfg), h, None)
            y = carry + a
            h = _apply_norm(bp["lnx"], y, cfg)
            xk = jnp.einsum("btd,dhk->bthk", enc, bp["xattn"]["wk"])
            xv = jnp.einsum("btd,dhk->bthk", enc, bp["xattn"]["wv"])
            y = y + L.apply_cross_attention(bp["xattn"], attn_dims(cfg, causal=False),
                                            h, xk, xv)
            h = _apply_norm(bp["ln2"], y, cfg)
            return y + L.apply_mlp(bp["ff"], h, cfg.mlp_type), (kv, (xk, xv))
        x, aux = _scan_blocks(params["dec_blocks"], x, dec_body,
                              run.remat and cfg.remat, with_aux=collect_cache)
        cache = None
        if collect_cache:
            kvs, xkvs = aux
            cache = {"k": kvs[0], "v": kvs[1], "xk": xkvs[0], "xv": xkvs[1]}
    else:
        def body(bp, carry):
            bp = _cast_f(bp, cd)
            return _uniform_block_fwd(bp, cfg, run, carry, positions)
        if "first_blocks" in params:
            x, kv0 = _scan_blocks(params["first_blocks"], x, body,
                                  run.remat and cfg.remat,
                                  with_aux=collect_cache)
        else:
            kv0 = None
        x, kvs = _scan_blocks(params["blocks"], x, body, run.remat and cfg.remat,
                              with_aux=collect_cache)
        cache = None
        if collect_cache:
            if kv0 is not None:
                kvs = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), kv0, kvs)
            cache = {"k": kvs[0], "v": kvs[1]}

    x = _apply_norm(params["final_norm"], x, cfg)
    return _head_out(params, cfg, x), cache


# ---------------------------------------------------------------------------
# decode (one token against a cache)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, b: int, t_max: int, *, t_enc: int = 0):
    """Zero-initialized decode cache pytree (shapes only matter for dry-run;
    real serving fills it via prefill + pad_cache)."""
    cd = _dt(cfg)
    if cfg.mixer == "rwkv":
        hd = rwkv_dims(cfg)
        L_ = cfg.n_layers
        return {"x_tm": jnp.zeros((L_, b, cfg.d_model), cd),
                "wkv": jnp.zeros((L_, b, cfg.n_heads, hd.head_size, hd.head_size),
                                 jnp.float32),
                "x_cm": jnp.zeros((L_, b, cfg.d_model), cd),
                "len": jnp.zeros((), jnp.int32)}
    if cfg.mixer == "hybrid":
        md = mamba_dims(cfg)
        nb = cfg.n_layers // cfg.hybrid_period
        per = cfg.hybrid_period
        return {"k": jnp.zeros((nb, b, t_max, cfg.n_kv_heads, cfg.head_dim_), cd),
                "v": jnp.zeros((nb, b, t_max, cfg.n_kv_heads, cfg.head_dim_), cd),
                "conv": jnp.zeros((nb, per - 1, b, md.d_conv - 1, md.d_inner), cd),
                "ssm": jnp.zeros((nb, per - 1, b, md.d_inner, md.d_state), jnp.float32),
                "len": jnp.zeros((), jnp.int32)}
    if cfg.encdec:
        L_ = cfg.n_layers
        h, hd = cfg.n_heads, cfg.head_dim_
        return {"k": jnp.zeros((L_, b, t_max, cfg.n_kv_heads, hd), cd),
                "v": jnp.zeros((L_, b, t_max, cfg.n_kv_heads, hd), cd),
                "xk": jnp.zeros((L_, b, t_enc or t_max, h, hd), cd),
                "xv": jnp.zeros((L_, b, t_enc or t_max, h, hd), cd),
                "len": jnp.zeros((), jnp.int32)}
    if cfg.attn_kind == "mla":
        m = cfg.mla
        L_ = cfg.n_layers
        return {"k": jnp.zeros((L_, b, t_max, m.kv_lora_rank), cd),
                "v": jnp.zeros((L_, b, t_max, m.qk_rope_dim), cd),
                "len": jnp.zeros((), jnp.int32)}
    L_ = cfg.n_layers
    if cfg.kv_quant:
        return {"k": jnp.zeros((L_, b, t_max, cfg.n_kv_heads, cfg.head_dim_), jnp.int8),
                "v": jnp.zeros((L_, b, t_max, cfg.n_kv_heads, cfg.head_dim_), jnp.int8),
                "k_scale": jnp.zeros((L_, b, t_max, cfg.n_kv_heads, 1), jnp.float32),
                "v_scale": jnp.zeros((L_, b, t_max, cfg.n_kv_heads, 1), jnp.float32),
                "len": jnp.zeros((), jnp.int32)}
    return {"k": jnp.zeros((L_, b, t_max, cfg.n_kv_heads, cfg.head_dim_), cd),
            "v": jnp.zeros((L_, b, t_max, cfg.n_kv_heads, cfg.head_dim_), cd),
            "len": jnp.zeros((), jnp.int32)}


def pad_cache(cfg: ArchConfig, cache, s: int, t_max: int):
    """Pad a prefill cache's time axis to t_max and set len=s."""
    if cfg.mixer == "rwkv":
        return dict(cache, len=jnp.asarray(s, jnp.int32))
    out = dict(cache)
    for k in ("k", "v"):
        a = cache[k]
        pad = [(0, 0)] * a.ndim
        pad[2] = (0, t_max - a.shape[2])
        out[k] = jnp.pad(a, pad)
    out["len"] = jnp.asarray(s, jnp.int32)
    return out


_flat_rank = compat.flat_axis_index


def _attn_decode(bp, cfg, run: RunCfg, h, ck, cv, clen, positions):
    """GQA decode, optionally with time-sharded KV (long_500k path)."""
    a = attn_dims(cfg)
    if not (run.seq_shard_kv and run.mesh is not None):
        return L.apply_attention_decode(bp, a, h, ck, cv, clen, positions)
    from jax.sharding import PartitionSpec as P
    q, knew, vnew = L._qkv(bp, a, h, positions)
    dax = tuple(run.data_axes)
    dspec = dax if len(dax) > 1 else dax[0]

    def local(qq, ks, vs, kn, vn, ln):
        r = _flat_rank(dax)
        tl = ks.shape[1]
        start = r * tl
        off = ln - start
        ok = (off >= 0) & (off < tl)
        offc = jnp.clip(off, 0, tl - 1)
        k2 = lax.dynamic_update_slice_in_dim(ks, kn.astype(ks.dtype), offc, 1)
        v2 = lax.dynamic_update_slice_in_dim(vs, vn.astype(vs.dtype), offc, 1)
        k2 = jnp.where(ok, k2, ks)
        v2 = jnp.where(ok, v2, vs)
        valid = ((start + jnp.arange(tl))[None, :] <= ln)
        valid = jnp.broadcast_to(valid, (qq.shape[0], tl))
        o = L.decode_attention_seqsharded(qq, k2, v2, valid,
                                          dax if len(dax) > 1 else dax[0])
        return o, k2, v2

    kvspec = P(None, dspec, None, None)
    o, ck2, cv2 = compat.shard_map(
        local, mesh=run.mesh,
        in_specs=(P(), kvspec, kvspec, P(), P(), P()),
        out_specs=(P(), kvspec, kvspec), check_vma=False)(
            q, ck, cv, knew, vnew, clen)
    return jnp.einsum("bshd,hdm->bsm", o, bp["wo"]), ck2, cv2


def decode_step(cfg: ArchConfig, run: RunCfg, params, cache, tokens):
    """One greedy-decode step. tokens: (B, 1) int32. Returns (logits, cache)."""
    cd = _dt(cfg)
    b = tokens.shape[0]
    clen = cache.get("len", jnp.zeros((), jnp.int32))
    positions = jnp.full((b, 1), clen, jnp.int32)

    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cd)

    if cfg.mixer == "rwkv":
        x = x[:, 0, :]
        x = _apply_norm(params["ln0"], x, cfg)

        def body(carry, xs):
            bp, x_tm, wkv, x_cm = xs
            bp = _cast_f(bp, cd)
            h1 = _apply_norm(bp["ln1"], carry, cfg)
            y, wkv2 = RW.rwkv_time_mix_step(bp["tm"], rwkv_dims(cfg), h1, x_tm, wkv)
            y0 = carry + y
            h2 = _apply_norm(bp["ln2"], y0, cfg)
            y2, x_cm2 = RW.rwkv_channel_mix_step(bp["cm"], h2, x_cm)
            return y0 + y2, (h1, wkv2, x_cm2)

        x, (ntm, nwkv, ncm) = lax.scan(
            body, x, (params["blocks"], cache["x_tm"], cache["wkv"], cache["x_cm"]))
        x = x[:, None, :]
        new_cache = {"x_tm": ntm, "wkv": nwkv, "x_cm": ncm,
                     "len": clen + 1}
    elif cfg.mixer == "hybrid":
        md = mamba_dims(cfg)
        per = cfg.hybrid_period

        # caches live in the scan CARRY and update in place via
        # dynamic_update_index (xs→ys stacking would double-buffer the
        # multi-GiB KV arrays; §Perf iteration M4)
        def body(carry, xs):
            x, k_all, v_all, conv_all, ssm_all = carry
            bp, i = xs
            bp = _cast_f(bp, cd)
            ck = lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
            cv = lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
            conv = lax.dynamic_index_in_dim(conv_all, i, 0, keepdims=False)
            ssm = lax.dynamic_index_in_dim(ssm_all, i, 0, keepdims=False)
            nconv, nssm = [], []
            mi = 0
            for j in range(per):
                h = cm.rms_norm(x, bp["ln1"][j])
                if j == cfg.hybrid_attn_pos:
                    a, ck, cv = _attn_decode(bp["attn"], cfg, run, h, ck, cv,
                                             clen, positions)
                else:
                    mp = jax.tree.map(lambda t: t[mi], bp["mamba"])
                    a2, (cs, ss) = MB.mamba_step(mp, md, h[:, 0, :],
                                                 conv[mi], ssm[mi])
                    a = a2[:, None, :]
                    nconv.append(cs)
                    nssm.append(ss)
                    mi += 1
                x = x + a
                h = cm.rms_norm(x, bp["ln2"][j])
                if j % cfg.moe.every == 1 % cfg.moe.every:
                    fp = jax.tree.map(lambda t: t[j // cfg.moe.every], bp["moe"])
                    x = x + _ff_apply(fp, cfg, run, h)
                else:
                    fp = jax.tree.map(lambda t: t[j // cfg.moe.every], bp["mlp"])
                    x = x + L.apply_mlp(fp, h, cfg.mlp_type)
            k_all = lax.dynamic_update_index_in_dim(k_all, ck, i, 0)
            v_all = lax.dynamic_update_index_in_dim(v_all, cv, i, 0)
            conv_all = lax.dynamic_update_index_in_dim(conv_all, jnp.stack(nconv), i, 0)
            ssm_all = lax.dynamic_update_index_in_dim(ssm_all, jnp.stack(nssm), i, 0)
            return (x, k_all, v_all, conv_all, ssm_all), None

        nb = cfg.n_layers // per
        (x, nk, nv, nconv, nssm), _ = lax.scan(
            body, (x, cache["k"], cache["v"], cache["conv"], cache["ssm"]),
            (params["blocks"], jnp.arange(nb)))
        new_cache = {"k": nk, "v": nv, "conv": nconv, "ssm": nssm,
                     "len": clen + 1}
    elif cfg.encdec:
        x = x + params["pos_embed"].astype(cd)[clen][None, None]

        def body(carry, xs):
            y, k_all, v_all = carry
            bp, xk, xv, i = xs
            bp = _cast_f(bp, cd)
            ck = lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
            cv = lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
            h = _apply_norm(bp["ln1"], y, cfg)
            a, ck2, cv2 = L.apply_attention_decode(
                bp["attn"], attn_dims(cfg), h, ck, cv, clen, None)
            y = y + a
            h = _apply_norm(bp["lnx"], y, cfg)
            y = y + L.apply_cross_attention(
                bp["xattn"], attn_dims(cfg, causal=False), h, xk, xv)
            h = _apply_norm(bp["ln2"], y, cfg)
            y = y + L.apply_mlp(bp["ff"], h, cfg.mlp_type)
            k_all = lax.dynamic_update_index_in_dim(k_all, ck2, i, 0)
            v_all = lax.dynamic_update_index_in_dim(v_all, cv2, i, 0)
            return (y, k_all, v_all), None

        (x, nk, nv), _ = lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["dec_blocks"], cache["xk"], cache["xv"],
             jnp.arange(cfg.n_layers)))
        new_cache = dict(cache, k=nk, v=nv, len=clen + 1)
    elif cfg.kv_quant and cfg.attn_kind == "gqa" and "first_blocks" not in params:
        from repro.models import kvquant as KQ

        def bodyq(carry, xs):
            y, k_all, v_all, ks_all, vs_all = carry
            bp, i = xs
            bp = _cast_f(bp, cd)
            a_dims = attn_dims(cfg)
            h = _apply_norm(bp["ln1"], y, cfg)
            q, knew, vnew = L._qkv(bp["attn"], a_dims, h, positions)
            # dequantize this layer's cache slab, splice the new entry in
            ck = KQ.dequantize(lax.dynamic_index_in_dim(k_all, i, 0, False),
                               lax.dynamic_index_in_dim(ks_all, i, 0, False), cd)
            cv = KQ.dequantize(lax.dynamic_index_in_dim(v_all, i, 0, False),
                               lax.dynamic_index_in_dim(vs_all, i, 0, False), cd)
            ck = lax.dynamic_update_slice_in_dim(ck, knew.astype(cd), clen, axis=1)
            cv = lax.dynamic_update_slice_in_dim(cv, vnew.astype(cd), clen, axis=1)
            t_ = ck.shape[1]
            valid = (jnp.arange(t_)[None, :] <= clen)[None, None, None]
            o = L._sdpa_direct(q, ck, cv, a_dims, valid)
            a = jnp.einsum("bshd,hdm->bsm", o, bp["attn"]["wo"])
            y = y + a
            h = _apply_norm(bp["ln2"], y, cfg)
            y = y + _ff_apply(bp["ff"], cfg, run, h)
            # quantize ONLY the new entry back into the int8 cache
            kq, ks = KQ.quantize(knew[:, 0])
            vq, vs = KQ.quantize(vnew[:, 0])
            def upd(all_, lay, newv):
                lay2 = lax.dynamic_update_slice_in_dim(
                    lay, newv[:, None].astype(lay.dtype), clen, axis=1)
                return lax.dynamic_update_index_in_dim(all_, lay2, i, 0)
            k_all = upd(k_all, lax.dynamic_index_in_dim(k_all, i, 0, False), kq)
            v_all = upd(v_all, lax.dynamic_index_in_dim(v_all, i, 0, False), vq)
            ks_all = upd(ks_all, lax.dynamic_index_in_dim(ks_all, i, 0, False), ks)
            vs_all = upd(vs_all, lax.dynamic_index_in_dim(vs_all, i, 0, False), vs)
            return (y, k_all, v_all, ks_all, vs_all), None

        (x, nk, nv, nks, nvs), _ = lax.scan(
            bodyq, (x, cache["k"], cache["v"], cache["k_scale"], cache["v_scale"]),
            (params["blocks"], jnp.arange(cfg.n_layers)))
        new_cache = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs,
                     "len": clen + 1}
    else:
        def body(carry, xs):
            y, k_all, v_all = carry
            bp, i = xs
            bp = _cast_f(bp, cd)
            ck = lax.dynamic_index_in_dim(k_all, i, 0, keepdims=False)
            cv = lax.dynamic_index_in_dim(v_all, i, 0, keepdims=False)
            h = _apply_norm(bp["ln1"], y, cfg)
            if cfg.attn_kind == "mla":
                a, ck2, cv2 = MLA.apply_mla_decode(
                    bp["attn"], mla_dims(cfg), h, ck, cv, clen, positions)
            else:
                a, ck2, cv2 = _attn_decode(bp["attn"], cfg, run, h, ck, cv,
                                           clen, positions)
            y = y + a
            h = _apply_norm(bp["ln2"], y, cfg)
            y = y + _ff_apply(bp["ff"], cfg, run, h)
            k_all = lax.dynamic_update_index_in_dim(k_all, ck2.astype(k_all.dtype), i, 0)
            v_all = lax.dynamic_update_index_in_dim(v_all, cv2.astype(v_all.dtype), i, 0)
            return (y, k_all, v_all), None

        nd = cfg.moe.first_dense if cfg.moe else 0
        if nd:
            (x, nk0, nv0), _ = lax.scan(
                body, (x, cache["k"][:nd], cache["v"][:nd]),
                (params["first_blocks"], jnp.arange(nd)))
            (x, nk1, nv1), _ = lax.scan(
                body, (x, cache["k"][nd:], cache["v"][nd:]),
                (params["blocks"], jnp.arange(cfg.n_layers - nd)))
            nk = jnp.concatenate([nk0, nk1], 0)
            nv = jnp.concatenate([nv0, nv1], 0)
        else:
            (x, nk, nv), _ = lax.scan(
                body, (x, cache["k"], cache["v"]),
                (params["blocks"], jnp.arange(cfg.n_layers)))
        new_cache = {"k": nk, "v": nv, "len": clen + 1}

    x = _apply_norm(params["final_norm"], x, cfg)
    return _head_out(params, cfg, x), new_cache


# ---------------------------------------------------------------------------
# losses / entry points
# ---------------------------------------------------------------------------

def lm_loss(cfg: ArchConfig, run: RunCfg, params, batch):
    """Next-token cross entropy (f32 softmax), mean over tokens.

    The gold-logit pick uses a fused iota==target select, NOT
    take_along_axis: a vocab-dim gather forces XLA to all-gather the
    model-sharded logits (observed +60 GiB/device on train_4k cells —
    EXPERIMENTS.md §Perf iteration M1).
    """
    logits, _ = forward(cfg, run, params, batch)
    if cfg.embed_mode in ("embeds",):
        targets = batch["labels"]
    else:
        targets = batch["tokens"]
    logits = cm.shard_act(logits, ("batch", "seq", "vocab"))
    logits = logits.astype(jnp.float32)   # f32 boundary is HERE (see _head_out)
    logits = logits[:, :-1]
    targets = targets[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=targets.dtype)
    gold = jnp.sum(jnp.where(vocab_iota == targets[..., None], logits, 0.0),
                   axis=-1)
    return jnp.mean(logz - gold)


def prefill(cfg: ArchConfig, run: RunCfg, params, batch, t_max: int = 0):
    logits, cache = forward(cfg, run, params, batch, collect_cache=True)
    s = (batch.get("tokens") if cfg.embed_mode == "tokens" else
         batch.get("embeds", batch.get("tokens"))).shape[1]
    if cfg.mixer == "hybrid":
        cache = {"k": cache["k"], "v": cache["v"],
                 "conv": cache["states"]["conv"], "ssm": cache["states"]["ssm"]}
        cache["len"] = jnp.asarray(s, jnp.int32)
        if t_max and t_max > s:
            for kk in ("k", "v"):
                a = cache[kk]
                pad = [(0, 0)] * a.ndim
                pad[2] = (0, t_max - a.shape[2])
                cache[kk] = jnp.pad(a, pad)
    elif cfg.mixer == "rwkv":
        cache = dict(cache, len=jnp.asarray(s, jnp.int32))
    else:
        cache = {("k"): cache["k"], "v": cache["v"],
                 **({"xk": cache["xk"], "xv": cache["xv"]} if cfg.encdec else {})}
        cache["len"] = jnp.asarray(s, jnp.int32)
        if t_max and t_max > s:
            cache = pad_cache(cfg, dict(cache), s, t_max)
    return logits[:, -1:], cache
