"""Token-choice top-k Mixture of Experts with capacity-based dispatch.

The dispatch/combine exchange is the paper's *fold communication* in LM
clothing: tokens sharded over ``data`` are exchanged with experts sharded
over ``model`` — an all-to-all along one mesh axis, exactly the X↔Y pencil
transpose pattern (DESIGN.md §4.2). The default path expresses it as einsum
dispatch under auto-SPMD; XLA lowers the resharding to all-to-all/collective
ops which the roofline's collective term measures.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.layers import init_mlp
from repro.models.common import shard_act


@dataclasses.dataclass(frozen=True)
class MoEDims:
    d_model: int
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0           # deepseek shared experts
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    mlp_type: str = "swiglu"
    router_norm_topk: bool = True   # qwen3 renormalizes the top-k probs


def init_moe(ini, m: MoEDims):
    p = {"router": ini.param("router", (m.d_model, m.n_experts),
                             ("embed", "experts"), scale=0.02)}
    sub = ini.sub("experts")
    p["experts"] = {
        "wi_gate": sub.param("wi_gate", (m.n_experts, m.d_model, m.d_ff_expert),
                             ("experts", "embed", "expert_mlp")),
        "wi_up": sub.param("wi_up", (m.n_experts, m.d_model, m.d_ff_expert),
                           ("experts", "embed", "expert_mlp")),
        "wo": sub.param("wo", (m.n_experts, m.d_ff_expert, m.d_model),
                        ("experts", "expert_mlp", "embed")),
    }
    if m.n_shared:
        p["shared"] = init_mlp(ini.sub("shared"), m.d_model,
                               m.d_ff_shared or m.d_ff_expert * m.n_shared,
                               m.mlp_type)
    return p


def _capacity(m: MoEDims, n_tokens: int) -> int:
    cap = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, min(cap, n_tokens))


def apply_moe(p, m: MoEDims, x):
    """x: (B, S, D) -> (B, S, D). Capacity-dropped token-choice routing."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    cap = _capacity(m, t)

    gate_logits = (xt @ p["router"]).astype(jnp.float32)           # (T, E)
    probs = jax.nn.softmax(gate_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)                   # (T, k)
    if m.router_norm_topk:
        top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # position of each (token, slot) in its expert's buffer
    onehot = jax.nn.one_hot(top_e, m.n_experts, dtype=jnp.int32)   # (T, k, E)
    flat = onehot.reshape(t * m.top_k, m.n_experts)
    pos = jnp.cumsum(flat, axis=0) - flat                          # arrival order
    pos = jnp.sum(pos * flat, axis=-1).reshape(t, m.top_k)
    keep = pos < cap

    # dispatch tensor (T, E, cap) — one-hot token→(expert, slot)
    disp = (jax.nn.one_hot(top_e, m.n_experts, dtype=xt.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                             dtype=xt.dtype)[..., None, :-1]
            )                                                      # (T,k,E,cap)
    combine = disp * top_p.astype(xt.dtype)[..., None, None]
    disp = jnp.sum(disp, axis=1)                                   # (T,E,cap)
    combine = jnp.sum(combine, axis=1)

    xe = jnp.einsum("td,tec->ecd", xt, disp)                       # (E,cap,D)
    xe = shard_act(xe, ("experts", None, "embed"))
    w = p["experts"]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w["wi_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, w["wi_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, w["wo"])
    ye = shard_act(ye, ("experts", None, "embed"))
    out = jnp.einsum("ecd,tec->td", ye, combine)

    if "shared" in p:
        from repro.models.layers import apply_mlp
        out = out + apply_mlp(p["shared"], xt[None], m.mlp_type)[0]
    return out.reshape(b, s, d)


# ---------------------------------------------------------------------------
# Expert-parallel path: the paper's fold communication, explicitly.
#
# Tokens live on the `data` mesh axis, experts on `model`. Dispatch is a
# capacity-bounded all_to_all along `model` (exactly an X↔Y pencil fold),
# local compute is a sort + ragged (grouped) matmul, combine is the mirror
# all_to_all. ``chunks`` applies the paper's pipelined schedule (§4.3.2) to
# the MoE: slab the tokens so chunk i's exchange overlaps chunk i+1's FFN.
# ---------------------------------------------------------------------------

def moe_ep_local(xt, p, m: MoEDims, model_axes: tuple[str, ...]):
    """Inside shard_map: xt (T_loc, D) local tokens; expert weights sharded
    (E_loc, ...) along `model`. Returns (T_loc, D).

    GShard-style fixed per-(sender, expert) capacity: every buffer is static
    so the expert FFN is one batched einsum (no sort / ragged matmul — the
    ragged path materialized per-group masks under XLA:CPU, +200 GiB on the
    qwen3 train cell; EXPERIMENTS.md §Perf iteration M3)."""
    from jax import lax

    t, d = xt.shape
    msize = compat.axes_size(model_axes)
    name = model_axes if len(model_axes) > 1 else model_axes[0]
    e_loc = m.n_experts // msize
    k = m.top_k
    e = m.n_experts

    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    if m.router_norm_topk:
        top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                       # (T·k,)
    flat_w = top_p.reshape(-1)
    cap = int(math.ceil(t * k * m.capacity_factor / e))
    cap = max(4, ((cap + 3) // 4) * 4)
    # position of each entry within its expert's slab (arrival order)
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(oh, axis=0) - oh) * oh, axis=-1)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)   # overflow bucket

    tok_idx = jnp.arange(t * k, dtype=jnp.int32) // k
    send_tok = jnp.full((e * cap + 1,), t, jnp.int32).at[slot].set(tok_idx)
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    send_x = xt_pad[send_tok[:-1]]                   # (E·cap, D)

    # fold communication along `model` (the X↔Y pencil exchange)
    recv_x = lax.all_to_all(send_x, name, 0, 0, tiled=True)
    # recv: (msize, e_loc, cap, D) — rank-major blocks, expert slabs static
    xe = recv_x.reshape(msize, e_loc, cap, d).transpose(1, 0, 2, 3)
    xe = xe.reshape(e_loc, msize * cap, d)

    w = p["experts"]
    act = jax.nn.silu if m.mlp_type == "swiglu" else \
        (lambda v: jax.nn.gelu(v, approximate=True))
    h = act(jnp.einsum("ecd,edf->ecf", xe, w["wi_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xe, w["wi_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, w["wo"])      # (e_loc, msize·cap, D)

    ye = ye.reshape(e_loc, msize, cap, d).transpose(1, 0, 2, 3)
    back = lax.all_to_all(ye.reshape(msize * e_loc * cap, d), name, 0, 0,
                          tiled=True)
    back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)], axis=0)
    wgt = jnp.where(keep, flat_w, 0.0).astype(back.dtype)
    gathered = back[slot] * wgt[:, None]
    out = jnp.sum(gathered.reshape(t, k, d), axis=1)
    return out


def apply_moe_ep(p, m: MoEDims, x, mesh, *, data_axes=("data",),
                 model_axes=("model",), chunks: int = 1):
    """shard_map wrapper; x (B,S,D) batch-sharded over ``data_axes``."""
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    dspec = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
    mspec = tuple(model_axes) if len(model_axes) > 1 else model_axes[0]

    def local_fn(xl, router, wig, wiu, wo):
        pl = {"router": router,
              "experts": {"wi_gate": wig, "wi_up": wiu, "wo": wo}}
        bl = xl.shape[0]
        xt = xl.reshape(-1, d)
        tl = xt.shape[0]
        c = min(chunks, tl)
        while tl % c:
            c -= 1
        step = tl // c
        # remat per chunk so only ONE chunk's dispatch buffers are live
        # during the layer's backward (§Perf iteration M7)
        one = jax.checkpoint(
            lambda ct: moe_ep_local(ct, pl, m, tuple(model_axes)))
        outs = [one(xt[i * step:(i + 1) * step]) for i in range(c)]
        return jnp.concatenate(outs, axis=0).reshape(bl, s, d)

    w = p["experts"]
    fn = compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dspec, None, None), P(), P(mspec, None, None),
                  P(mspec, None, None), P(mspec, None, None)),
        out_specs=P(dspec, None, None), check_vma=False)
    out = fn(x, p["router"], w["wi_gate"], w["wi_up"], w["wo"])
    if "shared" in p:
        from repro.models.layers import apply_mlp
        out = out + apply_mlp(p["shared"], x, m.mlp_type)
    return out


def load_balance_loss(gate_logits, top_e, n_experts: int) -> jax.Array:
    """Switch-style auxiliary loss (fraction·prob per expert)."""
    probs = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_e[..., 0], n_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac * imp)
