"""Mamba (S6) selective-SSM block for the Jamba hybrid (arXiv:2403.19887).

Selective scan over time with data-dependent (Δ, B, C); causal depthwise
conv front-end. State per layer: conv tail (B, d_conv−1, d_inner) + SSM state
(B, d_inner, d_state) — O(1) decode, which is what makes the hybrid run
``long_500k``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class MambaDims:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, -(-self.d_model // 16))


def init_mamba(ini, m: MambaDims):
    di, ds, dr = m.d_inner, m.d_state, m.dt_rank
    return {
        "in_proj": ini.param("in_proj", (m.d_model, 2 * di), ("embed", "mlp")),
        "conv_w": ini.param("conv_w", (m.d_conv, di), ("conv", "mlp"), scale=0.1),
        "conv_b": ini.param("conv_b", (di,), ("mlp",), mode="zeros"),
        "x_proj": ini.param("x_proj", (di, dr + 2 * ds), ("mlp", "state")),
        "dt_w": ini.param("dt_w", (dr, di), ("state", "mlp")),
        "dt_b": ini.param("dt_b", (di,), ("mlp",), mode="ones"),
        "A_log": ini.param("A_log", (di, ds), ("mlp", "state"), mode="ones"),
        "D": ini.param("D", (di,), ("mlp",), mode="ones"),
        "out_proj": ini.param("out_proj", (di, m.d_model), ("mlp", "embed")),
    }


def chunked_time_scan(step, carry0, xs, chunk: int = 256):
    """Two-level rematted scan over time: the backward saves carries once per
    *chunk* instead of per step (a 4096-step scan over a (B, d_inner,
    d_state) f32 carry otherwise banks ~8.6 GiB per layer)."""
    s = jax.tree.leaves(xs)[0].shape[0]
    c = min(chunk, s)
    while s % c:
        c //= 2
    if c <= 1:
        return lax.scan(step, carry0, xs)
    xs_r = jax.tree.map(lambda a: a.reshape((s // c, c) + a.shape[1:]), xs)

    step_ck = jax.checkpoint(step)   # per-step intermediates stay transient

    @jax.checkpoint
    def outer(carry, xsc):
        return lax.scan(step_ck, carry, xsc)

    carry, ys = lax.scan(outer, carry0, xs_r)
    ys = jax.tree.map(lambda a: a.reshape((s,) + a.shape[2:]), ys)
    return carry, ys


def _ssm_inputs(p, m: MambaDims, xc):
    """xc: (..., d_inner) post-conv activations -> (Δ, B, C) f32."""
    proj = xc @ p["x_proj"]
    dr, ds = m.dt_rank, m.d_state
    dt = jax.nn.softplus((proj[..., :dr] @ p["dt_w"]
                          + p["dt_b"].astype(proj.dtype)).astype(jnp.float32))
    bmat = proj[..., dr:dr + ds].astype(jnp.float32)
    cmat = proj[..., dr + ds:].astype(jnp.float32)
    return dt, bmat, cmat


def mamba_seq(p, m: MambaDims, x, conv_state0, ssm_state0):
    """x: (B, S, D) -> (y, (conv_tail, ssm_state))."""
    from repro.models.common import shard_act

    b, s, d = x.shape
    di, ds = m.d_inner, m.d_state
    xz = x @ p["in_proj"]
    xz = shard_act(xz, ("batch", "seq", "mlp"))   # keep d_inner TP-sharded
    xi, z = xz[..., :di], xz[..., di:]
    # causal depthwise conv with carried tail
    xpad = jnp.concatenate([conv_state0.astype(xi.dtype), xi], axis=1)
    conv = sum(xpad[:, i:i + s, :] * p["conv_w"][i].astype(xi.dtype)
               for i in range(m.d_conv))
    xc = jax.nn.silu(conv + p["conv_b"].astype(xi.dtype))
    dt, bmat, cmat = _ssm_inputs(p, m, xc)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))               # (di, ds)

    # discretize INSIDE the (rematted) step: precomputing da/dbx materializes
    # (B,S,di,ds) f32 ≈ 69 GiB/device on the jamba train_4k cell
    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp
        da_t = jnp.exp(dt_t[..., None] * a)                    # (B,di,ds)
        h = da_t * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xc = shard_act(xc, ("batch", "seq", "mlp"))
    dt = shard_act(dt, ("batch", "seq", "mlp"))
    xs = (jnp.swapaxes(dt, 0, 1), jnp.swapaxes(bmat, 0, 1),
          jnp.swapaxes(cmat, 0, 1), jnp.swapaxes(xc.astype(jnp.float32), 0, 1))
    h_last, ys = chunked_time_scan(step, ssm_state0, xs)
    y = jnp.swapaxes(ys, 0, 1)                                 # (B,S,di)
    y = shard_act(y, ("batch", "seq", "mlp"))
    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    conv_tail = xpad[:, s:, :] if m.d_conv > 1 else conv_state0
    return out, (conv_tail.astype(conv_state0.dtype), h_last)


def mamba_step(p, m: MambaDims, x_t, conv_state, ssm_state):
    """One-token decode. x_t: (B, D); conv_state: (B, d_conv-1, d_inner)."""
    di = m.d_inner
    xz = x_t @ p["in_proj"]
    xi, z = xz[..., :di], xz[..., di:]
    window = jnp.concatenate([conv_state.astype(xi.dtype), xi[:, None, :]], axis=1)
    conv = jnp.einsum("bcd,cd->bd", window, p["conv_w"].astype(xi.dtype))
    xc = jax.nn.silu(conv + p["conv_b"].astype(xi.dtype))
    dt, bmat, cmat = _ssm_inputs(p, m, xc)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    da = jnp.exp(dt[..., None] * a)                            # (B,di,ds)
    dbx = (dt * xc.astype(jnp.float32))[..., None] * bmat[:, None, :]
    h = da * ssm_state + dbx
    y = jnp.einsum("bds,bs->bd", h, cmat)
    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)
    y = y.astype(x_t.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], (window[:, 1:, :].astype(conv_state.dtype), h)
