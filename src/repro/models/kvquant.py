"""Int8 KV-cache quantization (beyond-paper decode memory-term lever).

Per-(token, head) symmetric scales: k/v stored int8 with an f32 scale of
shape (..., H, 1) — cache HBM traffic and residency halve vs bf16 (the
scale adds 1/(2·head_dim) overhead). Dequantization happens on read inside
the attention block; the new token's entry is quantized on write.

Enabled per-arch via ``ArchConfig.kv_quant`` (uniform GQA decode path).
Accuracy: per-head amax scaling bounds relative error at ~0.4% per element;
tests assert decode logits track the bf16 cache closely and argmax agrees.
"""

from __future__ import annotations

import jax.numpy as jnp


def quantize(x):
    """x: (..., D) -> (int8 q, f32 scale (..., 1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)
