"""Shared model plumbing: parameter init with logical-axis tagging, norms,
activation-sharding helpers. Pure JAX — params are pytrees of arrays and a
parallel pytree of logical axis tuples drives sharding (MaxText-style rules
live in ``repro.distributed.sharding``)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Initializer:
    """Creates params and records each leaf's logical axes in a mirror tree."""

    key: jax.Array
    dtype: jnp.dtype
    axes: dict = dataclasses.field(default_factory=dict)

    def sub(self, name: str) -> "Initializer":
        self.key, k = jax.random.split(self.key)
        child = Initializer(key=k, dtype=self.dtype, axes={})
        self.axes[name] = child.axes
        return child

    def param(self, name: str, shape, logical, scale: float | None = None,
              mode: str = "normal"):
        self.key, k = jax.random.split(self.key)
        assert len(shape) == len(logical), (name, shape, logical)
        if mode == "zeros":
            w = jnp.zeros(shape, self.dtype)
        elif mode == "ones":
            w = jnp.ones(shape, self.dtype)
        else:
            if scale is None:
                scale = 1.0 / np.sqrt(max(shape[0], 1))
            w = (scale * jax.random.normal(k, shape, jnp.float32)).astype(self.dtype)
        self.axes[name] = tuple(logical)
        return w


def stack_params(trees):
    """Stack per-layer param trees along a new leading 'layers' axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def stack_axes(axes_tree, name: str = "layers"):
    """Prefix every logical-axes leaf with a stacking axis (scan depth)."""
    def fix(leaf):
        return (name,) + tuple(leaf)
    return jax.tree.map(fix, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6, plus_one: bool = False):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (x32 * inv * scale).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# activation sharding
# ---------------------------------------------------------------------------

_ACT_RULES: dict = {}


def set_activation_rules(rules: dict) -> None:
    """Install logical→mesh rules for activation constraints (set by the
    launcher; empty rules = no constraints, e.g. single-device tests)."""
    global _ACT_RULES
    _ACT_RULES = dict(rules)


def shard_act(x, logical):
    """with_sharding_constraint by logical axes, if rules are installed."""
    if not _ACT_RULES:
        return x
    from jax.sharding import PartitionSpec as P
    spec = tuple(_ACT_RULES.get(a) for a in logical)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, TypeError, RuntimeError):
        return x  # no mesh in context / inside manual shard_map region


def sinusoid_positions(t: int, d: int, dtype=jnp.float32):
    """Whisper-style fixed sinusoidal embeddings (t, d)."""
    half = d // 2
    freqs = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    pos = np.arange(t)[:, None] * freqs[None, :]
    emb = np.concatenate([np.sin(pos), np.cos(pos)], axis=1)
    return jnp.asarray(emb, dtype)
