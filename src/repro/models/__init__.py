from repro.models.transformer import (RunCfg, init_model, model_axes, forward,
                                      decode_step, init_cache, pad_cache,
                                      prefill, lm_loss)

__all__ = ["RunCfg", "init_model", "model_axes", "forward", "decode_step",
           "init_cache", "pad_cache", "prefill", "lm_loss"]
