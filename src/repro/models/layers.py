"""Attention (GQA/MQA), RoPE, and MLP layers with train / prefill / decode
paths, including the sequence-sharded long-context decode used by
``long_500k`` (flash-decoding-style log-sum-exp combine across the mesh —
the pencil idiom: keep the KV slab local, reduce across the grid)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.common import shard_act


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions, head_dim: int, base: float = 10000.0):
    """positions: (..., S) int -> cos/sin (..., S, head_dim/2) f32."""
    half = head_dim // 2
    inv = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, half) or (S, half). Pairs (even, odd
    halves) convention (HF llama style: split at D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    else:
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = x1f * c - x2f * s
    o2 = x2f * c + x1f * s
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(ini, d_model: int, d_ff: int, mlp_type: str):
    p = {}
    if mlp_type in ("swiglu", "geglu"):
        p["wi_gate"] = ini.param("wi_gate", (d_model, d_ff), ("embed", "mlp"))
        p["wi_up"] = ini.param("wi_up", (d_model, d_ff), ("embed", "mlp"))
    else:  # plain gelu (whisper)
        p["wi"] = ini.param("wi", (d_model, d_ff), ("embed", "mlp"))
        p["bi"] = ini.param("bi", (d_ff,), ("mlp",), mode="zeros")
        p["bo"] = ini.param("bo", (d_model,), ("embed",), mode="zeros")
    p["wo"] = ini.param("wo", (d_ff, d_model), ("mlp", "embed"))
    return p


def apply_mlp(p, x, mlp_type: str):
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    elif mlp_type == "geglu":
        h = jax.nn.gelu(x @ p["wi_gate"], approximate=True) * (x @ p["wi_up"])
    else:
        h = jax.nn.gelu(x @ p["wi"] + p["bi"].astype(x.dtype), approximate=True)
    h = shard_act(h, ("batch", "seq", "mlp"))
    out = h @ p["wo"]
    if "bo" in p:
        out = out + p["bo"].astype(x.dtype)
    return out


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_base: float = 10000.0
    causal: bool = True


def init_attention(ini, a: AttnDims):
    p = {
        "wq": ini.param("wq", (a.d_model, a.n_heads, a.head_dim),
                        ("embed", "heads", "head_dim")),
        "wk": ini.param("wk", (a.d_model, a.n_kv_heads, a.head_dim),
                        ("embed", "kv_heads", "head_dim")),
        "wv": ini.param("wv", (a.d_model, a.n_kv_heads, a.head_dim),
                        ("embed", "kv_heads", "head_dim")),
        "wo": ini.param("wo", (a.n_heads, a.head_dim, a.d_model),
                        ("heads", "head_dim", "embed")),
    }
    if a.qkv_bias:
        p["bq"] = ini.param("bq", (a.n_heads, a.head_dim), ("heads", "head_dim"), mode="zeros")
        p["bk"] = ini.param("bk", (a.n_kv_heads, a.head_dim), ("kv_heads", "head_dim"), mode="zeros")
        p["bv"] = ini.param("bv", (a.n_kv_heads, a.head_dim), ("kv_heads", "head_dim"), mode="zeros")
    return p


def _qkv(p, a: AttnDims, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if a.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if positions is not None:
        cos, sin = rope_cos_sin(positions, a.head_dim, a.rope_base)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _sdpa_direct(q, k, v, a: AttnDims, mask=None):
    """q: (B,S,H,D)  k/v: (B,T,Hkv,D); grouped heads; f32 softmax."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, d)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(d).astype(jnp.float32)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", w.astype(v.dtype), v)
    return out.reshape(b, s, h, d)


CHUNK_THRESHOLD = 2048   # S·T above which the online-softmax path kicks in
Q_CHUNK = 512
K_CHUNK = 1024


def _sdpa_chunked(q, k, v, a: AttnDims, causal: bool,
                  q_chunk: int = Q_CHUNK, k_chunk: int = K_CHUNK):
    """Flash-attention-style online softmax over KV blocks.

    Never materializes the (S, T) score matrix — the memory-roofline fix for
    train_4k/prefill_32k cells (EXPERIMENTS.md §Perf iteration M1). Causal
    blocks strictly above the diagonal are skipped (halves the FLOPs).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    qc = min(q_chunk, s)
    while s % qc:
        qc //= 2
    kc = min(k_chunk, t)
    while t % kc:
        kc //= 2
    nq, nk = s // qc, t // kc
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qg = q.reshape(b, nq, qc, hkv, g, d)
    kb = k.reshape(b, nk, kc, hkv, d)
    dv = v.shape[-1]
    vb = v.reshape(b, nk, kc, hkv, dv)

    def q_block(qi):
        qblk = qg[:, qi]                                     # (b,qc,hkv,g,d)
        m0 = jnp.full((b, hkv, g, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        o0 = jnp.zeros((b, hkv, g, qc, dv), jnp.float32)

        def kv_step(carry, ki):
            m, den, o = carry
            lg = jnp.einsum("bqhgd,bkhd->bhgqk", qblk,
                            kb[:, ki]).astype(jnp.float32) * scale
            if causal:
                qpos = qi * qc + jnp.arange(qc)
                kpos = ki * kc + jnp.arange(kc)
                lg = jnp.where(kpos[None, :] <= qpos[:, None], lg, -1e30)
            m2 = jnp.maximum(m, jnp.max(lg, axis=-1))
            alpha = jnp.exp(m - m2)
            w = jnp.exp(lg - m2[..., None])
            den2 = den * alpha + jnp.sum(w, axis=-1)
            o2 = o * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", w, vb[:, ki].astype(jnp.float32))
            return (m2, den2, o2), None

        if causal:
            # static upper bound on useful kv blocks for this q block
            hi = ((qi + 1) * qc + kc - 1) // kc
            hi = min(hi, nk)
            ks = jnp.arange(hi)
        else:
            ks = jnp.arange(nk)
        # remat the step so backward recomputes the exp-weights instead of
        # saving a (qc, kc) tensor per kv block (§Perf iteration M2)
        (m, den, o), _ = lax.scan(jax.checkpoint(kv_step), (m0, l0, o0), ks)
        ob = o / jnp.maximum(den[..., None], 1e-30)
        return ob                                           # (b,hkv,g,qc,d)

    outs = [q_block(qi) for qi in range(nq)]                # unrolled over q
    out = jnp.stack(outs, axis=3)                           # (b,hkv,g,nq,qc,dv)
    out = out.transpose(0, 3, 4, 1, 2, 5).reshape(b, s, h, dv)
    return out.astype(q.dtype)


def _sdpa(q, k, v, a: AttnDims, mask=None, *, causal_hint=None):
    s, t = q.shape[1], k.shape[1]
    if causal_hint is not None and s > 1 and s * t > CHUNK_THRESHOLD ** 2:
        return _sdpa_chunked(q, k, v, a, causal=causal_hint)
    return _sdpa_direct(q, k, v, a, mask)


def apply_attention(p, a: AttnDims, x, positions):
    """Full self-attention for train / prefill; returns (out, (k, v))."""
    q, k, v = _qkv(p, a, x, positions)
    s, t = q.shape[1], k.shape[1]
    if s > 1 and s * t > CHUNK_THRESHOLD ** 2:
        o = _sdpa_chunked(q, k, v, a, causal=a.causal)
    else:
        mask = None
        if a.causal:
            mask = (jnp.arange(t)[None, :] <= jnp.arange(s)[:, None])[None, None, None]
        o = _sdpa_direct(q, k, v, a, mask)
    o = shard_act(o, ("batch", "seq", "heads", "head_dim"))
    return jnp.einsum("bshd,hdm->bsm", o, p["wo"]), (k, v)


def apply_cross_attention(p, a: AttnDims, x, k, v):
    """Cross-attention (whisper decoder): kv precomputed from the encoder."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    s, t = q.shape[1], k.shape[1]
    if s > 1 and s * t > CHUNK_THRESHOLD ** 2:
        o = _sdpa_chunked(q, k, v, a, causal=False)
    else:
        o = _sdpa_direct(q, k, v, a, mask=None)
    return jnp.einsum("bshd,hdm->bsm", o, p["wo"])


def apply_attention_decode(p, a: AttnDims, x, cache_k, cache_v, cache_len,
                           positions):
    """One-token decode against a (B, T_max, Hkv, D) cache.

    Returns (out, new_k_entry, new_v_entry) — the caller owns the cache
    update (so scan-stacked caches update in one place)."""
    q, k, v = _qkv(p, a, x, positions)  # s == 1
    t = cache_k.shape[1]
    ck = lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), cache_len, axis=1)
    cv = lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), cache_len, axis=1)
    valid = (jnp.arange(t)[None, :] <= cache_len)[None, None, None]  # (1,1,1,1,T)->broadcast
    o = _sdpa(q, ck, cv, a, mask=valid)
    return jnp.einsum("bshd,hdm->bsm", o, p["wo"]), ck, cv


def decode_attention_seqsharded(q, k_shard, v_shard, local_valid, axis_name):
    """Flash-decoding across a mesh axis: KV time-sharded, LSE-combined.

    q: (B,1,H,D) replicated; k/v_shard: (B,T_loc,Hkv,D) this rank's slab;
    local_valid: (B, T_loc) bool. Runs inside shard_map; psum/pmax over
    ``axis_name``. This is the long_500k path (batch=1 cannot shard the
    batch axis, so the *sequence* becomes the pencil).
    """
    b, s, h, d = q.shape
    hkv = k_shard.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, d)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k_shard).astype(jnp.float32)
    logits = logits / jnp.sqrt(d).astype(jnp.float32)
    neg = jnp.asarray(-1e30, jnp.float32)
    logits = jnp.where(local_valid[:, None, None, None, :], logits, neg)
    m_loc = jnp.max(logits, axis=-1)                                 # (b,h,g,s)
    m_glob = lax.pmax(m_loc, axis_name)
    w = jnp.exp(logits - m_glob[..., None])
    l_loc = jnp.sum(w, axis=-1)
    o_loc = jnp.einsum("bhgst,bthd->bshgd", w.astype(v_shard.dtype), v_shard)
    l_glob = lax.psum(l_loc, axis_name)                              # (b,h,g,s)
    o_glob = lax.psum(o_loc.astype(jnp.float32), axis_name)
    lg = jnp.transpose(l_glob, (0, 3, 1, 2))[..., None]              # (b,s,h,g,1)
    out = (o_glob / jnp.maximum(lg, 1e-30)).astype(q.dtype)
    return out.reshape(b, s, h, d)
