"""Counters/gauges registry for wire-traffic and cache accounting.

Flat dot-separated string keys (``comm.exchange_rounds.data``,
``plan_cache.hits``), integer/float values. Counters accumulate with
:meth:`Metrics.inc`; gauges overwrite with :meth:`Metrics.set_gauge`.

The comm-layer counters fire at **trace time** (inside jit tracing of the
shard_map bodies), so they count once per *compilation*, from one rank's
SPMD perspective — the analytically checkable quantities (rounds per
exchange, bytes per rank per fold), not a per-execution wire tap. See the
README's jit-visibility notes.

Disabled, ``inc``/``set_gauge`` return before touching the lock or the
dict — instrumentation left in hot paths costs one branch.
"""

from __future__ import annotations

import threading


from repro.obs import _state


class Metrics:
    """Thread-safe counters + gauges, cheap when disabled."""

    def __init__(self):
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._lock = threading.Lock()

    # ---- writers (no-ops while disabled) ---------------------------------
    def inc(self, name: str, value: float = 1) -> None:
        if not _state.is_enabled():
            return
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def set_gauge(self, name: str, value: float) -> None:
        if not _state.is_enabled():
            return
        with self._lock:
            self._gauges[name] = value

    # ---- readers (always available) --------------------------------------
    def get(self, name: str, default: float = 0) -> float:
        with self._lock:
            if name in self._counters:
                return self._counters[name]
            return self._gauges.get(name, default)

    def counters(self) -> dict[str, float]:
        with self._lock:
            return dict(self._counters)

    def gauges(self) -> dict[str, float]:
        with self._lock:
            return dict(self._gauges)

    def snapshot(self) -> dict:
        """``{"counters": {...}, "gauges": {...}}`` for exporters."""
        return {"counters": self.counters(), "gauges": self.gauges()}

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
