"""Shared on/off switch of the observability layer.

One module-level flag, imported by ``obs.tracer`` and ``obs.metrics`` alike
(keeping it here avoids a tracer <-> metrics import cycle). The flag is the
zero-overhead-when-disabled contract: every instrumented call site checks it
*before* allocating attributes, formatting counter keys, or taking a lock,
so a disabled tracer costs one predicted branch per dispatch and nothing at
all per executed collective (wire metrics fire at trace time only).
"""

from __future__ import annotations

import threading

#: guards the enable/disable transitions (readers go lock-free: a stale read
#: during a transition only means one span more or less, never corruption)
lock = threading.RLock()

_enabled = False


def is_enabled() -> bool:
    """Whether tracing/metrics collection is currently on."""
    return _enabled


def set_enabled(value: bool) -> None:
    global _enabled
    with lock:
        _enabled = bool(value)
