"""``repro.obs`` — zero-overhead-when-disabled tracing and metrics.

The observability layer of the stack: a thread-safe span :class:`Tracer`
(nested ``with obs.span(name, **attrs)`` contexts, Chrome-trace-event
export for Perfetto), a counters/gauges :class:`Metrics` registry (wire
bytes, ppermute/all-to-all dispatches, per-axis exchange rounds, plan-cache
hits/misses, retraces), and the :func:`traced_call` dispatch-boundary
wrapper. Instrumentation lives in ``core.transpose``/``core.comm`` (wire
metrics at trace time), ``core.fft3d`` (phase spans with perf-model
predictions), ``solvers.base`` (step/observable spans) and ``repro.tuning``
(sweep spans, cache counters).

Disabled — the default — every entry point returns before allocating:
``span()`` hands back a shared no-op singleton, ``metrics.inc`` is one
branch, ``traced_call`` wrappers tail-call straight through. Enable with
:func:`enable` (the CLIs' ``--trace PATH`` flags do), export with
:func:`write_chrome_trace` / :func:`summary_table`.

Import of this package is jax-free; jax is only touched inside an enabled
``traced_call`` (to block on dispatched results).

What jit lets us see: **spans cannot live inside jitted shard_map
bodies** — a ``with`` block there times Python *tracing*, which runs once
per compilation. The span layer therefore wraps dispatch boundaries
(``dispatch/...`` spans, blocking on results), while inside-jit structure
is captured as trace-time metrics and ``trace/...`` spans annotated with
the perf model's per-phase predictions. See README "Observability".
"""

from __future__ import annotations

from repro.obs import _state
from repro.obs.export import (chrome_trace, summary_table,
                              validate_chrome_trace, write_chrome_trace)
from repro.obs.metrics import Metrics
from repro.obs.tracer import NULL_SPAN, Span, TracedCallable, Tracer

__all__ = [
    "Tracer", "Span", "TracedCallable", "Metrics", "NULL_SPAN",
    "tracer", "metrics", "span", "traced_call",
    "enable", "disable", "is_enabled", "clear", "capture",
    "chrome_trace", "write_chrome_trace", "validate_chrome_trace",
    "summary_table",
]

#: process-wide default instances every instrumented module shares
tracer = Tracer()
metrics = Metrics()

is_enabled = _state.is_enabled


def enable() -> None:
    """Turn span/metric collection on (process-wide)."""
    _state.set_enabled(True)


def disable() -> None:
    """Turn collection off; recorded spans/counters stay readable."""
    _state.set_enabled(False)


def clear() -> None:
    """Drop all recorded spans and counters."""
    tracer.clear()
    metrics.clear()


def span(name: str, /, **attrs):
    """``with obs.span("dispatch/fft3d.fwd", engine="torus"):`` on the
    default tracer. Returns the shared no-op singleton while disabled —
    guard ``**attrs`` construction behind :func:`is_enabled` on hot paths,
    since keyword packing allocates before the call."""
    return tracer.span(name, **attrs)


def traced_call(fn, name: str, attrs: dict | None = None) -> TracedCallable:
    """Wrap ``fn`` so every call is a ``dispatch/...`` span that blocks on
    the result (accurate wall time under async dispatch). Attributes are
    fixed at wrap time; jit surfaces (``.lower`` etc.) forward through."""
    return TracedCallable(fn, name, tracer, attrs)


class capture:
    """``with obs.capture() as (tracer, metrics):`` — enable + clear on
    entry, disable on exit (events stay readable). Test/tooling helper."""

    def __enter__(self):
        clear()
        enable()
        return tracer, metrics

    def __exit__(self, *exc):
        disable()
        return False
