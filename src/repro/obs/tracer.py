"""Thread-safe span tracer with nesting, for Chrome-trace-event export.

A :class:`Tracer` records *spans* — named wall-clock intervals with
key/value attributes — via the ``with tracer.span(name, **attrs):`` context
manager. Spans nest per thread (each records its parent's name and depth),
and the recorded events serialize straight into the Chrome trace event
format (``obs.export.chrome_trace``) that Perfetto / chrome://tracing load.

Two span flavors by naming convention (see README "Observability"):

* ``dispatch/...`` — wall time at a jit dispatch boundary. Accurate only if
  the span blocks on the dispatched work before closing;
  :func:`traced_call` does exactly that.
* ``trace/...`` — Python *tracing* time inside a jitted function body.
  These fire once per compilation, not per execution: they show the comm
  DAG's structure and the perf model's per-phase predictions, not runtime.

Disabled (the default), ``tracer.span(name)`` returns a module-level no-op
singleton — no event, no allocation, no lock.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable

from repro.obs import _state


def _now_us() -> float:
    return time.perf_counter() * 1e6


class _NullSpan:
    """The shared disabled-path span: enter/exit do nothing, allocate
    nothing. ``set_attr`` is accepted and dropped so call sites need no
    enabled-check of their own around attribute updates."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set_attr(self, **attrs) -> None:
        pass


NULL_SPAN = _NullSpan()


class Span:
    """One live span (context manager). Created only when tracing is on."""

    __slots__ = ("tracer", "name", "attrs", "t0_us", "tid", "parent", "depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.t0_us = 0.0
        self.tid = 0
        self.parent = ""
        self.depth = 0

    def set_attr(self, **attrs) -> None:
        """Attach/overwrite attributes while the span is open (e.g. a
        result computed inside the ``with`` block)."""
        self.attrs.update(attrs)

    def __enter__(self):
        stack = self.tracer._stack()
        self.parent = stack[-1].name if stack else ""
        self.depth = len(stack)
        self.tid = threading.get_ident()
        stack.append(self)
        self.t0_us = _now_us()
        return self

    def __exit__(self, *exc):
        dur = _now_us() - self.t0_us
        stack = self.tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self.tracer._record({
            "name": self.name, "ts": self.t0_us, "dur": dur,
            "tid": self.tid, "parent": self.parent, "depth": self.depth,
            "args": self.attrs,
        })
        return False


class Tracer:
    """Collects span events; thread-safe; cheap when disabled."""

    def __init__(self):
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._local = threading.local()

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, event: dict) -> None:
        with self._lock:
            self._events.append(event)

    def span(self, name: str, /, **attrs):
        """Context manager timing one named interval. Returns the shared
        no-op singleton when tracing is disabled (zero allocation as long
        as the caller passes no ``**attrs`` — guard attribute construction
        behind ``obs.is_enabled()`` on hot paths)."""
        if not _state.is_enabled():
            return NULL_SPAN
        return Span(self, name, attrs)

    def events(self) -> list[dict]:
        """Snapshot of the recorded span events (closed spans only)."""
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()


class TracedCallable:
    """A callable wrapped in a ``dispatch/...`` span that blocks on the
    result before the span closes — without that block, an async jit
    dispatch returns immediately and the span would time only the Python
    dispatch overhead. Disabled, the wrapper is one branch and a tail call.

    Attribute access forwards to the wrapped function, so jit surfaces
    (``.lower``, ``.trace``, ...) keep working on the wrapped object.
    """

    def __init__(self, fn: Callable, name: str, tracer: "Tracer",
                 attrs: dict | None = None):
        self._fn = fn
        self._name = name
        self._tracer = tracer
        self._attrs = dict(attrs or {})

    def __call__(self, *args, **kwargs) -> Any:
        if not _state.is_enabled():
            return self._fn(*args, **kwargs)
        import jax  # deferred: repro.obs stays importable without jax

        with self._tracer.span(self._name, **self._attrs):
            out = self._fn(*args, **kwargs)
            jax.block_until_ready(out)
        return out

    def __getattr__(self, item):
        return getattr(self._fn, item)

    def __repr__(self):
        return f"TracedCallable({self._name!r}, {self._fn!r})"
