"""Exporters: Chrome-trace-event JSON (Perfetto-loadable) + summary table.

The JSON document follows the Chrome trace event format's "X" (complete)
events — ``name``/``ph``/``ts``/``dur``/``pid``/``tid``/``args`` under a
top-level ``traceEvents`` list — which https://ui.perfetto.dev and
``chrome://tracing`` both open directly. Extra top-level keys (our
``metrics`` snapshot and ``meta``) are tolerated by both viewers.
"""

from __future__ import annotations

import json
import os


def chrome_trace(tracer, metrics=None, meta: dict | None = None) -> dict:
    """Render recorded spans (+ the metrics snapshot) as one Chrome-trace
    document. Span attributes become the event's ``args``; the recorded
    parent/depth ride along in ``args`` too (Perfetto nests same-tid "X"
    events by time containment on its own)."""
    pid = os.getpid()
    events = []
    for ev in tracer.events():
        args = dict(ev.get("args") or {})
        if ev.get("parent"):
            args["parent"] = ev["parent"]
        events.append({
            "name": ev["name"], "ph": "X", "cat": ev["name"].split("/")[0],
            "ts": round(ev["ts"], 3), "dur": round(ev["dur"], 3),
            "pid": pid, "tid": ev["tid"], "args": args,
        })
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if metrics is not None:
        doc["metrics"] = metrics.snapshot()
    if meta:
        doc["meta"] = dict(meta)
    return doc


def write_chrome_trace(path: str, tracer, metrics=None,
                       meta: dict | None = None) -> None:
    """Atomically write the Chrome-trace JSON document to ``path``."""
    doc = chrome_trace(tracer, metrics, meta)
    tmp = path + f".tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)


def validate_chrome_trace(doc: dict) -> list[str]:
    """Schema check of a Chrome-trace document; returns problem strings
    (empty = valid). Used by the obs tests and the CI trace-smoke step."""
    problems = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return [f"traceEvents is {type(events).__name__}, want list"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i} is not an object")
            continue
        for key, types in (("name", str), ("ph", str),
                           ("ts", (int, float)), ("dur", (int, float)),
                           ("pid", int), ("tid", int)):
            if not isinstance(ev.get(key), types):
                problems.append(f"event {i} ({ev.get('name')!r}): bad {key}")
        if ev.get("ph") != "X":
            problems.append(f"event {i}: ph={ev.get('ph')!r}, want 'X'")
        if "args" in ev and not isinstance(ev["args"], dict):
            problems.append(f"event {i}: args is not an object")
    return problems


def summary_table(tracer, metrics=None) -> str:
    """Human-readable per-span-name aggregation + the counters, as text."""
    agg: dict[str, list[float]] = {}
    for ev in tracer.events():
        agg.setdefault(ev["name"], []).append(ev["dur"])
    lines = []
    if agg:
        width = max(len(n) for n in agg)
        lines.append(f"{'span':<{width}}  {'count':>5}  {'total_us':>12}  "
                     f"{'mean_us':>12}  {'max_us':>12}")
        for name in sorted(agg):
            durs = agg[name]
            lines.append(f"{name:<{width}}  {len(durs):>5}  "
                         f"{sum(durs):>12.1f}  "
                         f"{sum(durs) / len(durs):>12.1f}  "
                         f"{max(durs):>12.1f}")
    if metrics is not None:
        counters = metrics.counters()
        if counters:
            if lines:
                lines.append("")
            width = max(len(n) for n in counters)
            for name in sorted(counters):
                lines.append(f"{name:<{width}}  {counters[name]:>14g}")
        for name, value in sorted(metrics.gauges().items()):
            lines.append(f"{name} = {value:g}")
    return "\n".join(lines) if lines else "(no spans or counters recorded)"
