"""Training driver with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --batch 8 --seq 512 --mesh 1x1 --ckpt-dir /tmp/ckpt

Multi-host TPU pods: run the same command per host after
``jax.distributed.initialize()`` (see launch/scripts/). Resume is automatic:
if the checkpoint dir has a LATEST pointer, training continues from it —
kill -9 at any step and relaunch to verify (tests/test_checkpoint.py does).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.distributed import sharding as sh
from repro.launch.mesh import make_dev_mesh, mesh_axes
from repro.models import common as cm
from repro.models.transformer import RunCfg, init_model
from repro.optim import adamw
from repro.training.train_loop import TrainCfg, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL (e.g. 4x2)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--halt-after", type=int, default=0,
                    help="simulate a crash: exit after N steps (schedule and "
                         "data are still configured for --steps)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    dm, mm = (int(v) for v in args.mesh.split("x"))
    mesh = make_dev_mesh(data=dm, model=mm)
    data_axes, model_axes = mesh_axes(mesh)
    run = RunCfg(mesh=mesh, data_axes=data_axes, model_axes=model_axes,
                 remat=cfg.remat)
    cm.set_activation_rules({"batch": "data", "heads": "model", "mlp": "model",
                             "experts": "model", "kv_heads": "model"})

    params, axes = init_model(cfg, jax.random.PRNGKey(0))
    shapes = jax.eval_shape(lambda p: p, params)
    shardings = sh.tree_shardings(mesh, axes, shapes)
    params = jax.device_put(params, shardings)
    acfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                             warmup_steps=max(args.steps // 20, 5),
                             moment_dtype=cfg.opt_state_dtype)
    tcfg = TrainCfg(microbatches=args.microbatches, adamw=acfg,
                    grad_compression=args.grad_compression)
    opt_state = adamw.init(acfg, params)

    start = 0
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and ckpt.latest_step() is not None:
        (params, opt_state), meta = ckpt.restore(
            (params, opt_state),
            shardings=(shardings, jax.tree.map(lambda _: None, opt_state)))
        start = meta["step"] + 1
        print(f"[resume] from step {meta['step']}")

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch, kind=(
                          "embeds" if cfg.embed_mode == "embeds" else
                          "frames" if cfg.embed_mode == "frames" else "tokens"),
                      d_model=cfg.d_model)
    pipe = Pipeline(dcfg)
    step_fn = jax.jit(make_train_step(cfg, run, tcfg), donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    with compat.set_mesh(mesh):
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in pipe.batch_for_step(step).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"({(time.time() - t0):.1f}s)", flush=True)
            if ckpt and (step % args.ckpt_every == 0 or step == args.steps - 1):
                ckpt.save(step, (params, opt_state), meta={"arch": args.arch})
            if args.halt_after and step + 1 >= args.halt_after:
                if ckpt:
                    ckpt.wait()
                print(f"[halt] simulated crash after step {step}")
                return losses
    if ckpt:
        ckpt.wait()
    print(f"[done] first loss {losses[0]:.4f} last loss {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
