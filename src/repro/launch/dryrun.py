from repro.launch.mesh import ensure_host_devices

ensure_host_devices(512)

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory/cost/collective analysis for §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --multi-pod

Each cell writes benchmarks/out/dryrun/<arch>__<shape>__<mesh>.json
incrementally, so an interrupted sweep resumes with --skip-existing.
"""

import argparse
import json
import os
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import REGISTRY, SHAPES, get_config, shape_applicable
from repro.configs.base import count_active_params, count_params
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.launch import specs as SP
from repro.models import common as cm
from repro.models.transformer import RunCfg, decode_step, prefill
from repro.optim import adamw
from repro.training.train_loop import TrainCfg, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "out", "dryrun")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "c64": 8, "c128": 16,
               "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|c64|c128|s64|u64|s32|u32|s16|u16|"
                       r"s8|u8|pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op (start ops counted once;
    matching -done ops carry no payload of their own)."""
    per_op: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes, op = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(shapes):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        per_op[op] = per_op.get(op, 0) + nbytes
        per_op[op + "_count"] = per_op.get(op + "_count", 0) + 1
    per_op["total"] = sum(v for k, v in per_op.items()
                          if not k.endswith("_count"))
    return per_op


def build_cell(arch: str, shape_name: str, mesh, run_over=None):
    """Returns (fn, arg_structs) ready to lower for this cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    data_axes, model_axes = mesh_axes(mesh)
    rules = sh.PARAM_RULES
    act_rules = dict(sh.ACT_RULES)
    if "pod" in mesh.shape:
        act_rules = sh.multipod_rules(act_rules)
    cm.set_activation_rules({k: (v if v is None else
                                 (v if len(v) > 1 else v[0]))
                             for k, v in act_rules.items()})
    seq_shard = shape.name == "long_500k"
    run = RunCfg(mesh=mesh, data_axes=data_axes, model_axes=model_axes,
                 seq_shard_kv=seq_shard, remat=cfg.remat)
    if run_over:
        run = run_over(run)

    params_sds, axes, param_sh = SP.param_structs(cfg, mesh)

    if shape.kind == "train":
        tcfg = TrainCfg(microbatches=cfg.train_microbatches,
                        adamw=adamw.AdamWConfig(moment_dtype=cfg.opt_state_dtype))
        step = make_train_step(cfg, run, tcfg)
        opt_sds = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.dtype(cfg.opt_state_dtype), sharding=s.sharding),
            params_sds)
        opt = {"m": opt_sds, "v": opt_sds,
               "count": jax.ShapeDtypeStruct((), jnp.int32)}
        batch = SP.batch_specs(cfg, shape, mesh)
        fn = jax.jit(step, donate_argnums=(0, 1))
        return fn, (params_sds, opt, batch)
    if shape.kind == "prefill":
        batch = SP.batch_specs(cfg, shape, mesh)
        pf = lambda p, b: prefill(cfg, run, p, b)
        # constrain the returned cache's shardings (otherwise XLA replicates
        # the multi-GiB KV stacks: qwen1.5 prefill_32k +21 GiB observed)
        out_shapes = jax.eval_shape(pf, params_sds, batch)
        cache_sh = sh.cache_specs(mesh, out_shapes[1], cfg)
        from jax.sharding import NamedSharding
        logits_sh = NamedSharding(mesh, sh.batch_spec(mesh, 3))
        fn = jax.jit(pf, out_shardings=(logits_sh, cache_sh))
        return fn, (params_sds, batch)
    # decode
    cache, tok = SP.decode_specs(cfg, shape, mesh, seq_shard=seq_shard)
    fn = jax.jit(lambda p, c, t: decode_step(cfg, run, p, c, t),
                 donate_argnums=(1,))
    return fn, (params_sds, cache, tok)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             keep_hlo: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": 512 if multi_pod else 256}
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, SHAPES[shape_name])
    if not ok:
        rec["status"] = why
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        with compat.set_mesh(mesh):
            fn, args = build_cell(arch, shape_name, mesh)
            lowered = fn.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_per_device_bytes": int(ma.argument_size_in_bytes
                                         + ma.output_size_in_bytes
                                         + ma.temp_size_in_bytes
                                         - ma.alias_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
        txt = compiled.as_text()
        rec["collectives"] = collective_bytes(txt)
        from repro.launch.hlo_cost import analyze_hlo
        walk = analyze_hlo(txt)
        rec["cost_tripaware"] = {"flops": walk["flops"],
                                 "bytes_accessed": walk["bytes"],
                                 "collectives": walk["collectives"]}
        rec["model_params"] = count_params(cfg)
        rec["model_params_active"] = count_active_params(cfg)
        rec["status"] = "ok"
        if keep_hlo:
            with open(os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.hlo"),
                      "w") as f:
                f.write(txt)
    except Exception as e:  # record failures; the sweep continues
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def run_fft_cell(n: int, multi_pod: bool, out_dir: str, *,
                 schedule: str = "pipelined", chunks: int = 4,
                 net: str = "switched", comm_engine: str = "",
                 r2c_packed: bool = False,
                 backend: str = "jnp", tag: str = "") -> dict:
    """Dry-run the paper's own workload: N³ real 3D FFT on the production
    mesh (pencil grid = (pod·data, model))."""
    import math as _math

    from repro.core.engine_spec import EngineSpec
    from repro.core.fft3d import make_fft3d

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": f"fft{n}{tag}", "shape": f"{schedule}_{comm_engine or net}"
           + ("_packed" if r2c_packed else ""),
           "mesh": mesh_name, "chips": 512 if multi_pod else 256}
    mesh = make_production_mesh(multi_pod=multi_pod)
    u_axes = ("pod", "data") if multi_pod else ("data",)
    t0 = time.time()
    try:
        with compat.set_mesh(mesh):
            spec = EngineSpec(engine=comm_engine or net, backend=backend,
                              schedule=schedule, chunks=chunks, real=True,
                              r2c_packed=r2c_packed)
            fwd, inv, plan = make_fft3d(
                mesh, (n, n, n), u_axes=u_axes, v_axes=("model",), spec=spec)
            x = jax.ShapeDtypeStruct(
                (n, n, n), jnp.float32,
                sharding=plan.grid.sharding(mesh))
            lowered = fwd.lower(x)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_per_device_bytes": int(ma.argument_size_in_bytes
                                         + ma.output_size_in_bytes
                                         + ma.temp_size_in_bytes
                                         - ma.alias_size_in_bytes)}
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
        txt = compiled.as_text()
        rec["collectives"] = collective_bytes(txt)
        from repro.launch.hlo_cost import analyze_hlo
        walk = analyze_hlo(txt)
        rec["cost_tripaware"] = {"flops": walk["flops"],
                                 "bytes_accessed": walk["bytes"],
                                 "collectives": walk["collectives"]}
        # "model params" stand-in: the transform size; model flops = 5N³log2 N³
        rec["model_params"] = n ** 3
        rec["model_params_active"] = n ** 3
        rec["fft_model_flops_total"] = 5.0 * n ** 3 * _math.log2(float(n) ** 3)
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--fft", action="store_true", help="paper FFT cells")
    ap.add_argument("--fft-n", type=int, default=0)
    ap.add_argument("--fft-schedule", default="pipelined")
    ap.add_argument("--fft-net", default="switched")
    ap.add_argument("--fft-engine", default="",
                    help="TransposeEngine (switched/torus/overlap_ring); "
                         "empty = the engine named by --fft-net")
    ap.add_argument("--fft-chunks", type=int, default=4)
    ap.add_argument("--fft-packed", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.fft or args.fft_n:
        sizes = [args.fft_n] if args.fft_n else [512, 1024, 2048, 4096]
        meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]
        for n in sizes:
            for mp in meshes:
                rec = run_fft_cell(n, mp, args.out,
                                   schedule=args.fft_schedule,
                                   chunks=args.fft_chunks, net=args.fft_net,
                                   comm_engine=args.fft_engine,
                                   r2c_packed=args.fft_packed)
                path = os.path.join(
                    args.out, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[fft] N={n} {rec['mesh']} {rec['shape']} -> {rec['status']}"
                      + (f" compile={rec.get('compile_s')}s" if rec["status"] == "ok"
                         else f" {rec.get('error', '')[:150]}"), flush=True)
        return

    archs = list(REGISTRY) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {arch} {shape} {mesh_name}")
                    continue
                print(f"[cell] {arch} {shape} {mesh_name} ...", flush=True)
                rec = run_cell(arch, shape, mp, args.out, keep_hlo=args.keep_hlo)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"    -> {rec['status']}"
                      + (f" compile={rec.get('compile_s')}s"
                         f" peak={rec.get('memory', {}).get('peak_per_device_bytes', 0)/2**30:.2f}GiB"
                         if rec["status"] == "ok" else
                         f" {rec.get('error', '')[:200]}"), flush=True)


if __name__ == "__main__":
    main()
