"""Launchers: mesh setup, dry-run planning, HLO cost inspection, serving."""
