"""ShapeDtypeStruct stand-ins for every (arch × shape) cell — weak-type
correct, shardable, no device allocation (the dry-run input contract)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCfg
from repro.distributed import sharding as sh
from repro.models import transformer as tf


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_specs(cfg: ArchConfig, shape: ShapeCfg, mesh) -> dict:
    """Train/prefill batch ShapeDtypeStructs with batch-axis sharding."""
    b, s = shape.global_batch, shape.seq_len
    bs = NamedSharding(mesh, sh.batch_spec(mesh, 2))
    bs3 = NamedSharding(mesh, sh.batch_spec(mesh, 3))
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.embed_mode == "embeds":
        return {"embeds": _sds((b, s, cfg.d_model), cd, bs3),
                "labels": _sds((b, s), jnp.int32, bs)}
    if cfg.embed_mode == "frames":
        # decoder length capped for enc-dec training (audio: enc dominates)
        return {"frames": _sds((b, s, cfg.d_model), cd, bs3),
                "tokens": _sds((b, min(s, 4096)), jnp.int32, bs)}
    return {"tokens": _sds((b, s), jnp.int32, bs)}


def decode_specs(cfg: ArchConfig, shape: ShapeCfg, mesh, *,
                 seq_shard: bool = False) -> tuple[dict, object]:
    """(cache ShapeDtypeStructs, token struct) for a decode cell: one new
    token against a KV cache of seq_len."""
    b, t = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(
        lambda: tf.init_cache(cfg, b, t, t_enc=min(t, 8192) if cfg.encdec else 0))
    shardings = sh.cache_specs(mesh, cache_shapes, cfg, seq_shard=seq_shard)
    cache = {k: _sds(v.shape, v.dtype, shardings[k])
             for k, v in cache_shapes.items()}
    bs = NamedSharding(mesh, sh.batch_spec(mesh, 2) if not seq_shard
                       else P(None, None))
    tok = _sds((b, 1), jnp.int32, bs)
    return cache, tok


def param_structs(cfg: ArchConfig, mesh) -> tuple[dict, dict, object]:
    """(params SDS tree, axes tree, shardings tree) without materializing."""
    holder = {}

    def run(key):
        p, ax = tf.init_model(cfg, key)
        holder["axes"] = ax
        return p

    shapes = jax.eval_shape(run, jax.random.PRNGKey(0))
    axes = holder["axes"]
    shardings = sh.tree_shardings(mesh, axes, shapes)
    structs = jax.tree.map(lambda s, d: _sds(s.shape, s.dtype, d),
                           shapes, shardings)
    return structs, axes, shardings
