"""Production mesh builders.

Single pod: 16×16 = 256 chips (data, model). Multi-pod: 2×16×16 = 512 chips
(pod, data, model). The FFT pencil grid maps (Pu, Pv) = (data, model), or
((pod, data), model) multi-pod. Functions, not module constants — importing
this module never touches jax device state.
"""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh for tests/examples on N fake or real devices."""
    if pod:
        return compat.make_mesh((pod, data, model), ("pod", "data", "model"))
    return compat.make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """(data_axes incl. pod, model_axes) for a production-style mesh."""
    names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    return data_axes, ("model",)
