"""Production mesh builders and the fake host-device bootstrap.

Single pod: 16×16 = 256 chips (data, model). Multi-pod: 2×16×16 = 512 chips
(pod, data, model). The FFT pencil grid maps (Pu, Pv) = (data, model), or
((pod, data), model) multi-pod. Functions, not module constants — importing
this module never touches jax device state.
"""

from __future__ import annotations

import os

from repro import compat

_FORCE_FLAG = "xla_force_host_platform_device_count"


def parse_mesh_arg(text: str) -> tuple[int, int]:
    """Parse a CLI ``--mesh PUxPV`` string (e.g. ``4x2``) into ``(pu, pv)``.

    Shared by the tuning and solver CLIs; raises ``SystemExit`` with a
    usage message on malformed input.
    """
    try:
        pu, pv = (int(t) for t in text.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh must look like 4x2, got {text!r}")
    return pu, pv


def ensure_host_devices(n: int) -> None:
    """Make the CPU backend expose ``n`` fake devices (idempotent).

    The one shared implementation of the ``XLA_FLAGS`` dance every example,
    test subprocess, and CLI used to copy-paste. Must run before the XLA
    backend initializes (i.e. before the first ``jax.devices()``-like call;
    merely importing jax is fine); an existing
    ``--xla_force_host_platform_device_count`` in the environment wins, so
    CI/outer drivers can pin their own count.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if _FORCE_FLAG in flags:
        return
    os.environ["XLA_FLAGS"] = f"--{_FORCE_FLAG}={int(n)} {flags}".rstrip()


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh for tests/examples on N fake or real devices."""
    if pod:
        return compat.make_mesh((pod, data, model), ("pod", "data", "model"))
    return compat.make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """(data_axes incl. pod, model_axes) for a production-style mesh."""
    names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    return data_axes, ("model",)
