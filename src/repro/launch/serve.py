"""Serving drivers — the LM token path and the spectral-simulation path.

Two serving modes share this entry point:

* **LM serving** (the default): batched prefill + greedy decode with a KV
  cache over the ``repro.models`` transformer stack::

      PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \\
          --batch 4 --prompt-len 32 --gen 16

* **simulation serving** (``--sim``): every other argument is forwarded to
  ``repro.serving.cli`` — the batched spectral-solver server that groups
  same-fingerprint :class:`~repro.serving.request.SimRequest`\\ s into one
  sharded solver step and streams observables back (see ``docs/serving.md``)::

      PYTHONPATH=src python -m repro.launch.serve --sim --case heat --n 16 \\
          --mesh 4x2 --requests 8 --steps 3 --max-batch 4 \\
          --trace serve.trace.json
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    if "--sim" in argv:
        argv.remove("--sim")
        from repro.serving.cli import main as sim_main
        return sim_main(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.launch.mesh import make_dev_mesh, mesh_axes
    from repro.models.transformer import (RunCfg, decode_step, init_model,
                                          prefill)

    ap = argparse.ArgumentParser(
        prog="repro.launch.serve",
        description="LM serving driver (batched prefill + greedy decode); "
                    "--sim switches to the batched spectral-simulation "
                    "server (repro.serving.cli flags apply).")
    ap.add_argument("--sim", action="store_true",
                    help="serve spectral simulations instead of LM tokens "
                         "(remaining args go to repro.serving.cli)")
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    dm, mm = (int(v) for v in args.mesh.split("x"))
    mesh = make_dev_mesh(data=dm, model=mm)
    data_axes, model_axes = mesh_axes(mesh)
    run = RunCfg(mesh=mesh, data_axes=data_axes, model_axes=model_axes,
                 remat=False)

    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    b, s = args.batch, args.prompt_len
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)}
    if cfg.embed_mode == "frames":
        batch["frames"] = jnp.asarray(rng.randn(b, s, cfg.d_model), jnp.float32)

    t_max = s + args.gen
    pre = jax.jit(lambda p, bt: prefill(cfg, run, p, bt, t_max=t_max))
    dec = jax.jit(lambda p, c, t: decode_step(cfg, run, p, c, t),
                  donate_argnums=(1,))

    t0 = time.time()
    logits, cache = pre(params, batch)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    prefill_s = time.time() - t0
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = dec(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    print(f"prefill {s} tokens x{b}: {prefill_s*1e3:.1f} ms")
    print(f"decode  {args.gen - 1} steps: {dt*1e3:.1f} ms "
          f"({(args.gen - 1) * b / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", np.asarray(toks[0])[:16])
    return toks


if __name__ == "__main__":
    main()
