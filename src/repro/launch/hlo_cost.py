"""Trip-count-aware HLO cost model.

XLA:CPU's ``compiled.cost_analysis()`` counts while-loop bodies ONCE
(verified: a 10-step scan of a matmul reports 1 matmul of FLOPs), which
silently undercounts every scanned-layer model by ~L×. This walker parses
``compiled.as_text()`` and accumulates, with each while body's contribution
multiplied by its ``known_trip_count``:

* flops        — dot ops: 2 · |out| · K (K = contracted extent);
* bytes        — operands+results of ops at fusion boundaries (interior
                 fusion ops don't touch HBM — same model XLA uses);
* collectives  — result-shape bytes per collective op kind.

Fusion calls recurse for FLOPs (dots inside fusions) but not for bytes.
"""

from __future__ import annotations

import re

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "c64": 8, "c128": 16,
               "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE = re.compile(r"(f64|f32|bf16|f16|c64|c128|s64|u64|s32|u32|s16|u16|s8|u8|"
                    r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_HDR = re.compile(r"^(ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\)\s*->")
_DEF = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OP = re.compile(r"^((?:\([^)]*\)|\S)+)\s+([\w\-]+)\((.*)$")
_TRIP = re.compile(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)')
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_NO_BYTES = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "while", "conditional", "call", "custom-call", "after-all"}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _dims_of(text: str):
    m = _SHAPE.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


class Instr:
    __slots__ = ("name", "rtext", "op", "args", "line")

    def __init__(self, name, rtext, op, args, line):
        self.name, self.rtext, self.op, self.args, self.line = \
            name, rtext, op, args, line


def parse(txt: str):
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur = None
    for raw in txt.splitlines():
        ls = raw.strip()
        if ls.endswith("{"):
            m = _HDR.match(ls)
            if m:
                cur = m.group(2).lstrip("%")
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if ls.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        d = _DEF.match(ls)
        if not d:
            continue
        rest = d.group(2)
        o = _OP.match(rest)
        if not o:
            continue
        args = o.group(3)
        depth = 1
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = args[:i]
                    break
        comps[cur].append(Instr(d.group(1), o.group(1), o.group(2), args, ls))
    return comps, entry


def analyze_hlo(txt: str):
    comps, entry = parse(txt)
    shapes = {}
    for instrs in comps.values():
        for ins in instrs:
            shapes[ins.name] = ins.rtext

    memo_flops: dict[str, float] = {}
    memo_bytes: dict[str, float] = {}
    memo_coll: dict[str, dict] = {}

    def visit(comp: str):
        if comp in memo_flops:
            return memo_flops[comp], memo_bytes[comp], memo_coll[comp]
        flops = 0.0
        nbytes = 0.0
        coll: dict[str, float] = {}
        for ins in comps.get(comp, []):
            op = ins.op
            if op == "while":
                m = _TRIP.search(ins.line)
                trip = int(m.group(1)) if m else 1
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if bm and bm.group(1) in comps:
                    f, b, c = visit(bm.group(1))
                    flops += trip * f
                    nbytes += trip * b
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0) + trip * v
                continue
            if op == "fusion":
                cm = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                if cm and cm.group(1) in comps:
                    f, _, c = visit(cm.group(1))
                    flops += f
                    for k, v in c.items():
                        coll[k] = coll.get(k, 0) + v
                # bytes at the fusion boundary
                nbytes += _shape_bytes(ins.rtext)
                for a in re.findall(r"%([\w\.\-]+)", ins.args):
                    nbytes += _shape_bytes(shapes.get(a, ""))
                continue
            if op in ("conditional", "call"):
                for cm in re.findall(r"(?:true_computation|false_computation|"
                                     r"branch_computations=\{?|to_apply)=?%?"
                                     r"([\w\.\-]+)", ins.line):
                    if cm in comps:
                        f, b, c = visit(cm)
                        flops += f
                        nbytes += b
                        for k, v in c.items():
                            coll[k] = coll.get(k, 0) + v
                continue
            if op.startswith(_COLLECTIVES):
                base = next(c for c in _COLLECTIVES if op.startswith(c))
                if op.endswith("-done"):
                    continue
                coll[base] = coll.get(base, 0) + _shape_bytes(ins.rtext)
                nbytes += _shape_bytes(ins.rtext)
                continue
            if op == "fft":
                out = _dims_of(ins.rtext) or []
                n_out = 1
                for d in out:
                    n_out *= d
                ln = out[-1] if out else 1
                import math
                flops += 5.0 * n_out * max(math.log2(max(ln, 2)), 1.0)
            if op in ("dot", "convolution"):
                out = _dims_of(ins.rtext)
                ops_names = re.findall(r"%([\w\.\-]+)", ins.args)
                k = 1
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
                if cm and ops_names:
                    lhs_dims = _dims_of(shapes.get(ops_names[0], "")) or []
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            k *= lhs_dims[int(idx)]
                if out is not None:
                    n_out = 1
                    for d in out:
                        n_out *= d
                    flops += 2.0 * n_out * k
            if op not in _NO_BYTES:
                nbytes += _shape_bytes(ins.rtext)
                for a in re.findall(r"%([\w\.\-]+)", ins.args):
                    nbytes += _shape_bytes(shapes.get(a, ""))
        memo_flops[comp] = flops
        memo_bytes[comp] = nbytes
        memo_coll[comp] = coll
        return flops, nbytes, coll

    f, b, c = visit(entry)
    c = dict(c)
    c["total"] = sum(c.values())
    return {"flops": f, "bytes": b, "collectives": c}
