"""Benchmark suite — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Analytic (model-derived) rows
report ``us_per_call=0``; measured rows time real executions on this host.
``--json PATH`` additionally writes the machine-readable
``{"schema": "bench-fft/v2", "meta": {...}, "rows": [{name, us_per_call,
p50_us, p95_us, model_predicted_us, model_err, config}]}`` document that
CI uploads as the perf-trajectory artifact (measured rows carry the tail
percentiles and the perf model's prediction; ``meta`` pins the substrate
and active calibration). ``--trace PATH`` writes a Chrome-trace JSON of
the run — auto-derived as ``<json>.trace.json`` when ``--json`` is given;
``--trace ''`` disables.

    PYTHONPATH=src python -m benchmarks.run [--only a,b,c] [--json BENCH_fft.json]
    PYTHONPATH=src python -m benchmarks.run --only solvers,serving \\
        --json BENCH_fft.json --trace bench.trace.json

``--list`` prints the known ``--only`` workload names (one per line) and
exits — the discovery aid for the exit-2 unknown-name path.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

_ROWS: list[dict] = []


def _row(name, us, derived, config=None, stats=None, model_us=None):
    print(f"{name},{us:.3f},{derived}")
    if config is None:
        config = {"derived": derived} if derived != "" else {}
    row = {"name": name, "us_per_call": round(us, 3), "config": config}
    if stats is not None:
        row["p50_us"] = round(stats["p50_us"], 3)
        row["p95_us"] = round(stats["p95_us"], 3)
    if model_us is not None and model_us > 0 and us > 0:
        # signed relative model error: measured/predicted − 1. The absolute
        # prediction is nominal-substrate seconds, so the gate in compare.py
        # tracks the *drift* of this error vs a baseline, not its size.
        row["model_predicted_us"] = round(model_us, 3)
        row["model_err"] = round(us / model_us - 1.0, 4)
    _ROWS.append(row)


# ---------------------------------------------------------------------------
# Table 4.1 / 4.2 — architecture comparison (normalized units)
# ---------------------------------------------------------------------------

def bench_table_4_1():
    from repro.core import perfmodel as pm
    for mu in (1, 3):
        t = pm.table_4_1(mu)
        for k, v in t.items():
            _row(f"table4.1/mu{mu}/{k}/T_tot", 0.0, v["T_tot"])
            _row(f"table4.1/mu{mu}/{k}/B", 0.0, v["B"])
            _row(f"table4.1/mu{mu}/{k}/M", 0.0, v["M"])


def bench_table_4_2():
    from repro.core import perfmodel as pm
    for mu in (1, 3, 4):
        t = pm.table_4_2(mu)
        for k, v in t.items():
            _row(f"table4.2/mu{mu}/{k}/T_tot", 0.0, v["T_tot"])
            _row(f"table4.2/mu{mu}/{k}/B", 0.0, v["B"])


# ---------------------------------------------------------------------------
# Tables 5.2 / 5.4 / 5.6 — FFT engine characterization
# ---------------------------------------------------------------------------

_ENGINE_POINTS = [  # (r, n, l_op, f_mhz) — f_max values reported by the thesis
    (1, 512, 3, 250), (1, 1024, 3, 247), (1, 2048, 3, 251), (1, 4096, 3, 244),
    (1, 8192, 3, 236), (1, 2048, 9, 379),
    (2, 512, 3, 238), (2, 2048, 6, 345), (2, 8192, 9, 377),
    (4, 512, 3, 226), (4, 2048, 9, 376), (4, 4096, 9, 378),
]


def bench_engine_tables():
    from repro.core.perfmodel import EnginePoint
    for r, n, lop, f in _ENGINE_POINTS:
        pt = EnginePoint(n=n, r=r, l_op=lop, f_mhz=f)
        tbl = {1: "5.2", 2: "5.4", 4: "5.6"}[r]
        base = f"table{tbl}/R{r}/N{n}/lop{lop}"
        _row(base + "/latency_cycles", 0.0, pt.latency_cycles)
        _row(base + "/T_FFT_us", 0.0, round(pt.t_fft_us, 3))
        _row(base + "/B_FFT_GiBs", 0.0, round(pt.b_fft_gib_s, 2))
        _row(base + "/GFLOPS", 0.0, round(pt.gflops, 2))


# ---------------------------------------------------------------------------
# Table 5.7 — global 3D FFT expected times ; Table 5.8 — Xeon Phi baseline
# ---------------------------------------------------------------------------

def bench_global_fft():
    from repro.core import perfmodel as pm
    for mu in (1, 3):
        t = pm.table_5_7(mu=mu)
        for n, row in t.items():
            for p, v in row.items():
                _row(f"table5.7/mu{mu}/N{n}/P{p}", 0.0,
                     "oom" if v is None else round(v, 6))
    # Table 5.8 — measured Marconi (Xeon Phi) baselines from the thesis, the
    # strong-scaling comparison the paper draws in §5.6
    xeon = {(1024, 8): 1.20, (1024, 16): 0.67, (1024, 32): 1.61,
            (1024, 64): 0.29, (1024, 128): 0.18, (2048, 16): 48.2,
            (2048, 32): 3.75, (2048, 64): 2.26, (2048, 128): 4.90,
            (2048, 256): 0.74, (2048, 512): 0.41}
    for (n, p), v in sorted(xeon.items()):
        ours = pm.global_fft_time(n, min(p, 1024), mu=1)
        _row(f"table5.8/N{n}/P{p}/xeonphi_s", 0.0, v)
        _row(f"table5.8/N{n}/P{p}/fpga_model_speedup", 0.0, round(v / ours, 1))


# ---------------------------------------------------------------------------
# Figs 5.11 / 5.12 — network required-bandwidth curves
# ---------------------------------------------------------------------------

def bench_network_bw():
    from repro.core import topology as topo
    for topol in ("switched", "torus"):
        fig = "fig5.11" if topol == "switched" else "fig5.12"
        curves = topo.bandwidth_curves(topol)
        for (r, f), pts in sorted(curves.items()):
            for q, bw in pts:
                if q in (2, 4, 8, 16, 32):
                    _row(f"{fig}/{topol}/R{r}/f{int(f)}/sqP{q}_Gbps",
                         0.0, round(bw, 1))
    s = topo.scalability_summary(200.0)
    for (t, r, f), p in sorted(s.items()):
        _row(f"scalability/{t}/R{r}/f{int(f)}/maxP_at_200G", 0.0, p)


# ---------------------------------------------------------------------------
# Fig 1.1 — required RAM per node
# ---------------------------------------------------------------------------

def bench_fig_1_1():
    from repro.core.perfmodel import required_ram_per_node
    for n in (256, 512, 1024, 2048, 4096, 8192):
        for p in (1, 64, 1024):
            _row(f"fig1.1/N{n}/P{p}_GiB", 0.0,
                 round(required_ram_per_node(n, p) / 2 ** 30, 3))


# ---------------------------------------------------------------------------
# Measured: single-host FFT wallclock (engine vs oracle backends)
# ---------------------------------------------------------------------------

def _time(fn, *a, iters=5):
    from repro.tuning.timing import time_us
    return time_us(fn, *a, iters=iters)


def _stats(fn, *a, iters=5):
    from repro.tuning.timing import time_stats
    return time_stats(fn, *a, iters=iters)


def bench_fft_wallclock():
    import functools

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    for n in (256, 1024):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, n), jnp.float32)
        xi = jnp.zeros_like(x)
        for backend in ("jnp", "ref", "pallas"):
            st = _stats(lambda a, b: kops.fft1d(a, b, backend=backend), x, xi)
            _row(f"fft1d_wallclock/{backend}/B64xN{n}", st["mean_us"], "",
                 stats=st)
    from repro.core import perfmodel as pm
    from repro.core.decomposition import PencilGrid
    from repro.core.fft3d import FFT3DPlan, fft3d_local
    for n in (32, 64):
        grid = PencilGrid(pu=1, pv=1, u_axes=(), v_axes=())
        plan = FFT3DPlan(n=(n, n, n), grid=grid, backend="jnp")
        x = jax.random.normal(jax.random.PRNGKey(1), (n, n, n), jnp.float32)
        xi = jnp.zeros_like(x)
        f = jax.jit(functools.partial(fft3d_local, plan))
        st = _stats(f, x, xi)
        model = pm.estimate_plan_seconds((n, n, n), 1, 1,
                                         spec=plan.spec()) * 1e6
        _row(f"fft3d_wallclock/jnp/N{n}", st["mean_us"], "", stats=st,
             model_us=model)
        z = np.random.randn(n, n, n).astype(np.complex64)
        t0 = time.time()
        for _ in range(5):
            np.fft.fftn(z)
        _row(f"fft3d_wallclock/numpy/N{n}", (time.time() - t0) / 5 * 1e6, "")


# ---------------------------------------------------------------------------
# Measured: distributed 3D FFT per TransposeEngine (the engine axis of the
# plan space — fft_overlap_ring rows are the perf trajectory of the fused
# compute/communication ring vs the serial fabrics)
# ---------------------------------------------------------------------------

def bench_fft_engines(n: int = 16):
    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.core.comm import ENGINE_NAMES
    from repro.core.engine_spec import EngineSpec
    from repro.core.fft3d import make_fft3d

    ndev = len(jax.devices())
    rng = np.random.RandomState(0)
    xr = jnp.asarray(rng.randn(n, n, n).astype(np.float32))
    xi = jnp.zeros_like(xr)

    from repro.core import perfmodel as pm

    def _sweep(mesh, mesh_tag, u_axes, v_axes):
        for engine in ENGINE_NAMES:
            fwd, inv, plan = make_fft3d(mesh, (n, n, n),
                                        spec=EngineSpec(engine=engine),
                                        u_axes=u_axes, v_axes=v_axes)
            cfg = {"comm_engine": engine, "net": plan.net, "n": n,
                   "mesh": mesh_tag, "backend": plan.backend}
            g = plan.grid
            model = pm.estimate_plan_seconds(
                (n, n, n), g.pu, g.pv, spec=plan.spec(),
                pu_axes=g.u_sizes, pv_axes=g.v_sizes) * 1e6
            st = _stats(fwd, xr, xi)
            _row(f"fft_{engine}/N{n}/mesh{mesh_tag}/fwd", st["mean_us"], "",
                 config=cfg, stats=st, model_us=model)
            kr, ki = fwd(xr, xi)
            st = _stats(inv, kr, ki)
            _row(f"fft_{engine}/N{n}/mesh{mesh_tag}/inv", st["mean_us"], "",
                 config=cfg, stats=st, model_us=model)

    pu, pv = (4, 2) if ndev >= 8 else ((2, 1) if ndev >= 2 else (1, 1))
    mesh = compat.make_mesh((pu, pv), ("data", "model"))
    _sweep(mesh, f"{pu}x{pv}", ("data",), ("model",))
    if ndev >= 8:
        # multi-axis pencil: the u grid dimension spans two mesh axes, so
        # the ring engines run one staged per-axis ring per mesh axis
        mesh3 = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
        _sweep(mesh3, "2x2x2", ("pod", "data"), ("model",))


# ---------------------------------------------------------------------------
# Measured: per-solver step latency (the repro.solvers workloads — each row
# is one full FFT→spectral→iFFT→local cycle on the largest pencil mesh the
# host's devices allow)
# ---------------------------------------------------------------------------

def bench_solvers(n: int = 16):
    import jax

    from repro import compat
    from repro.solvers import SOLVERS, make_solver
    from repro.solvers.base import SpectralSolver

    ndev = len(jax.devices())
    pu, pv = (4, 2) if ndev >= 8 else ((2, 1) if ndev >= 2 else (1, 1))
    mesh = compat.make_mesh((pu, pv), ("data", "model"))
    # float32: benches run without x64 and timing doesn't need f64 validation
    for case in sorted(SOLVERS):
        solver = make_solver(case, mesh, (n, n, n), dtype="float32")
        state = solver.init_state()
        # time the jitted step directly: the benchmark number stays free of
        # the dispatch-span bookkeeping solver.step() adds under --trace
        st = _stats(solver._stepj, state.fields, iters=3)
        _row(f"solver_{case}/N{n}/mesh{pu}x{pv}/us_per_step", st["mean_us"],
             "", config={"case": case, "n": n, "mesh": f"{pu}x{pv}",
                         **solver.plan_config()},
             stats=st, model_us=solver.predict_step_us())
        if SOLVERS[case].spectral_kernel is SpectralSolver.spectral_kernel:
            continue  # no diagonal spectral kernel — nothing to fuse
        fused = make_solver(case, mesh, (n, n, n), dtype="float32",
                            plan_cfg={"fused_roundtrip": True})
        fstate = fused.init_state()
        st = _stats(fused._stepj, fstate.fields, iters=3)
        _row(f"solver_{case}_fused/N{n}/mesh{pu}x{pv}/us_per_step",
             st["mean_us"], "",
             config={"case": case, "n": n, "mesh": f"{pu}x{pv}",
                     **fused.plan_config()},
             stats=st, model_us=fused.predict_step_us())


# ---------------------------------------------------------------------------
# Measured: batched solver serving (requests/s + latency tails under a burst
# load — the repro.serving layer's rows on the perf trajectory)
# ---------------------------------------------------------------------------

def bench_serving(n: int = 16, n_requests: int = 8, steps: int = 2):
    """Load-generate against an in-process SimServer at two batch limits.

    Burst-submits ``n_requests`` same-fingerprint heat requests and drains;
    ``max_batch=1`` is the no-batching baseline, ``max_batch=4`` the batched
    path (⌈8/4⌉ = 2 sharded steps per Δt instead of 8). Rows carry the mean
    request latency as ``us_per_call`` with p50/p95 (row schema) and p99
    (serving extra) tails, plus a lower-is-better ``us_per_request``
    throughput row (``requests_per_s`` in its config). A compile warm-up
    run per batch limit keeps XLA compilation off the latency rows — the
    registry keeps engines hot, which is the layer's whole point.
    """
    import jax

    from repro import compat
    from repro.serving import SimRequest, SimServer, run_load

    ndev = len(jax.devices())
    pu, pv = (4, 2) if ndev >= 8 else ((2, 1) if ndev >= 2 else (1, 1))
    mesh = compat.make_mesh((pu, pv), ("data", "model"))
    case = "heat"

    def make_requests():
        return [SimRequest(case=case, n=n, steps=steps, dtype="float32",
                           scale=1.0 + 0.25 * i, request_id=f"req-{i}")
                for i in range(n_requests)]

    for max_batch in (1, 4):
        server = SimServer(mesh, max_batch=max_batch, use_plan_cache=False)
        run_load(server, make_requests())        # compile warm-up, untimed
        report = run_load(server, make_requests())
        st = report.stats()
        assert st["n_failed"] == 0, report.results
        cfg = {"case": case, "n": n, "mesh": f"{pu}x{pv}",
               "steps": steps, "requests": st["n_requests"],
               "max_batch": max_batch,
               "requests_per_s": st["requests_per_s"]}
        base = f"serving_{case}/N{n}/mesh{pu}x{pv}/b{max_batch}"
        _row(f"{base}/latency", st["mean_us"], "", config=cfg,
             stats={"p50_us": st["p50_us"], "p95_us": st["p95_us"]})
        _ROWS[-1]["p99_us"] = st["p99_us"]
        _row(f"{base}/us_per_request",
             st["wall_s"] * 1e6 / max(st["n_requests"], 1), "", config=cfg)


# ---------------------------------------------------------------------------
# Measured: autotuned vs default 3D-FFT plan (single device, Pu=Pv=1)
# ---------------------------------------------------------------------------

def bench_fft_autotune(n: int = 32):
    """Time the autotuner's sweep (the default plan is always in it).

    ``force=True``: a benchmark must measure *this* run, never replay the
    persistent plan cache (the entry still gets refreshed as a side effect).
    """
    from repro import compat
    from repro.tuning import autotune

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    res = autotune(mesh, n, real=True, max_candidates=4, iters=3, force=True)
    for r in res.rows:
        _row(f"fft3d_autotune/N{n}/{r['name']}", r["us_per_call"], "",
             config=r["config"])
    _row(f"fft3d_autotune/N{n}/selected", res.best_us, res.best.name,
         config=res.best_config)


BENCHES = {
    "table_4_1": bench_table_4_1,
    "table_4_2": bench_table_4_2,
    "engine_tables": bench_engine_tables,
    "global_fft": bench_global_fft,
    "network_bw": bench_network_bw,
    "fig_1_1": bench_fig_1_1,
    "fft_wallclock": bench_fft_wallclock,
    "fft_engines": bench_fft_engines,
    "fft_autotune": bench_fft_autotune,
    "solvers": bench_solvers,
    "serving": bench_serving,
}


def _trace_path_for(json_path: str) -> str:
    base = json_path[:-5] if json_path.endswith(".json") else json_path
    return base + ".trace.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help=f"comma-separated benchmark names; known: "
                         f"{','.join(sorted(BENCHES))}")
    ap.add_argument("--json", dest="json_path", default="",
                    help="also write rows as a bench-fft/v2 JSON document")
    ap.add_argument("--trace", dest="trace_path", default=None,
                    help="write a Chrome-trace JSON (Perfetto-loadable) of "
                         "the run; defaults to <json-stem>.trace.json when "
                         "--json is given, '' disables")
    ap.add_argument("--list", action="store_true",
                    help="print the known --only workload names and exit")
    args = ap.parse_args()
    if args.list:
        for name in sorted(BENCHES):
            print(name)
        return
    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        # a typo'd --only must fail loudly, not emit an empty document that
        # the CI perf gate would then wave through
        ap.error(f"unknown benchmark name(s) {', '.join(unknown)}; "
                 f"known: {', '.join(sorted(BENCHES))}")
    trace_path = args.trace_path
    if trace_path is None:
        trace_path = _trace_path_for(args.json_path) if args.json_path else ""
    if trace_path:
        from repro import obs
        obs.clear()
        obs.enable()
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()
    meta = None
    if args.json_path or trace_path:
        import jax

        from repro.core import perfmodel as pm
        cal = pm.active_calibration()
        meta = {"jax": jax.__version__,
                "platform": jax.devices()[0].platform,
                "device_kind": jax.devices()[0].device_kind,
                "devices": len(jax.devices()),
                "benches": names,
                "calibration": {
                    "active": cal is not None,
                    "link_bytes_per_s": pm.link_bytes_per_s(),
                    **({"fingerprint": cal.get("fingerprint", {})}
                       if cal else {}),
                }}
    if args.json_path:
        from repro.tuning.cli import write_bench_json
        write_bench_json(args.json_path, _ROWS, meta)
    if trace_path:
        from repro import obs
        obs.disable()
        obs.write_chrome_trace(trace_path, obs.tracer, obs.metrics, meta=meta)
        print(f"# wrote trace {trace_path} "
              f"({len(obs.tracer.events())} spans); load in "
              f"https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
