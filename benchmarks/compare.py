"""Bench regression gate: diff two ``bench-fft/v1|v2`` JSON documents.

    PYTHONPATH=src python -m benchmarks.compare BASELINE.json NEW.json \
        [--threshold 0.15] [--strict] [--model-drift-threshold 0.5]

Compares ``us_per_call`` of *measured* rows (``us_per_call > 0``; analytic
model rows carry 0 and are skipped) that appear in both documents, matched
by ``name``. Timings only transfer within one substrate: when the two
documents' ``meta`` disagree on platform / device kind / device count /
JAX version, the gate soft-passes rather than comparing apples to oranges
(e.g. the baseline artifact predates a CI environment change). Exit codes:

* ``0`` — no row regressed beyond the threshold, or soft-pass (baseline
  file missing / no overlapping rows / substrate mismatch) when
  ``--strict`` is not given — CI's first run has no previous artifact to
  compare against.
* ``1`` — at least one row regressed by more than ``--threshold``
  (default 0.15 = +15% time per call).
* ``2`` — unreadable/invalid input, a ``--expect GLOB`` with no matching
  measured row in the new document, or soft-pass conditions under
  ``--strict``.
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import sys

#: accepted document generations: v2 rows additionally carry
#: ``p50_us``/``p95_us`` and ``model_predicted_us``/``model_err``
SCHEMAS = ("bench-fft/v1", "bench-fft/v2")
SCHEMA = SCHEMAS[-1]

#: meta keys that must agree for timings to be comparable at all
SUBSTRATE_KEYS = ("platform", "device_kind", "devices", "jax")


def load_doc(path: str) -> tuple[dict, dict, dict]:
    """``({name: us_per_call}, {name: model_err}, meta)`` for the measured
    rows of a document (``model_err`` only where a row carries one — v1
    documents yield an empty error map)."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") not in SCHEMAS:
        raise ValueError(f"{path}: expected schema in {SCHEMAS!r}, "
                         f"got {doc.get('schema')!r}")
    out, errs = {}, {}
    for row in doc.get("rows", []):
        name, us = row.get("name"), row.get("us_per_call")
        if isinstance(name, str) and isinstance(us, (int, float)) and us > 0:
            out[name] = float(us)
            err = row.get("model_err")
            if isinstance(err, (int, float)):
                errs[name] = float(err)
    return out, errs, doc.get("meta", {})


def substrate_mismatch(base_meta: dict, new_meta: dict) -> str:
    """Non-empty reason string when the two measurement substrates differ."""
    for key in SUBSTRATE_KEYS:
        if base_meta.get(key) != new_meta.get(key):
            return (f"{key}: baseline={base_meta.get(key)!r} "
                    f"vs new={new_meta.get(key)!r}")
    return ""


def median_abs_err(errs: dict) -> float:
    """Median |model_err| over a document's predicted rows."""
    vals = sorted(abs(v) for v in errs.values())
    if not vals:
        return 0.0
    mid = len(vals) // 2
    if len(vals) % 2:
        return vals[mid]
    return 0.5 * (vals[mid - 1] + vals[mid])


def compare(base: dict, new: dict, threshold: float):
    """(regressions, improvements, n_common): rows beyond ±threshold."""
    regressions, improvements = [], []
    common = sorted(set(base) & set(new))
    for name in common:
        ratio = new[name] / base[name]
        if ratio > 1.0 + threshold:
            regressions.append((name, base[name], new[name], ratio))
        elif ratio < 1.0 - threshold:
            improvements.append((name, base[name], new[name], ratio))
    regressions.sort(key=lambda r: -r[3])
    improvements.sort(key=lambda r: r[3])
    return regressions, improvements, len(common)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.compare",
        description="Fail when BENCH_fft.json regressed vs a baseline run.")
    ap.add_argument("baseline", help="previous run's bench-fft/v1 JSON")
    ap.add_argument("new", help="this run's bench-fft/v1 JSON")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="relative us_per_call increase that fails the gate "
                         "(default 0.15 = +15%%)")
    ap.add_argument("--strict", action="store_true",
                    help="missing baseline / empty overlap is an error "
                         "instead of a soft pass")
    ap.add_argument("--ignore", action="append", default=[], metavar="GLOB",
                    help="row-name glob to exclude from the gate "
                         "(repeatable; e.g. 'autotune/*' for low-iteration "
                         "sweep diagnostics too noisy to gate on)")
    ap.add_argument("--expect", action="append", default=[], metavar="GLOBS",
                    help="comma-separated row-name globs, each of which "
                         "must match at least one measured row of the NEW "
                         "document (repeatable; e.g. "
                         "'fft_overlap_ring*,fft_pallas_ring*' keeps the "
                         "engine workloads on the perf trajectory — a "
                         "bench that silently stops emitting any one of "
                         "them fails here, exit 2)")
    ap.add_argument("--min-us", type=float, default=0.0,
                    help="gate only rows whose baseline us_per_call is at "
                         "least this (sub-threshold timings are scheduler "
                         "jitter on shared runners, not signal)")
    ap.add_argument("--model-drift-threshold", type=float, default=0.0,
                    metavar="FRAC",
                    help="enable the perf-model drift gate: fail when the "
                         "median |model_err| of the new document exceeds "
                         "the baseline's by more than this fraction (plus a "
                         "0.02 absolute allowance). The new document must "
                         "carry model_err rows (bench-fft/v2); a baseline "
                         "without them soft-passes this gate only.")
    args = ap.parse_args(argv)

    def soft(msg: str) -> int:
        print(f"bench-compare: {msg}")
        if args.strict:
            return 2
        print("bench-compare: soft pass (no baseline to gate against)")
        return 0

    try:
        new, new_errs, new_meta = load_doc(args.new)
    except (FileNotFoundError, json.JSONDecodeError, ValueError) as e:
        print(f"bench-compare: unreadable new document: {e}")
        return 2

    if args.model_drift_threshold > 0 and not new_errs:
        # the drift gate guards the model's health; a new document with no
        # predicted rows means the bench stopped emitting them — fail loud
        # like --expect, don't soft-pass
        print(f"bench-compare: FAIL — model drift gate requested but "
              f"{args.new!r} carries no model_err rows (bench-fft/v2 "
              f"measured rows with predictions)")
        return 2

    # --expect guards the new document alone, so it binds even on the first
    # run when there is no baseline to diff against; each comma-separated
    # glob must be satisfied independently
    expected = [g.strip() for arg in args.expect for g in arg.split(",")
                if g.strip()]
    for pat in expected:
        if not any(fnmatch.fnmatch(name, pat) for name in new):
            print(f"bench-compare: FAIL — no measured row in {args.new!r} "
                  f"matches expected glob {pat!r} (workload fell off the "
                  f"perf trajectory)")
            return 2

    try:
        base, base_errs, base_meta = load_doc(args.baseline)
    except FileNotFoundError:
        return soft(f"baseline {args.baseline!r} not found")
    except (json.JSONDecodeError, ValueError) as e:
        return soft(f"unreadable baseline: {e}")

    mismatch = substrate_mismatch(base_meta, new_meta)
    if mismatch:
        return soft(f"measurement substrate changed ({mismatch}) — "
                    f"timings are not comparable")

    if args.ignore:
        def keep(name):
            return not any(fnmatch.fnmatch(name, pat) for pat in args.ignore)
        dropped = sorted(n for n in (set(base) | set(new)) if not keep(n))
        base = {k: v for k, v in base.items() if keep(k)}
        new = {k: v for k, v in new.items() if keep(k)}
        base_errs = {k: v for k, v in base_errs.items() if keep(k)}
        new_errs = {k: v for k, v in new_errs.items() if keep(k)}
        if dropped:
            print(f"bench-compare: ignoring {len(dropped)} row(s) matching "
                  f"{args.ignore}")
    if args.min_us > 0:
        fast = [k for k, v in base.items() if v < args.min_us]
        if fast:
            print(f"bench-compare: skipping {len(fast)} row(s) under "
                  f"{args.min_us:g} us (below the noise floor)")
            base = {k: v for k, v in base.items() if k not in set(fast)}

    regressions, improvements, n_common = compare(base, new, args.threshold)
    if not n_common:
        return soft("no measured rows in common")

    print(f"bench-compare: {n_common} measured rows in common, "
          f"threshold +{args.threshold:.0%}")
    for name, b, n, ratio in improvements:
        print(f"  improved  {name}: {b:.1f} -> {n:.1f} us ({ratio:.2f}x)")
    for name, b, n, ratio in regressions:
        print(f"  REGRESSED {name}: {b:.1f} -> {n:.1f} us ({ratio:.2f}x)")

    drift_failed = False
    if args.model_drift_threshold > 0:
        if not base_errs:
            print("bench-compare: model drift gate: baseline has no "
                  "model_err rows (pre-v2 artifact) — recording this run's "
                  "error as the new reference, not gating")
        else:
            b_med, n_med = median_abs_err(base_errs), median_abs_err(new_errs)
            # absolute 0.02 allowance: a near-perfect baseline (median error
            # ~0) must not turn ordinary run-to-run jitter into a failure
            limit = b_med * (1.0 + args.model_drift_threshold) + 0.02
            verdict = "OK" if n_med <= limit else "FAIL"
            print(f"bench-compare: model drift: median |model_err| "
                  f"{b_med:.3f} -> {n_med:.3f} (limit {limit:.3f}, "
                  f"{len(new_errs)} predicted rows) {verdict}")
            if n_med > limit:
                drift_failed = True

    if regressions:
        print(f"bench-compare: FAIL — {len(regressions)} row(s) regressed "
              f"more than {args.threshold:.0%}")
        return 1
    if drift_failed:
        print("bench-compare: FAIL — perf model drifted from its measured "
              "baseline (recalibrate or fix the model)")
        return 1
    print("bench-compare: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
