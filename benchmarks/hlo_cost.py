"""Shim: the implementation lives in repro.launch.hlo_cost (importable from
both the dry-run driver and the benchmarks package)."""
from repro.launch.hlo_cost import analyze_hlo, parse  # noqa: F401
