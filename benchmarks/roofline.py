"""Roofline analysis from dry-run artifacts (deliverable g).

Reads benchmarks/out/dryrun/*.json and derives, per (arch × shape × mesh):

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s        [s]
    memory term     = HLO_bytes_per_chip / HBM_bw             [s]
    collective term = collective_bytes_per_chip / ICI_bw      [s]

(cost_analysis on the SPMD executable reports per-chip figures; peak chip
constants are the assignment's v5e numbers.) Also reports MODEL_FLOPS
(6·N·D train / 2·N·D inference, N = active params) and the useful-compute
ratio MODEL_FLOPS_per_chip / HLO_FLOPs — remat/dispatch waste shows up here.

    PYTHONPATH=src python -m benchmarks.roofline [--dir ...] [--md out.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip (v5e)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link (assignment constant)

SHAPE_TOKENS = {         # decoded tokens per step for inference shapes
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,
    "long_500k": 1,
}


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    # prefer the trip-count-aware HLO walk: XLA:CPU cost_analysis counts
    # while (scan) bodies once (see hlo_cost.py)
    cost = rec.get("cost_tripaware") or rec["cost"]
    flops = cost["flops"]                        # per-chip (SPMD program)
    mem_bytes = cost["bytes_accessed"]
    coll = (cost.get("collectives") or rec["collectives"])["total"]
    t_c = flops / PEAK_FLOPS
    t_m = mem_bytes / HBM_BW
    t_n = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    dom = max(terms, key=terms.get)
    n_active = rec["model_params_active"]
    if "fft_model_flops_total" in rec:           # paper FFT cells
        model_flops_total = rec["fft_model_flops_total"]
    else:
        tokens = SHAPE_TOKENS[rec["shape"]]
        factor = 6 if rec["shape"] == "train_4k" else 2
        model_flops_total = factor * n_active * tokens
    model_flops_chip = model_flops_total / chips
    bound = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom,
        "model_flops_per_chip": model_flops_chip,
        "useful_ratio": model_flops_chip / flops if flops else 0.0,
        "roofline_frac": (model_flops_chip / PEAK_FLOPS) / bound if bound else 0.0,
        "peak_gib": rec["memory"]["peak_per_device_bytes"] / 2 ** 30,
        "fits_hbm": rec["memory"]["peak_per_device_bytes"] <= 16 * 2 ** 30,
    }


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(os.path.dirname(__file__),
                                                  "out", "dryrun"))
    ap.add_argument("--md", default="")
    ap.add_argument("--mesh", default="pod16x16",
                    help="roofline table mesh (single pod by assignment)")
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        rec = json.load(open(path))
        a = analyze(rec)
        if a and a["mesh"] == args.mesh:
            rows.append(a)
        elif rec.get("status") not in ("ok", None) and rec["mesh"] == args.mesh:
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": rec["status"]})

    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "useful | roofline frac | peak GiB | fits |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    print("arch,shape,compute_s,memory_s,collective_s,dominant,useful_ratio,"
          "roofline_frac,peak_gib,fits_hbm")
    for r in rows:
        if "status" in r and "compute_s" not in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['status']} | — | — | — | — |")
            print(f"{r['arch']},{r['shape']},,,,{r['status']},,,,")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.3f} | {r['peak_gib']:.2f} | "
            f"{'y' if r['fits_hbm'] else 'NO'} |")
        print(f"{r['arch']},{r['shape']},{r['compute_s']:.6g},"
              f"{r['memory_s']:.6g},{r['collective_s']:.6g},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['roofline_frac']:.4f},"
              f"{r['peak_gib']:.2f},{r['fits_hbm']}")
    if args.md:
        with open(args.md, "w") as f:
            f.write("\n".join(lines) + "\n")


if __name__ == "__main__":
    main()
