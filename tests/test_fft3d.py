"""Distributed 3D FFT integration tests.

The heavy multi-device checks run in a subprocess (the fake-device XLA flag
must be set before jax initializes); single-device plan/layout logic is
tested in-process.
"""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decomposition import PencilGrid
from repro.core.fft3d import FFT3DPlan, fft3d_local, ifft3d_local

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_multi_device_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_dist_fft_check.py")],
        env=env, capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ALL_OK" in out.stdout


def test_single_device_local_matches_fftn():
    grid = PencilGrid(pu=1, pv=1, u_axes=(), v_axes=())
    # with pu=pv=1 the folds are pure local transposes — run outside shard_map
    plan = FFT3DPlan(n=(8, 8, 8), grid=grid, backend="ref")
    rng = np.random.RandomState(1)
    g = rng.randn(8, 8, 8) + 1j * rng.randn(8, 8, 8)
    kr, ki = fft3d_local(plan, jnp.asarray(g.real), jnp.asarray(g.imag))
    want = np.fft.fftn(g, axes=(0, 1, 2)).transpose(2, 0, 1)
    got = np.asarray(kr) + 1j * np.asarray(ki)
    assert np.linalg.norm(got - want) / np.linalg.norm(want) < 1e-10
    br, bi = ifft3d_local(plan, kr, ki)
    back = np.asarray(br) + 1j * np.asarray(bi)
    assert np.linalg.norm(back - g) / np.linalg.norm(g) < 1e-10


@pytest.mark.parametrize("pu,pv,ok", [(2, 2, True), (3, 2, False), (2, 3, False)])
def test_validate(pu, pv, ok):
    grid = PencilGrid(pu=pu, pv=pv)
    if ok:
        grid.validate((16, 16, 16))
    else:
        with pytest.raises(ValueError):
            grid.validate((16, 16, 16))


def test_padded_r2c_len():
    g = PencilGrid(pu=4, pv=2)
    assert g.padded_r2c_len(16) == 12  # 9 -> 12
    assert g.padded_r2c_len(8) == 8    # 5 -> 8
    g1 = PencilGrid(pu=1, pv=1)
    assert g1.padded_r2c_len(16) == 9


def test_volume_model_eqs_3_3_and_3_4():
    # paper Eq 3.3/3.4, s=8 bytes
    g = PencilGrid(pu=4, pv=4)
    n = (64, 64, 64)
    assert g.local_volume_bytes(n) == 8 * 64**3 // 16
    assert g.local_volume_after_x_bytes(n) == 8 * (64**3 + 2 * 64**2) // 16
