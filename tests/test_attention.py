"""Attention-path equivalence tests: chunked (flash-style) vs direct,
sequence-sharded decode vs dense decode, MLA chunked vs direct."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models import mla as MLA
from repro.models.common import Initializer


def _dims(h=8, hkv=2, d=32, causal=True):
    return L.AttnDims(d_model=h * d, n_heads=h, n_kv_heads=hkv, head_dim=d,
                      causal=causal)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,t", [(64, 64), (128, 128), (96, 96)])
def test_chunked_matches_direct(causal, s, t):
    a = _dims(causal=causal)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, s, a.n_heads, a.head_dim), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, t, a.n_kv_heads, a.head_dim))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, t, a.n_kv_heads, a.head_dim))
    mask = None
    if causal:
        mask = (jnp.arange(t)[None, :] <= jnp.arange(s)[:, None])[None, None, None]
    ref = L._sdpa_direct(q, k, v, a, mask)
    got = L._sdpa_chunked(q, k, v, a, causal=causal, q_chunk=32, k_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_chunked_dv_differs_from_dqk():
    a = _dims()
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 64, 8, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 48))
    got = L._sdpa_chunked(q, k, v, a, causal=True, q_chunk=16, k_chunk=16)
    assert got.shape == (1, 64, 8, 48)


def test_mla_chunked_matches_direct():
    m = MLA.MLADims(d_model=64, n_heads=4, kv_lora_rank=32, qk_nope_dim=16,
                    qk_rope_dim=8, v_head_dim=16)
    ini = Initializer(key=jax.random.PRNGKey(0), dtype=jnp.float32)
    p = MLA.init_mla(ini, m)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 96, 64))
    pos = jnp.arange(96)[None, :] * jnp.ones((2, 1), jnp.int32)
    ref, _ = MLA.apply_mla(p, m, x, pos)

    import repro.models.layers as Lmod
    old = Lmod.CHUNK_THRESHOLD
    Lmod.CHUNK_THRESHOLD = 8  # force the chunked path
    try:
        got, _ = MLA.apply_mla(p, m, x, pos)
    finally:
        Lmod.CHUNK_THRESHOLD = old
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_seqsharded_decode_matches_dense_subprocess():
    """The long_500k LSE-combine must equal dense decode attention."""
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = """
from repro.launch.mesh import ensure_host_devices
ensure_host_devices(4)
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.models import layers as L

a = L.AttnDims(d_model=64, n_heads=8, n_kv_heads=2, head_dim=8)
from repro.compat import make_mesh, shard_map
mesh = make_mesh((4,), ("data",))
B, T = 1, 64
q = jax.random.normal(jax.random.PRNGKey(0), (B, 1, 8, 8))
k = jax.random.normal(jax.random.PRNGKey(1), (B, T, 2, 8))
v = jax.random.normal(jax.random.PRNGKey(2), (B, T, 2, 8))
clen = 37
mask = (jnp.arange(T)[None, :] <= clen)[None, None, None]
ref = L._sdpa_direct(q, k, v, a, mask)

def local(qq, ks, vs):
    r = jax.lax.axis_index("data")
    tl = ks.shape[1]
    valid = ((r * tl + jnp.arange(tl))[None, :] <= clen)
    valid = jnp.broadcast_to(valid, (qq.shape[0], tl))
    return L.decode_attention_seqsharded(qq, ks, vs, valid, "data")

got = jax.jit(shard_map(local, mesh=mesh,
    in_specs=(P(), P(None, "data", None, None), P(None, "data", None, None)),
    out_specs=P(), check_vma=False))(q, k, v)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)
print("SEQSHARD_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SEQSHARD_OK" in out.stdout


def test_int8_kv_cache_decode_close_to_bf16():
    """kv_quant=True decode logits track the unquantized cache closely."""
    import dataclasses
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import RunCfg, decode_step, init_cache, init_model

    cfg = get_config("deepseek-7b", smoke=True)
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    run = RunCfg(mesh=None, remat=False)
    params, _ = init_model(cfg, jax.random.PRNGKey(7))
    rng = np.random.RandomState(7)

    cache = init_cache(cfg, 2, t_max=16)
    cacheq = init_cache(cfgq, 2, t_max=16)
    assert cacheq["k"].dtype == jnp.int8
    agree = 0
    for t in range(8):
        tok = jnp.asarray(rng.randint(0, cfg.vocab, (2, 1)), jnp.int32)
        lo, cache = decode_step(cfg, run, params, cache, tok)
        lq, cacheq = decode_step(cfgq, run, params, cacheq, tok)
        err = float(jnp.max(jnp.abs(lo - lq))) / max(float(jnp.max(jnp.abs(lo))), 1e-9)
        assert err < 0.08, (t, err)
        agree += int(jnp.argmax(lo[:, -1], -1)[0] == jnp.argmax(lq[:, -1], -1)[0])
    assert agree >= 7  # top-1 agreement on ≥7/8 greedy steps
