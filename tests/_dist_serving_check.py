"""Multi-device serving smoke (run in a subprocess so the fake
device-count XLA flag is set before jax initializes).

Usage: python tests/_dist_serving_check.py [--mesh PUxPV] [--engine NAME]
(expects PYTHONPATH=src)

The acceptance check the CI mesh × engine matrix names: two concurrent
heat requests submitted to a :class:`repro.serving.SimServer` on the
Pu×Pv pencil mesh (default 4x2) must batch into **one** sharded solver
step over a leading batch axis, and every streamed per-step observable —
including the accumulated ``t`` clock — must come back **bitwise
identical** to a solo ``SpectralSolver`` run of the same request (exact
float equality, no tolerance). A third request with a different
fingerprint (nls) rides along to prove the queue groups by fingerprint
instead of batching across engines. ``--engine`` pins the fold
communications to one TransposeEngine so every matrix cell exercises its
own collective path. Prints CHECK ... OK per assertion group, then ALL_OK.
"""

import argparse
import math
import sys

from repro.launch.mesh import ensure_host_devices

# the fake-device flag must be set before jax initializes, and the count
# depends on the --mesh argument — peek at argv ahead of argparse
_ndev = 8
if "--mesh" in sys.argv[:-1]:
    _dims = [int(t) for t in sys.argv[sys.argv.index("--mesh") + 1].split("x")]
    _ndev = max(8, math.prod(_dims))
ensure_host_devices(_ndev)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

from repro import compat, obs  # noqa: E402
from repro.serving import (SimRequest, SimServer,  # noqa: E402
                           request_key, scaled_initial_fields)
from repro.solvers import SolverState  # noqa: E402


def solo_history(solver, scale: float, steps: int) -> list:
    """What an unbatched run records: same initial fields, same clocks."""
    st = SolverState(fields=scaled_initial_fields(solver, scale))
    history = [solver.observables(st)]
    for _ in range(steps):
        st = solver.step(st)
        history.append(solver.observables(st))
    return history


def run(pu: int = 4, pv: int = 2, engine: str = ""):
    assert len(jax.devices()) >= pu * pv, jax.devices()
    mesh = compat.make_mesh((pu, pv), ("data", "model"))
    plan_cfg = {"comm_engine": engine} if engine else None

    heat = [SimRequest(case="heat", n=16, steps=3, dtype="float64",
                       plan_cfg=plan_cfg, scale=1.0, request_id="heat-0"),
            SimRequest(case="heat", n=16, steps=2, dtype="float64",
                       plan_cfg=plan_cfg, scale=1.75, request_id="heat-1")]
    nls = SimRequest(case="nls", n=16, steps=2, dtype="float64",
                     plan_cfg=plan_cfg, request_id="nls-0")
    assert request_key(heat[0]) == request_key(heat[1])
    assert request_key(nls) != request_key(heat[0])

    with obs.capture() as (_, metrics):
        server = SimServer(mesh, max_batch=4, use_plan_cache=False)
        tickets = [server.submit(r) for r in (*heat, nls)]
        served = server.serve_pending()
    assert served == 3
    counters = metrics.counters()
    # fingerprint grouping: the two heat lanes shared one batch, nls got
    # its own — 2 batches, 2 engine builds, no cross-engine batching
    assert counters["serving.batches"] == 2, counters
    assert counters["serving.engine_cache.misses"] == 2, counters
    assert counters["serving.requests.completed"] == 3, counters
    results = [t.result(timeout=30) for t in tickets]
    assert all(r.ok for r in results), [r.error for r in results]
    assert [r.batch_size for r in results] == [2, 2, 1]
    print(f"CHECK serving_grouping OK  (2 heat lanes batched, nls solo, "
          f"{served} served)", flush=True)

    # the identity guarantee, bitwise: every streamed observable equals the
    # solo run's float exactly (dict == compares float bit patterns here)
    for req, res in zip((*heat, nls), results):
        solver = server.registry.get(req)
        assert (not engine) or solver.plan.comm_engine == engine
        ref = solo_history(solver, req.scale, req.steps)
        assert len(res.history) == req.steps + 1 == len(ref)
        assert res.history == ref, (req.request_id, res.history, ref)
        ok, lines = solver.validate(res.history)
        assert ok, (req.request_id, lines)
        print(f"CHECK serving_{req.request_id} OK  "
              f"(batched == solo bitwise over {req.steps} steps; "
              f"{'; '.join(lines)})", flush=True)

    print("ALL_OK", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="4x2", help="PUxPV pencil grid")
    ap.add_argument("--engine", default="",
                    help="pin every request's comm engine")
    args = ap.parse_args()
    pu, pv = (int(t) for t in args.mesh.lower().split("x"))
    run(pu, pv, args.engine)
