"""Autotuner subsystem: space enumeration, analytic pruning, plan cache,
and the end-to-end sweep (single device, Pu=Pv=1 — multi-device coverage
lives in the subprocess checks)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import compat
from repro.core import perfmodel as pm
from repro.tuning import (DEFAULT_CANDIDATE, Candidate, PlanCache, autotune,
                          candidate_space, problem_fingerprint)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# candidate space
# ---------------------------------------------------------------------------

def test_space_validity_rules():
    # single-rank grid: no ring engines (identical to switched), no vector modes
    cands = candidate_space(16, 1, 1)
    assert all(c.comm_engine == "switched" for c in cands)
    assert all(c.net == "switched" for c in cands)
    assert all(c.vector_mode == "streaming" for c in cands)
    assert all(not c.r2c_packed for c in cands)  # complex problem
    assert DEFAULT_CANDIDATE in cands

    # distributed grid: all five engines; real pow2 problem: packed appears
    cands = candidate_space(16, 4, 2, real=True)
    assert {c.comm_engine for c in cands} == {"switched", "torus",
                                              "overlap_ring", "pallas_ring",
                                              "bidi_ring"}
    # every ring engine rides the torus fabric (legacy net view)
    assert {c.net for c in cands} == {"switched", "torus"}
    assert all(c.net == ("switched" if c.comm_engine == "switched"
                         else "torus") for c in cands)
    assert any(c.r2c_packed for c in cands)

    # vector problem sweeps both vector modes
    cands = candidate_space(16, 4, 2, components=3)
    assert {c.vector_mode for c in cands} == {"streaming", "parallel"}

    # non-power-of-two N: only XLA's general engine survives
    cands = candidate_space(12, 2, 1)
    assert {c.backend for c in cands} == {"jnp"}

    # sequential candidates always carry chunks=1
    assert all(c.chunks == 1 for c in cands if c.schedule == "sequential")


def test_candidate_roundtrip():
    c = Candidate(backend="mxu", schedule="pipelined", chunks=4,
                  comm_engine="overlap_ring")
    assert c.config()["net"] == "torus"  # derived fabric rides along
    assert Candidate.from_config(c.config()) == c
    assert Candidate.from_config(json.loads(json.dumps(c.config()))) == c
    # pre-engine cache entries (net only) map onto the engine axis
    legacy = {"backend": "jnp", "schedule": "sequential", "chunks": 1,
              "net": "torus", "vector_mode": "streaming", "r2c_packed": False}
    assert Candidate.from_config(legacy).comm_engine == "torus"


# ---------------------------------------------------------------------------
# analytic pruning model
# ---------------------------------------------------------------------------

def test_estimate_orderings():
    est = lambda **kw: pm.estimate_plan_seconds(64, 4, 2, **kw)
    assert est() > 0 and np.isfinite(est())
    # torus never beats switched (Eq. 5.5 vs 5.6) once folds communicate
    assert est(net="torus") >= est(net="switched")
    # pipelined overlap helps at equal engine count (Table 4.1, mu=1: (mu+1)/2 < 2mu)
    assert est(schedule="pipelined", chunks=4) < est(schedule="sequential")
    # block-granular ring overlap beats the serial ring it rides on — on
    # every communicating mesh, including the small ones (2x2, 2x1) where a
    # naive fill term would penalize the overlap below the serial sum
    for pu, pv in [(4, 2), (2, 2), (2, 1), (8, 8)]:
        e = lambda **kw: pm.estimate_plan_seconds(64, pu, pv, **kw)
        assert e(comm_engine="overlap_ring") < e(comm_engine="torus"), (pu, pv)
        assert np.isfinite(e(comm_engine="overlap_ring"))
    # comm_engine="torus" is the same point as the legacy net="torus"
    assert est(comm_engine="torus") == pytest.approx(est(net="torus"))
    # heavier engines rank behind jnp
    assert est(backend="pallas") > est(backend="ref") > est(backend="jnp")
    # single-rank grids pay no network time
    assert pm.estimate_plan_seconds(64, 1, 1) == pytest.approx(
        pm.estimate_plan_seconds(64, 1, 1, net="torus"))


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_cache_roundtrip(tmp_path):
    cache = PlanCache(str(tmp_path / "sub" / "plans.json"))
    assert cache.get("missing") is None
    cache.put("k1", {"best": {"backend": "jnp"}, "us_per_call": 1.0})
    cache.put("k2", {"best": {"backend": "mxu"}, "us_per_call": 2.0})
    assert cache.get("k1")["best"]["backend"] == "jnp"
    assert PlanCache(cache.path).keys() == ["k1", "k2"]
    # corrupt file degrades to empty, not an exception
    with open(cache.path, "w") as f:
        f.write("{not json")
    assert PlanCache(cache.path).get("k1") is None


def test_fingerprint_distinguishes_problems():
    import jax
    k1, p1 = problem_fingerprint(16, 2, 2)
    k2, _ = problem_fingerprint(16, 2, 2, real=True)
    k3, _ = problem_fingerprint(16, 4, 1)
    k4, _ = problem_fingerprint(16, 2, 2, dtype="float64")
    # the inverse-aware objective weights are part of the problem identity:
    # a forward-only winner must not be replayed for a fwd+inv solver
    k5, p5 = problem_fingerprint(16, 2, 2, fwd_weight=1.0, inv_weight=0.0)
    k6, _ = problem_fingerprint(16, 2, 2, fwd_weight=2.0, inv_weight=1.0)
    assert len({k1, k2, k3, k4, k5, k6}) == 6
    assert p5["fwd_weight"] == 1.0 and p5["inv_weight"] == 0.0
    assert p1["jax_version"] == jax.__version__ and p1["device_kind"]
    # stable across calls (canonical serialization); 1:1 is the default
    assert problem_fingerprint(16, 2, 2)[0] == k1
    assert problem_fingerprint(16, 2, 2, fwd_weight=1.0, inv_weight=1.0)[0] == k1


# ---------------------------------------------------------------------------
# end-to-end sweep (1 device)
# ---------------------------------------------------------------------------

def test_autotune_end_to_end(tmp_path, monkeypatch):
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    path = str(tmp_path / "plans.json")
    res = autotune(mesh, 8, cache_path=path, max_candidates=2, iters=1)
    assert not res.cache_hit
    assert res.rows and res.best_us > 0
    # winner is never slower than the hardcoded default plan
    default_rows = [r for r in res.rows
                    if Candidate.from_config(r["config"]) == DEFAULT_CANDIDATE]
    assert default_rows, "default plan must always be timed"
    assert res.best_us <= default_rows[0]["us_per_call"]
    assert os.path.exists(path)

    # second call: cache hit, and nothing may be re-timed
    def boom(*a, **k):
        raise AssertionError("cache hit must not re-time candidates")
    import importlib
    autotune_mod = importlib.import_module("repro.tuning.autotune")
    monkeypatch.setattr(autotune_mod, "time_candidate_pair", boom)
    res2 = autotune(mesh, 8, cache_path=path, max_candidates=2, iters=1)
    assert res2.cache_hit and res2.best_config == res.best_config

    # different problem = different key -> timing required again (the patched
    # timer fails every candidate, so the sweep comes up empty)
    with pytest.raises(RuntimeError, match="no candidate ran"):
        autotune(mesh, 8, real=True, cache_path=path, max_candidates=1, iters=1)


def test_inverse_aware_objective(tmp_path, monkeypatch):
    """Objective = w_fwd·t_fwd + w_inv·t_inv, and weights key the cache."""
    import importlib
    autotune_mod = importlib.import_module("repro.tuning.autotune")

    # deterministic fake timer: forward 100us; inverse 10us, except the
    # default candidate whose inverse is catastrophically slow (300us)
    def fake_pair(mesh, n, cand, *, time_inverse=True, **kw):
        if not time_inverse:
            return 100.0, 0.0
        return 100.0, (300.0 if cand == DEFAULT_CANDIDATE else 10.0)
    monkeypatch.setattr(autotune_mod, "time_candidate_pair", fake_pair)

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    path = str(tmp_path / "plans.json")
    # forward-only tuning: every candidate ties at 100us; inverse not timed
    res_fwd = autotune(mesh, 8, cache_path=path, max_candidates=2, iters=1,
                       inv_weight=0.0)
    assert all(r["us_per_call"] == 100.0 and r["us_inv"] == 0.0
               for r in res_fwd.rows)
    # 1:1 objective: default scores 400, everything else 110 — the slow
    # inverse disqualifies the default plan
    res = autotune(mesh, 8, cache_path=path, max_candidates=2, iters=1)
    assert res.key != res_fwd.key  # weights fingerprint separately
    assert not res.cache_hit
    default_rows = [r for r in res.rows
                    if Candidate.from_config(r["config"]) == DEFAULT_CANDIDATE]
    assert default_rows[0]["us_per_call"] == pytest.approx(400.0)
    assert res.best_us == pytest.approx(110.0)
    assert Candidate.from_config(res.best_config) != DEFAULT_CANDIDATE
    # reweighting is a different problem -> re-tuned, not replayed
    res_w = autotune(mesh, 8, cache_path=path, max_candidates=2, iters=1,
                     fwd_weight=2.0, inv_weight=1.0)
    assert res_w.key not in (res.key, res_fwd.key)
    assert res_w.best_us == pytest.approx(210.0)
    with pytest.raises(ValueError, match="weights"):
        autotune(mesh, 8, cache_path=path, fwd_weight=0.0, inv_weight=0.0)
    with pytest.raises(ValueError, match="iters"):
        autotune(mesh, 8, cache_path=path, iters=0, force=True)


def test_make_fft3d_autotune_integration(tmp_path):
    import jax.numpy as jnp

    from repro.core.fft3d import make_fft3d

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    path = str(tmp_path / "plans.json")
    # int n is accepted like autotune() itself accepts it
    fwd, inv, plan = make_fft3d(mesh, 8, autotune=True,
                                tune_kwargs=dict(cache_path=path,
                                                 max_candidates=2, iters=1))
    assert plan.n == (8, 8, 8)
    rng = np.random.RandomState(0)
    xr = jnp.asarray(rng.randn(8, 8, 8))
    xi = jnp.asarray(rng.randn(8, 8, 8))
    kr, ki = fwd(xr, xi)
    want = np.fft.fftn(np.asarray(xr) + 1j * np.asarray(xi)).transpose(2, 0, 1)
    got = np.asarray(kr) + 1j * np.asarray(ki)
    assert np.linalg.norm(got - want) / np.linalg.norm(want) < 1e-9
    br, bi = inv(kr, ki)
    assert np.allclose(np.asarray(br) + 1j * np.asarray(bi),
                       np.asarray(xr) + 1j * np.asarray(xi))


# ---------------------------------------------------------------------------
# CLI (subprocess: owns its XLA device-count flag)
# ---------------------------------------------------------------------------

def test_cli_writes_cache_and_bench_json(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    cache = str(tmp_path / "plans.json")
    bench = str(tmp_path / "BENCH_fft.json")
    cmd = [sys.executable, "-m", "repro.tuning.cli", "--n", "8", "--mesh",
           "1x1", "--iters", "1", "--max-candidates", "2",
           "--cache", cache, "--json", bench]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900, cwd=str(tmp_path))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "selected [measured sweep]" in out.stdout
    doc = json.load(open(bench))
    assert doc["schema"] == "bench-fft/v2"
    names = [r["name"] for r in doc["rows"]]
    assert any(n.endswith("/selected") for n in names)
    assert all({"name", "us_per_call", "config"} <= set(r) for r in doc["rows"])
    assert json.load(open(cache))["entries"]

    out2 = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=900, cwd=str(tmp_path))
    assert out2.returncode == 0, out2.stderr[-3000:]
    assert "cache HIT" in out2.stdout
