"""Autotuner subsystem: space enumeration, analytic pruning, plan cache,
and the end-to-end sweep (single device, Pu=Pv=1 — multi-device coverage
lives in the subprocess checks)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import compat
from repro.core import perfmodel as pm
from repro.tuning import (DEFAULT_CANDIDATE, Candidate, PlanCache, autotune,
                          candidate_space, problem_fingerprint)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# candidate space
# ---------------------------------------------------------------------------

def test_space_validity_rules():
    # single-rank grid: no torus (identical to switched), no vector modes
    cands = candidate_space(16, 1, 1)
    assert all(c.net == "switched" for c in cands)
    assert all(c.vector_mode == "streaming" for c in cands)
    assert all(not c.r2c_packed for c in cands)  # complex problem
    assert DEFAULT_CANDIDATE in cands

    # distributed grid: both nets; real pow2 problem: packed appears
    cands = candidate_space(16, 4, 2, real=True)
    assert {c.net for c in cands} == {"switched", "torus"}
    assert any(c.r2c_packed for c in cands)

    # vector problem sweeps both vector modes
    cands = candidate_space(16, 4, 2, components=3)
    assert {c.vector_mode for c in cands} == {"streaming", "parallel"}

    # non-power-of-two N: only XLA's general engine survives
    cands = candidate_space(12, 2, 1)
    assert {c.backend for c in cands} == {"jnp"}

    # sequential candidates always carry chunks=1
    assert all(c.chunks == 1 for c in cands if c.schedule == "sequential")


def test_candidate_roundtrip():
    c = Candidate(backend="mxu", schedule="pipelined", chunks=4, net="torus")
    assert Candidate.from_config(c.config()) == c
    assert Candidate.from_config(json.loads(json.dumps(c.config()))) == c


# ---------------------------------------------------------------------------
# analytic pruning model
# ---------------------------------------------------------------------------

def test_estimate_orderings():
    est = lambda **kw: pm.estimate_plan_seconds(64, 4, 2, **kw)
    assert est() > 0 and np.isfinite(est())
    # torus never beats switched (Eq. 5.5 vs 5.6) once folds communicate
    assert est(net="torus") >= est(net="switched")
    # pipelined overlap helps at equal engine count (Table 4.1, mu=1: (mu+1)/2 < 2mu)
    assert est(schedule="pipelined", chunks=4) < est(schedule="sequential")
    # heavier engines rank behind jnp
    assert est(backend="pallas") > est(backend="ref") > est(backend="jnp")
    # single-rank grids pay no network time
    assert pm.estimate_plan_seconds(64, 1, 1) == pytest.approx(
        pm.estimate_plan_seconds(64, 1, 1, net="torus"))


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------

def test_cache_roundtrip(tmp_path):
    cache = PlanCache(str(tmp_path / "sub" / "plans.json"))
    assert cache.get("missing") is None
    cache.put("k1", {"best": {"backend": "jnp"}, "us_per_call": 1.0})
    cache.put("k2", {"best": {"backend": "mxu"}, "us_per_call": 2.0})
    assert cache.get("k1")["best"]["backend"] == "jnp"
    assert PlanCache(cache.path).keys() == ["k1", "k2"]
    # corrupt file degrades to empty, not an exception
    with open(cache.path, "w") as f:
        f.write("{not json")
    assert PlanCache(cache.path).get("k1") is None


def test_fingerprint_distinguishes_problems():
    import jax
    k1, p1 = problem_fingerprint(16, 2, 2)
    k2, _ = problem_fingerprint(16, 2, 2, real=True)
    k3, _ = problem_fingerprint(16, 4, 1)
    k4, _ = problem_fingerprint(16, 2, 2, dtype="float64")
    assert len({k1, k2, k3, k4}) == 4
    assert p1["jax_version"] == jax.__version__ and p1["device_kind"]
    # stable across calls (canonical serialization)
    assert problem_fingerprint(16, 2, 2)[0] == k1


# ---------------------------------------------------------------------------
# end-to-end sweep (1 device)
# ---------------------------------------------------------------------------

def test_autotune_end_to_end(tmp_path, monkeypatch):
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    path = str(tmp_path / "plans.json")
    res = autotune(mesh, 8, cache_path=path, max_candidates=2, iters=1)
    assert not res.cache_hit
    assert res.rows and res.best_us > 0
    # winner is never slower than the hardcoded default plan
    default_rows = [r for r in res.rows
                    if Candidate.from_config(r["config"]) == DEFAULT_CANDIDATE]
    assert default_rows, "default plan must always be timed"
    assert res.best_us <= default_rows[0]["us_per_call"]
    assert os.path.exists(path)

    # second call: cache hit, and nothing may be re-timed
    def boom(*a, **k):
        raise AssertionError("cache hit must not re-time candidates")
    import importlib
    autotune_mod = importlib.import_module("repro.tuning.autotune")
    monkeypatch.setattr(autotune_mod, "time_candidate", boom)
    res2 = autotune(mesh, 8, cache_path=path, max_candidates=2, iters=1)
    assert res2.cache_hit and res2.best_config == res.best_config

    # different problem = different key -> timing required again (the patched
    # timer fails every candidate, so the sweep comes up empty)
    with pytest.raises(RuntimeError, match="no candidate ran"):
        autotune(mesh, 8, real=True, cache_path=path, max_candidates=1, iters=1)


def test_make_fft3d_autotune_integration(tmp_path):
    import jax.numpy as jnp

    from repro.core.fft3d import make_fft3d

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    path = str(tmp_path / "plans.json")
    fwd, inv, plan = make_fft3d(mesh, (8, 8, 8), autotune=True,
                                tune_kwargs=dict(cache_path=path,
                                                 max_candidates=2, iters=1))
    rng = np.random.RandomState(0)
    xr = jnp.asarray(rng.randn(8, 8, 8))
    xi = jnp.asarray(rng.randn(8, 8, 8))
    kr, ki = fwd(xr, xi)
    want = np.fft.fftn(np.asarray(xr) + 1j * np.asarray(xi)).transpose(2, 0, 1)
    got = np.asarray(kr) + 1j * np.asarray(ki)
    assert np.linalg.norm(got - want) / np.linalg.norm(want) < 1e-9
    br, bi = inv(kr, ki)
    assert np.allclose(np.asarray(br) + 1j * np.asarray(bi),
                       np.asarray(xr) + 1j * np.asarray(xi))


# ---------------------------------------------------------------------------
# CLI (subprocess: owns its XLA device-count flag)
# ---------------------------------------------------------------------------

def test_cli_writes_cache_and_bench_json(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    cache = str(tmp_path / "plans.json")
    bench = str(tmp_path / "BENCH_fft.json")
    cmd = [sys.executable, "-m", "repro.tuning.cli", "--n", "8", "--mesh",
           "1x1", "--iters", "1", "--max-candidates", "2",
           "--cache", cache, "--json", bench]
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=900, cwd=str(tmp_path))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "selected [measured sweep]" in out.stdout
    doc = json.load(open(bench))
    assert doc["schema"] == "bench-fft/v1"
    names = [r["name"] for r in doc["rows"]]
    assert any(n.endswith("/selected") for n in names)
    assert all({"name", "us_per_call", "config"} <= set(r) for r in doc["rows"])
    assert json.load(open(cache))["entries"]

    out2 = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=900, cwd=str(tmp_path))
    assert out2.returncode == 0, out2.stderr[-3000:]
    assert "cache HIT" in out2.stdout
