import jax

# f64 validation of the FFT engine requires x64 (model code is dtype-explicit
# everywhere, so enabling it globally is safe).
jax.config.update("jax_enable_x64", True)
