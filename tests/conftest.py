import jax
import pytest

# f64 validation of the FFT engine requires x64 (model code is dtype-explicit
# everywhere, so enabling it globally is safe).
jax.config.update("jax_enable_x64", True)


@pytest.fixture(autouse=True)
def _no_ambient_calibration():
    """Pin the perf model to its built-in priors for every test.

    A developer machine may carry a persisted ``calibration.json``
    (``repro.tuning.calibrate``); the model loads it lazily, which would
    make the analytic-model assertions here depend on local measurement
    noise. Tests that exercise the calibrated path install their own
    document explicitly via ``set_calibration``/``reset_calibration``.
    """
    from repro.core import perfmodel as pm

    pm.set_calibration(None)
    yield
    pm.set_calibration(None)
