"""Examples are part of the contract: run each end-to-end in a subprocess."""

import os
import subprocess
import sys


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script, *args, timeout=1500):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", script), *args],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-3000:])
    return out.stdout


def test_quickstart():
    out = _run("quickstart.py")
    assert "quickstart OK" in out


def test_navier_stokes():
    out = _run("navier_stokes.py", "--n", "16", "--steps", "4")
    assert "energy monotone decay: True" in out


def test_train_lm_short():
    out = _run("train_lm.py", "--steps", "30", timeout=2400)
    assert "loss:" in out


def test_serve_lm():
    out = _run("serve_lm.py")
    assert "serve_lm OK" in out
