"""``repro.obs`` — span nesting and attributes, the disabled-path no-op
guarantees the hot paths rely on, the counters/gauges registry, the
Chrome-trace-event export schema (what Perfetto loads), and the timing
helpers' percentile stats and donated-buffer guard."""

import json
import threading

import pytest

from repro import obs


@pytest.fixture(autouse=True)
def _obs_reset():
    # every test starts and ends disabled with empty global state, however
    # the test body left it
    obs.disable()
    obs.clear()
    yield
    obs.disable()
    obs.clear()


# ---------------------------------------------------------------------------
# disabled path: the zero-overhead contract
# ---------------------------------------------------------------------------

def test_disabled_span_is_shared_noop_singleton():
    assert not obs.is_enabled()
    s = obs.span("dispatch/x")
    assert s is obs.NULL_SPAN
    # attrs are accepted and dropped without recording anything
    with obs.span("dispatch/x", engine="torus") as sp:
        sp.set_attr(late=1)
    assert obs.tracer.events() == []


def test_disabled_metrics_record_nothing():
    obs.metrics.inc("comm.wire_bytes", 1024)
    obs.metrics.set_gauge("g", 3.0)
    assert obs.metrics.counters() == {}
    assert obs.metrics.gauges() == {}
    assert obs.metrics.get("comm.wire_bytes") == 0
    assert obs.metrics.get("missing", default=-1) == -1


def test_disabled_traced_call_is_transparent():
    calls = []

    def fn(a, b=0):
        calls.append((a, b))
        return a + b

    fn.custom_marker = "still-reachable"
    wrapped = obs.traced_call(fn, "dispatch/fn")
    assert wrapped(1, b=2) == 3
    assert calls == [(1, 2)]
    assert obs.tracer.events() == []
    # attribute access forwards to the wrapped function (jit surfaces like
    # .lower keep working on the wrapped object)
    assert wrapped.custom_marker == "still-reachable"


# ---------------------------------------------------------------------------
# enabled path: nesting, attributes, threads
# ---------------------------------------------------------------------------

def test_span_nesting_records_parent_and_depth():
    obs.enable()
    with obs.span("dispatch/outer", engine="torus"):
        with obs.span("trace/inner", round=3) as sp:
            sp.set_attr(bytes=64)
    events = {e["name"]: e for e in obs.tracer.events()}
    assert set(events) == {"dispatch/outer", "trace/inner"}
    outer, inner = events["dispatch/outer"], events["trace/inner"]
    assert outer["parent"] == "" and outer["depth"] == 0
    assert inner["parent"] == "dispatch/outer" and inner["depth"] == 1
    assert inner["args"] == {"round": 3, "bytes": 64}
    assert outer["args"] == {"engine": "torus"}
    # the inner interval sits inside the outer one
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6


def test_span_stacks_are_per_thread():
    obs.enable()
    ready = threading.Event()

    def worker():
        with obs.span("dispatch/worker"):
            ready.set()

    with obs.span("dispatch/main"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    events = {e["name"]: e for e in obs.tracer.events()}
    # the worker's span must not see the main thread's open span as parent
    assert events["dispatch/worker"]["parent"] == ""
    assert events["dispatch/worker"]["tid"] != events["dispatch/main"]["tid"]


def test_traced_call_records_dispatch_span_with_attrs():
    obs.enable()
    wrapped = obs.traced_call(lambda x: x * 2, "dispatch/fft3d.fwd",
                              attrs={"engine": "switched"})
    assert wrapped(21) == 42
    (ev,) = obs.tracer.events()
    assert ev["name"] == "dispatch/fft3d.fwd"
    assert ev["args"] == {"engine": "switched"}
    assert ev["dur"] >= 0


def test_capture_enables_then_disables():
    with obs.capture() as (tracer, metrics):
        assert obs.is_enabled()
        with obs.span("dispatch/x"):
            metrics.inc("k", 2)
    assert not obs.is_enabled()
    # recorded state stays readable after capture exits
    assert [e["name"] for e in tracer.events()] == ["dispatch/x"]
    assert metrics.get("k") == 2


def test_metrics_counters_accumulate_and_gauges_overwrite():
    obs.enable()
    obs.metrics.inc("comm.exchanges.data")
    obs.metrics.inc("comm.exchanges.data")
    obs.metrics.inc("comm.wire_bytes", 640)
    obs.metrics.set_gauge("link_bytes_per_s", 1e9)
    obs.metrics.set_gauge("link_bytes_per_s", 2e9)
    assert obs.metrics.get("comm.exchanges.data") == 2
    assert obs.metrics.get("comm.wire_bytes") == 640
    assert obs.metrics.get("link_bytes_per_s") == 2e9
    snap = obs.metrics.snapshot()
    assert snap["counters"]["comm.wire_bytes"] == 640
    assert snap["gauges"] == {"link_bytes_per_s": 2e9}


# ---------------------------------------------------------------------------
# Chrome-trace export (the document Perfetto / chrome://tracing load)
# ---------------------------------------------------------------------------

def test_chrome_trace_document_schema(tmp_path):
    obs.enable()
    with obs.span("dispatch/fft3d.fwd", engine="torus"):
        with obs.span("trace/fft3d.fold_xy", grid_dim="u"):
            pass
    obs.metrics.inc("comm.wire_bytes", 128)
    obs.disable()

    path = str(tmp_path / "trace.json")
    obs.write_chrome_trace(path, obs.tracer, obs.metrics,
                           meta={"devices": 8})
    with open(path) as f:
        doc = json.load(f)
    assert obs.validate_chrome_trace(doc) == []
    assert doc["displayTimeUnit"] == "ms"
    assert doc["meta"] == {"devices": 8}
    assert doc["metrics"]["counters"]["comm.wire_bytes"] == 128
    events = {e["name"]: e for e in doc["traceEvents"]}
    assert set(events) == {"dispatch/fft3d.fwd", "trace/fft3d.fold_xy"}
    ev = events["trace/fft3d.fold_xy"]
    assert ev["ph"] == "X" and ev["cat"] == "trace"
    assert ev["args"]["grid_dim"] == "u"
    assert ev["args"]["parent"] == "dispatch/fft3d.fwd"
    assert events["dispatch/fft3d.fwd"]["cat"] == "dispatch"


def test_validate_chrome_trace_flags_malformed_documents():
    assert obs.validate_chrome_trace({}) != []
    assert obs.validate_chrome_trace({"traceEvents": {}}) != []
    bad_event = {"traceEvents": [{"name": "x", "ph": "B", "ts": 0.0,
                                  "dur": 1.0, "pid": 1, "tid": 1}]}
    assert any("ph" in p for p in obs.validate_chrome_trace(bad_event))
    missing_key = {"traceEvents": [{"name": "x", "ph": "X"}]}
    assert obs.validate_chrome_trace(missing_key) != []


def test_summary_table_lists_spans_and_counters():
    obs.enable()
    with obs.span("dispatch/solver.step"):
        pass
    obs.metrics.inc("plan_cache.hits")
    obs.disable()
    table = obs.summary_table(obs.tracer, obs.metrics)
    assert "dispatch/solver.step" in table
    assert "plan_cache.hits" in table
    empty = obs.summary_table(obs.Tracer(), obs.Metrics())
    assert "no spans" in empty


# ---------------------------------------------------------------------------
# timing helpers: percentile stats + the donated-buffer guard
# ---------------------------------------------------------------------------

def test_time_stats_distribution_keys_and_order():
    from repro.tuning.timing import time_stats

    stats = time_stats(lambda x: x + 1, 1.0, iters=7)
    assert stats["iters"] == 7
    assert stats["min_us"] <= stats["p50_us"] <= stats["p95_us"]
    assert stats["mean_us"] > 0
    with pytest.raises(ValueError, match="iters"):
        time_stats(lambda x: x, 1.0, iters=0)


def test_timing_refuses_donated_inputs():
    from repro.tuning.timing import time_stats, time_us

    class FakeDonated:
        deleted = False

        def is_deleted(self):
            return self.deleted

    def donating_fn(a):
        a.deleted = True  # what a jit with donate_argnums does on warm-up
        return 0.0

    with pytest.raises(ValueError, match="donated"):
        time_us(donating_fn, FakeDonated())
    with pytest.raises(ValueError, match="donated"):
        time_stats(donating_fn, FakeDonated())
