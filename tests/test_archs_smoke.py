"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train-grad step + prefill/decode consistency on CPU; asserts shapes and
no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.models import (RunCfg, decode_step, forward, init_cache, init_model,
                          lm_loss, prefill)

RUN = RunCfg(mesh=None, remat=False)
B, S = 2, 16


def make_batch(cfg, b=B, s=S, seed=0):
    rng = np.random.RandomState(seed)
    batch = {}
    if cfg.embed_mode == "embeds":
        batch["embeds"] = jnp.asarray(rng.randn(b, s, cfg.d_model), jnp.float32)
        batch["labels"] = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
    elif cfg.embed_mode == "frames":
        batch["frames"] = jnp.asarray(rng.randn(b, s, cfg.d_model), jnp.float32)
        batch["tokens"] = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(rng.randint(0, cfg.vocab, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    params, axes = init_model(cfg, jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    batch = make_batch(cfg)
    logits, _ = jax.jit(lambda p, b: forward(cfg, RUN, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_grad_step(arch):
    cfg = get_config(arch, smoke=True)
    params, _ = init_model(cfg, jax.random.PRNGKey(1))
    batch = make_batch(cfg, seed=1)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: lm_loss(cfg, RUN, p, batch)))(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    norm = sum(float(jnp.sum(jnp.abs(g))) for g in flat)
    assert norm > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    """Decode after prefill must equal teacher-forced forward logits."""
    cfg = get_config(arch, smoke=True)
    if cfg.embed_mode == "embeds":
        pytest.skip("vlm decode continues from text tokens; covered below")
    params, _ = init_model(cfg, jax.random.PRNGKey(2))
    batch = make_batch(cfg, seed=2)
    full_logits, _ = jax.jit(lambda p, b: forward(cfg, RUN, p, b))(params, batch)

    pre = {k: (v[:, : S - 1] if v.ndim >= 2 and v.shape[1] == S else v)
           for k, v in batch.items()}
    if cfg.embed_mode == "frames":
        pre["frames"] = batch["frames"]  # encoder sees the full frames
    last, cache = jax.jit(lambda p, b: prefill(cfg, RUN, p, b, t_max=S + 4))(params, pre)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full_logits[:, S - 2]),
                               rtol=2e-2, atol=2e-2)
    tok = batch["tokens"][:, S - 1:S]
    logits, cache = jax.jit(
        lambda p, c, t: decode_step(cfg, RUN, p, c, t))(params, cache, tok)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["smollm-360m", "deepseek-v2-lite-16b",
                                  "jamba-1.5-large-398b", "rwkv6-3b"])
def test_decode_from_zero_cache(arch):
    cfg = get_config(arch, smoke=True)
    params, _ = init_model(cfg, jax.random.PRNGKey(3))
    cache = init_cache(cfg, B, t_max=8)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache = jax.jit(
        lambda p, c, t: decode_step(cfg, RUN, p, c, t))(params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["len"]) == 1


def test_shape_applicability_rules():
    skipped = {a for a in ARCH_IDS
               if not shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert skipped == {"llava-next-34b", "smollm-360m", "deepseek-7b",
                       "qwen1.5-4b", "gemma-2b", "deepseek-v2-lite-16b",
                       "qwen3-moe-30b-a3b", "whisper-small"}
    for a in ("rwkv6-3b", "jamba-1.5-large-398b"):
        ok, _ = shape_applicable(get_config(a), SHAPES["long_500k"])
        assert ok


def test_param_counts_match_billing():
    """Analytic param counts should land near the advertised sizes."""
    from repro.configs import count_active_params, count_params
    expect = {"rwkv6-3b": (3.0e9, 0.4), "smollm-360m": (3.6e8, 0.15),
              "deepseek-7b": (7e9, 0.15), "qwen1.5-4b": (4e9, 0.25),
              "gemma-2b": (2.5e9, 0.25), "deepseek-v2-lite-16b": (16e9, 0.25),
              "qwen3-moe-30b-a3b": (30e9, 0.25),
              "jamba-1.5-large-398b": (398e9, 0.15),
              "llava-next-34b": (34e9, 0.15)}
    for arch, (target, tol) in expect.items():
        n = count_params(get_config(arch))
        assert abs(n - target) / target < tol, (arch, n, target)
    a3 = count_active_params(get_config("qwen3-moe-30b-a3b"))
    assert 2e9 < a3 < 5e9, a3  # "A3B"
