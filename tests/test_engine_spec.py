"""EngineSpec / CommDAG API redesign: validation, the axis-labelled step
contract, and the post-deprecation surface — the pre-spec spellings
(``make_engine``, ``NetworkPlan.for_engine``, ``make_fft3d``'s kwarg tail,
``fold_phase``/``unfold_phase``) are gone, and only the spec spelling
remains.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import comm
from repro.core import topology as topo
from repro.core.decomposition import (CommDAG, CommStep, PencilGrid, XY_STEP,
                                      YZ_STEP, fft3d_dag)
from repro.core.engine_spec import (DEFAULT_SPEC, ENGINE_FABRIC, EngineSpec)
from repro.tuning.space import Candidate


# ---------------------------------------------------------------------------
# EngineSpec — the one configuration object
# ---------------------------------------------------------------------------

def test_engine_spec_defaults_and_fabric():
    s = DEFAULT_SPEC
    assert (s.engine, s.backend, s.schedule, s.chunks) == \
        ("switched", "jnp", "sequential", 1)
    assert not s.real and not s.r2c_packed and s.vector_mode == "streaming"
    assert not s.fused_roundtrip
    for name, fab in ENGINE_FABRIC.items():
        assert EngineSpec(engine=name).fabric == fab


def test_engine_spec_validation():
    with pytest.raises(ValueError, match="unknown comm engine"):
        EngineSpec(engine="carrier_pigeon")
    with pytest.raises(ValueError, match="schedule"):
        EngineSpec(schedule="eventually")
    with pytest.raises(ValueError, match="vector_mode"):
        EngineSpec(vector_mode="sideways")
    with pytest.raises(ValueError, match="chunks"):
        EngineSpec(chunks=0)
    # sequential normalizes the pipeline depth away
    assert EngineSpec(schedule="sequential", chunks=8).chunks == 1
    assert EngineSpec(schedule="pipelined", chunks=8).chunks == 8


def test_engine_spec_replace_and_frozen():
    s = EngineSpec(engine="pallas_ring")
    s2 = s.replace(chunks=4, schedule="pipelined")
    assert s2.engine == "pallas_ring" and s2.chunks == 4
    assert s.chunks == 1  # original untouched
    with pytest.raises(dataclasses.FrozenInstanceError):
        s.engine = "torus"


def test_candidate_spec_roundtrip():
    # tuning's Candidate and EngineSpec are two views of the same point
    for cand in (Candidate(),
                 Candidate(backend="pallas", schedule="pipelined", chunks=4,
                           comm_engine="bidi_ring", vector_mode="parallel",
                           r2c_packed=True),
                 Candidate(schedule="pipelined", chunks=2,
                           comm_engine="pallas_ring", fused_roundtrip=True)):
        assert Candidate.from_spec(cand.spec()) == cand
    spec = EngineSpec(engine="overlap_ring", backend="ref",
                      schedule="pipelined", chunks=2)
    assert Candidate.from_spec(spec).spec() == spec
    # `real` is a problem property, not a Candidate knob — spec() threads it
    assert Candidate().spec(real=True).real


# ---------------------------------------------------------------------------
# CommDAG — the axis-labelled communication plan
# ---------------------------------------------------------------------------

def test_comm_dag_contract():
    dag = fft3d_dag()
    assert [s.name for s in dag] == ["xy", "yz"]
    assert dag.step("xy").grid_dim == "u"
    assert dag.step("yz").grid_dim == "v"
    # unfold geometry is derived from the fold's: split/concat swap roles
    for s in dag:
        assert s.unfold_split == s.concat_offset
        assert s.unfold_concat == s.split_offset
        # both local permutes are involutions (fold and unfold share them)
        perm = s.permute
        assert tuple(perm[perm[i]] for i in range(3)) == (0, 1, 2)
    with pytest.raises(KeyError):
        dag.step("zz")
    # inverse walk reverses the steps
    assert [s.name for s in dag.inverse_steps()] == ["yz", "xy"]
    # the real (r2c) forward marks the X↔Y fold non-c2c, yz stays c2c
    rdag = fft3d_dag(real=True)
    assert not rdag.step("xy").c2c and rdag.step("yz").c2c


def test_comm_dag_validate_names_grid_dims():
    grid = PencilGrid(pu=2, pv=2, u_axes=("data",), v_axes=("model",))
    fft3d_dag().validate(grid)
    bogus = CommDAG(steps=(CommStep(name="ww", grid_dim="w", split_offset=-1,
                                    concat_offset=-3, permute=(2, 1, 0),
                                    slab_offset=-2),))
    with pytest.raises(ValueError):
        bogus.validate(grid)


def test_pencil_grid_per_axis_sizes():
    g = PencilGrid(pu=4, pv=2, u_axes=("pod", "data"), v_axes=("model",),
                   u_sizes=(2, 2))
    assert g.dim_sizes("u") == (2, 2) and g.dim_sizes("v") == (2,)
    assert g.dim_axes("u") == ("pod", "data")
    with pytest.raises(ValueError):
        g.dim_axes("w")
    with pytest.raises(ValueError):  # sizes must multiply to the dim extent
        PencilGrid(pu=4, pv=2, u_axes=("pod", "data"), v_axes=("model",),
                   u_sizes=(2, 3))
    # default: one axis carries the whole dimension
    assert PencilGrid(pu=4, pv=2).dim_sizes("u") == (4,)


# ---------------------------------------------------------------------------
# per-axis round pricing (mirrors the hypothesis versions in
# test_property.py, which only run where hypothesis is installed)
# ---------------------------------------------------------------------------

FACTORIZATIONS = [(2, 2), (4, 2), (2, 2, 2), (4, 4), (3, 2), (1, 4)]


@pytest.mark.parametrize("engine", list(ENGINE_FABRIC))
@pytest.mark.parametrize("sizes", FACTORIZATIONS)
def test_perfmodel_prices_per_axis_rounds(engine, sizes):
    from repro.core import perfmodel as pm

    fabric = ENGINE_FABRIC[engine]
    pu = int(np.prod(sizes))
    # message counts: Σ per-axis on the torus, one all-to-all on switched
    got = pm.fold_messages(sizes, fabric, engine)
    if fabric == "switched":
        assert got == 1
    else:
        assert got == sum(pm.fold_messages(q, fabric, engine) for q in sizes)
    assert pm.fold_messages(tuple(sizes) + (1,), fabric, engine) == got
    # staged per-axis rings never price worse than one flat product ring
    flat = pm.estimate_plan_seconds(64, pu, 2, comm_engine=engine)
    staged = pm.estimate_plan_seconds(64, pu, 2, comm_engine=engine,
                                      pu_axes=sizes)
    comm_axes = [q for q in sizes if q > 1]
    if fabric == "switched" or len(comm_axes) <= 1:
        assert staged == pytest.approx(flat)
    else:
        assert staged <= flat * (1 + 1e-12)
    # chunk model invariants survive per-axis pricing, kwargs or spec alike
    k = pm.optimal_chunks(64, pu, 2, comm_engine=engine, pu_axes=sizes)
    assert 1 <= k <= pm.MAX_MODEL_CHUNKS and (k & (k - 1)) == 0
    assert k == pm.optimal_chunks(64, pu, 2, spec=EngineSpec(engine=engine),
                                  pu_axes=sizes)
    with pytest.raises(ValueError):  # pu_axes must factor pu
        pm.estimate_plan_seconds(64, pu, 2, comm_engine=engine,
                                 pu_axes=(pu, 3))


# ---------------------------------------------------------------------------
# pre-spec spellings — removed after their deprecation cycle
# ---------------------------------------------------------------------------

GRID0 = PencilGrid(pu=1, pv=1, u_axes=(), v_axes=())


def test_pre_spec_spellings_removed():
    # the deprecation cycle ended: the shim surfaces no longer exist
    assert not hasattr(comm, "make_engine")
    assert not hasattr(topo.NetworkPlan, "for_engine")
    eng = comm.build_engine(EngineSpec(), GRID0)
    assert not hasattr(eng, "fold_phase")
    assert not hasattr(eng, "unfold_phase")
    # the spec spelling is the one way to a configured engine
    assert isinstance(
        comm.build_engine(EngineSpec(engine="overlap_ring", backend="ref",
                                     schedule="pipelined", chunks=4,
                                     real=True), GRID0),
        comm.OverlapRingEngine)
    with pytest.raises(ValueError, match="unknown comm engine"):
        EngineSpec(engine="carrier_pigeon")


def test_make_fft3d_rejects_legacy_kwarg_tail():
    from repro import compat
    from repro.core.fft3d import make_fft3d

    mesh = compat.make_mesh((1, 1), ("data", "model"))
    for bad in (dict(comm_engine="torus"), dict(net="torus"),
                dict(schedule="pipelined", chunks=2), dict(backend="jnp"),
                dict(carrier="pigeon")):
        with pytest.raises(TypeError, match="unexpected keyword"):
            make_fft3d(mesh, 8, **bad)
    # the spec spelling builds the configured plan
    _, _, plan = make_fft3d(mesh, 8, spec=EngineSpec(
        engine="torus", schedule="pipelined", chunks=2))
    assert plan.comm_engine == "torus"
    assert plan.schedule == "pipelined" and plan.chunks == 2


def test_run_fold_unfold_contract():
    import jax.numpy as jnp

    eng = comm.build_engine(EngineSpec(), GRID0)
    x = jnp.asarray(np.random.RandomState(0).randn(4, 4, 4))
    compute = lambda a: (a * 2.0,)
    step = XY_STEP
    # fold = compute, then relayout (on a 1x1 grid: just the local permute);
    # unfold = inverse relayout, then compute — their composition is the
    # pre-spec fold_phase/unfold_phase contract without the shim names
    (y,) = eng.run_fold(step, compute, (x,))
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(x).transpose(step.permute) * 2.0)
    (z,) = eng.run_unfold(step, compute, (y,))
    np.testing.assert_array_equal(np.asarray(z), np.asarray(x) * 4.0)
