"""``repro.checkpoint`` failure semantics: atomic visibility, keep-last-k
GC, the torn-LATEST scan fallback, and — the satellite this PR fixes — the
async writer surfacing its failure on the next ``wait()``/``save()``
instead of swallowing it. The torn writes come from the fleet's
deterministic fault injector (:func:`repro.fleet.faults.
arm_torn_checkpoint`), which reproduces exactly what a mid-write kill
leaves on disk.
"""

import os

import numpy as np
import pytest

from repro import obs
from repro.checkpoint.checkpoint import CheckpointError, CheckpointManager
from repro.fleet.faults import arm_torn_checkpoint


def _tree(v: float = 0.0):
    return {"fields": [np.full((4, 4), v), np.arange(8.0) + v],
            "t": np.float64(v), "n_steps": np.int64(int(v))}


def _assert_tree_equal(a, b):
    assert np.array_equal(a["fields"][0], b["fields"][0])
    assert np.array_equal(a["fields"][1], b["fields"][1])
    assert a["t"] == b["t"] and a["n_steps"] == b["n_steps"]


# ---------------------------------------------------------------------------
# roundtrip + GC + pointer fallback
# ---------------------------------------------------------------------------

def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=3)
    assert mgr.latest_step() is None
    mgr.save(2, _tree(2.0), meta={"case": "heat"}, block=True)
    assert mgr.latest_step() == 2
    assert mgr.last_save_bytes > 0
    tree, meta = mgr.restore(_tree(0.0))
    _assert_tree_equal(tree, _tree(2.0))
    assert meta["case"] == "heat" and meta["step"] == 2


def test_keep_last_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _tree(float(step)), block=True)
    kept = sorted(d for d in os.listdir(mgr.dir) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4
    # an old step is gone for good, not just unlisted
    with pytest.raises((KeyError, OSError, AssertionError)):
        mgr.restore(_tree(0.0), step=1)


def test_latest_step_scan_fallback_on_torn_pointer(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=3)
    mgr.save(1, _tree(1.0), block=True)
    mgr.save(2, _tree(2.0), block=True)
    ptr = os.path.join(mgr.dir, "LATEST")
    # pointer at a directory that was never completed
    with open(ptr, "w") as f:
        f.write("step_00000099")
    assert mgr.latest_step() == 2
    tree, _ = mgr.restore(_tree(0.0))
    _assert_tree_equal(tree, _tree(2.0))
    # no pointer at all: same scan
    os.remove(ptr)
    assert mgr.latest_step() == 2


# ---------------------------------------------------------------------------
# the async-writer error capture (the satellite fix)
# ---------------------------------------------------------------------------

def test_async_write_error_surfaces_on_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=3)
    mgr.save(2, _tree(2.0), block=True)
    arm_torn_checkpoint(mgr, at_step=4)
    mgr.save(4, _tree(4.0))            # async: returns without raising
    with pytest.raises(CheckpointError, match="injected torn checkpoint"):
        mgr.wait()
    # the torn tmp is invisible; the last complete snapshot still resolves
    assert mgr.latest_step() == 2
    tree, _ = mgr.restore(_tree(0.0))
    _assert_tree_equal(tree, _tree(2.0))
    # the error was consumed — the manager recovers, next save lands
    mgr.save(6, _tree(6.0), block=True)
    assert mgr.latest_step() == 6


def test_async_write_error_surfaces_on_next_save(tmp_path):
    # the implicit wait() at the head of save() re-raises too: a failed
    # async write can never masquerade as success across saves
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=3)
    arm_torn_checkpoint(mgr, at_step=0)
    mgr.save(2, _tree(2.0))
    with pytest.raises(CheckpointError, match="OSError"):
        mgr.save(4, _tree(4.0))
    mgr.save(6, _tree(6.0), block=True)   # fault fired once; recovered
    assert mgr.latest_step() == 6


def test_blocking_save_raises_inline(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=3)
    arm_torn_checkpoint(mgr, at_step=0)
    with pytest.raises(CheckpointError, match="injected torn checkpoint"):
        mgr.save(2, _tree(2.0), block=True)
    assert mgr.latest_step() is None


def test_sync_mode_raises_inline(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=3, async_write=False)
    arm_torn_checkpoint(mgr, at_step=0)
    with pytest.raises(CheckpointError, match="injected torn checkpoint"):
        mgr.save(2, _tree(2.0))
    mgr.save(4, _tree(4.0))
    assert mgr.latest_step() == 4


def test_checkpoint_metrics(tmp_path):
    with obs.capture() as (_, metrics):
        mgr = CheckpointManager(str(tmp_path / "ck"), keep=3)
        mgr.save(2, _tree(2.0), block=True)
        arm_torn_checkpoint(mgr, at_step=4)
        with pytest.raises(CheckpointError):
            mgr.save(4, _tree(4.0), block=True)
        mgr.restore(_tree(0.0))
    c = metrics.counters()
    assert c["checkpoint.saves"] == 2
    assert c["checkpoint.write_errors"] == 1
    assert c["checkpoint.restores"] == 1
    assert c["checkpoint.bytes"] == 2 * mgr.last_save_bytes
    assert metrics.gauges()["checkpoint.restore_us"] > 0
