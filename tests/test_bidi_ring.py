"""Bidirectional (two-NIC) ring exchange: round model, wire semantics, and
the engine round counter — without devices.

The ring schedules are *direct-send* (every wire value depends only on the
sender's local data, never on previously received blocks), so a two-pass
replay simulates all P ranks exactly: pass 1 records every rank's ppermute
sends with receives stubbed to zeros, pass 2 replays with the true received
values resolved from the recorded sends. The ppermute call sequence is
deterministic and identical across ranks, so the call index aligns the
rounds. (The distributed version of these properties — real ``shard_map``
over fake devices, incl. P=2 and odd-P meshes — runs in
``tests/_dist_transpose_check.py``.)
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import comm
from repro.core import transpose as tr
from repro.core.decomposition import PencilGrid

PS = (2, 3, 4, 5, 8)


def test_round_model():
    # the complexity claim of the bidirectional ring: ceil((P-1)/2) rounds
    for p in range(1, 33):
        assert tr.ring_rounds(p) == max(p - 1, 0)
        assert tr.bidi_rounds(p) == math.ceil((p - 1) / 2)
    # the engines' pure-python round models agree
    assert comm.OverlapRingEngine.wire_rounds(8) == 7
    assert comm.PallasRingEngine.wire_rounds(8) == 7
    assert comm.BidiRingEngine.wire_rounds(8) == 4
    assert comm.BidiRingEngine.wire_rounds(2) == 1   # P=2: one shared neighbor
    assert comm.BidiRingEngine.wire_rounds(5) == 2   # odd P: balanced split


class RingSimulator:
    """Replay a per-rank exchange function for all P ranks (see module doc)."""

    def __init__(self, p):
        self.p = p
        self.sends = {}       # call_idx -> {src_rank: np value}
        self.perms = {}       # call_idx -> {src: dst}
        self.wire_calls = 0   # ppermute calls of one rank's replay pass

    def run(self, monkeypatch, fn):
        """``fn(me) -> result`` under patched collectives; list per rank."""
        results = []
        for phase in ("record", "replay"):
            results = []
            for me in range(self.p):
                counter = {"i": 0}

                def fake_ppermute(x, name, perm, *, _me=me, _c=counter,
                                  _phase=phase):
                    i = _c["i"]
                    _c["i"] += 1
                    if _phase == "record":
                        self.sends.setdefault(i, {})[_me] = np.asarray(x)
                        self.perms[i] = dict(perm)
                        return jnp.zeros_like(x)
                    src = next(s for s, d in self.perms[i].items() if d == _me)
                    return jnp.asarray(self.sends[i][src])

                monkeypatch.setattr(tr, "_ppermute", fake_ppermute)
                monkeypatch.setattr(tr, "_axis_size", lambda axes: self.p)
                monkeypatch.setattr(tr, "_flat_axis_index",
                                    lambda axes, _me=me: _me)
                monkeypatch.setattr(compat, "axes_size",
                                    lambda axes: self.p)
                monkeypatch.setattr(compat, "flat_axis_index",
                                    lambda axes, _me=me: _me)
                results.append(fn(me))
                self.wire_calls = counter["i"]
        return results


def _locals(p, seed=0):
    rng = np.random.RandomState(seed)
    return [jnp.asarray(rng.randn(2 * p, 3)) for _ in range(p)]


def _expected_all_to_all(xs, p):
    """Tiled all-to-all semantics: rank me's output is, slot by slot, block
    ``me`` of every source rank, merged rank-major along the concat axis."""
    out = []
    for me in range(p):
        blocks = [np.asarray(x).reshape(p, 2, 3)[me] for x in xs]  # src-major
        out.append(np.stack(blocks, axis=1).reshape(2, 3 * p))
    return out


@pytest.mark.parametrize("p", PS)
def test_bidi_exchange_matches_ring_and_all_to_all(p, monkeypatch):
    xs = _locals(p)
    want = _expected_all_to_all(xs, p)

    def uni(me):
        outs, _ = tr.ring_exchange((xs[me],), ("data",), split_axis=0,
                                   concat_axis=1)
        return np.asarray(outs[0])

    def bidi(me):
        outs, _ = tr.ring_exchange_bidi((xs[me],), ("data",), split_axis=0,
                                        concat_axis=1)
        return np.asarray(outs[0])

    got_uni = RingSimulator(p).run(monkeypatch, uni)
    sim = RingSimulator(p)
    got_bidi = sim.run(monkeypatch, bidi)
    for me in range(p):
        np.testing.assert_array_equal(got_bidi[me], want[me])
        np.testing.assert_array_equal(got_bidi[me], got_uni[me])
    # same total wire traffic (every foreign block crosses the wire once):
    # P-1 sends per rank, just split across the two directions
    assert sim.wire_calls == p - 1


@pytest.mark.parametrize("p", PS)
def test_bidi_engine_round_counter(p, monkeypatch):
    # the engine's exchange_rounds counter pins the complexity claim:
    # ceil((P-1)/2) rounds per exchange vs P-1 for the unidirectional rings
    grid = PencilGrid(pu=p, pv=1, u_axes=("data",), v_axes=())
    engines = {name: [comm.build_engine(comm.EngineSpec(engine=name), grid) for _ in range(p)]
               for name in ("overlap_ring", "bidi_ring")}
    xs = _locals(p)

    for name, per_rank in engines.items():
        def fn(me, _eng=per_rank, _name=name):
            eng = _eng[me]
            eng.exchange_rounds = 0   # the simulator runs two passes
            outs, _ = eng._exchange((xs[me],), ("data",), split_axis=0,
                                    concat_axis=1)
            return np.asarray(outs[0])

        got = RingSimulator(p).run(monkeypatch, fn)
        for me in range(p):
            np.testing.assert_array_equal(got[me],
                                          _expected_all_to_all(xs, p)[me])
        want = (math.ceil((p - 1) / 2) if name == "bidi_ring" else p - 1)
        assert all(e.exchange_rounds == want for e in per_rank), name


def test_bidi_interleave_thunk_runs_once(monkeypatch):
    p = 4
    xs = _locals(p)
    calls = []

    def fn(me):
        _, follow = tr.ring_exchange_bidi(
            (xs[me],), ("data",), split_axis=0, concat_axis=1,
            interleave=lambda: calls.append(me) or "butterflies-ran")
        return follow

    follows = RingSimulator(p).run(monkeypatch, fn)
    assert follows == ["butterflies-ran"] * p
    # one thunk per rank per pass (record + replay), emitted after the
    # first round's sends — never re-run on later rounds
    assert len(calls) == 2 * p


def test_bidi_engine_degenerate_grid_local_transposes():
    # on the 1x1 grid nothing communicates: folds reduce to pure local
    # transposes and unfold∘fold is the identity (no devices involved)
    grid = PencilGrid(pu=1, pv=1, u_axes=(), v_axes=())
    eng = comm.build_engine(comm.EngineSpec(engine="bidi_ring"), grid)
    x = jnp.asarray(np.random.RandomState(0).randn(4, 4, 4))
    for which in ("xy", "yz"):
        back = eng.unfold(which, eng.fold(which, x))
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
    assert eng.exchange_rounds == 0
