"""The bench regression gate (``benchmarks/compare.py``): row matching,
threshold semantics, exit codes, the soft-pass path CI relies on for
the first run (no baseline artifact yet), v1/v2 schema interop, and the
predicted-vs-measured model-drift gate."""

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _doc(rows, meta=None, schema="bench-fft/v1"):
    return {"schema": schema, "meta": meta or {}, "rows": rows}


def _write(path, rows, meta=None, schema="bench-fft/v1"):
    with open(path, "w") as f:
        json.dump(_doc(rows, meta, schema), f)
    return str(path)


def _mrow(name, us, err):
    """A v2 measured row carrying a perf-model prediction with signed
    relative error ``err`` (measured/predicted - 1)."""
    return {"name": name, "us_per_call": us, "config": {},
            "model_predicted_us": round(us / (1.0 + err), 3),
            "model_err": err}


def _run(*args):
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.compare", *args],
        cwd=REPO, capture_output=True, text=True, timeout=120)


def test_pass_and_regression_exit_codes(tmp_path):
    base = _write(tmp_path / "base.json", [
        {"name": "fft_overlap_ring/N16/fwd", "us_per_call": 100.0, "config": {}},
        {"name": "fft_switched/N16/fwd", "us_per_call": 50.0, "config": {}},
        {"name": "table4.1/analytic", "us_per_call": 0.0, "config": {}},
        {"name": "only_in_base", "us_per_call": 10.0, "config": {}},
    ])
    ok = _write(tmp_path / "ok.json", [
        {"name": "fft_overlap_ring/N16/fwd", "us_per_call": 110.0, "config": {}},
        {"name": "fft_switched/N16/fwd", "us_per_call": 30.0, "config": {}},
        {"name": "table4.1/analytic", "us_per_call": 0.0, "config": {}},
        {"name": "only_in_new", "us_per_call": 10.0, "config": {}},
    ])
    out = _run(base, ok, "--threshold", "0.15")
    assert out.returncode == 0, out.stdout + out.stderr
    assert "improved" in out.stdout and "OK" in out.stdout

    bad = _write(tmp_path / "bad.json", [
        {"name": "fft_overlap_ring/N16/fwd", "us_per_call": 120.0, "config": {}},
        {"name": "fft_switched/N16/fwd", "us_per_call": 50.0, "config": {}},
    ])
    out = _run(base, bad, "--threshold", "0.15")
    assert out.returncode == 1
    assert "REGRESSED fft_overlap_ring/N16/fwd" in out.stdout
    # a looser gate lets the same diff through
    assert _run(base, bad, "--threshold", "0.25").returncode == 0


def test_analytic_rows_never_gate(tmp_path):
    # us_per_call == 0 rows are model-derived, not measurements
    base = _write(tmp_path / "base.json",
                  [{"name": "table5.7/N512", "us_per_call": 0.0, "config": {}}])
    new = _write(tmp_path / "new.json",
                 [{"name": "table5.7/N512", "us_per_call": 0.0, "config": {}}])
    out = _run(base, new)
    assert out.returncode == 0
    assert "no measured rows in common" in out.stdout


def test_ignore_globs_exclude_noisy_rows(tmp_path):
    # low-iteration autotune sweep rows are excluded from the gate by glob
    base = _write(tmp_path / "base.json", [
        {"name": "autotune/key/jnp/seq", "us_per_call": 10.0, "config": {}},
        {"name": "fft_switched/fwd", "us_per_call": 50.0, "config": {}},
    ])
    new = _write(tmp_path / "new.json", [
        {"name": "autotune/key/jnp/seq", "us_per_call": 100.0, "config": {}},
        {"name": "fft_switched/fwd", "us_per_call": 50.0, "config": {}},
    ])
    assert _run(base, new).returncode == 1  # gated without --ignore
    out = _run(base, new, "--ignore", "autotune/*")
    assert out.returncode == 0, out.stdout
    assert "ignoring 1 row" in out.stdout
    # ignoring everything leaves no overlap -> soft pass
    out = _run(base, new, "--ignore", "autotune/*", "--ignore", "fft_*")
    assert out.returncode == 0
    assert "no measured rows in common" in out.stdout


def test_min_us_noise_floor(tmp_path):
    # sub-floor baseline rows are scheduler jitter, not signal
    base = _write(tmp_path / "base.json", [
        {"name": "tiny", "us_per_call": 100.0, "config": {}},
        {"name": "big", "us_per_call": 5000.0, "config": {}},
    ])
    new = _write(tmp_path / "new.json", [
        {"name": "tiny", "us_per_call": 200.0, "config": {}},
        {"name": "big", "us_per_call": 5100.0, "config": {}},
    ])
    assert _run(base, new).returncode == 1  # tiny row gates by default
    out = _run(base, new, "--min-us", "500")
    assert out.returncode == 0, out.stdout
    assert "below the noise floor" in out.stdout
    # the floor never exempts rows that are actually slow
    slow = _write(tmp_path / "slow.json", [
        {"name": "big", "us_per_call": 9000.0, "config": {}}])
    assert _run(base, slow, "--min-us", "500").returncode == 1


def test_substrate_change_soft_passes(tmp_path):
    # a 10x "regression" measured on a different substrate (device count,
    # platform, jax version...) is not comparable — soft pass, not failure
    rows_base = [{"name": "fft_switched/fwd", "us_per_call": 10.0, "config": {}}]
    rows_new = [{"name": "fft_switched/fwd", "us_per_call": 100.0, "config": {}}]
    meta = {"platform": "cpu", "device_kind": "cpu", "devices": 8, "jax": "x"}
    base = _write(tmp_path / "base.json", rows_base, meta)
    new = _write(tmp_path / "new.json", rows_new, {**meta, "devices": 1})
    out = _run(base, new)
    assert out.returncode == 0
    assert "substrate changed" in out.stdout and "soft pass" in out.stdout
    assert _run(base, new, "--strict").returncode == 2
    # same substrate: the regression gates as usual
    same = _write(tmp_path / "same.json", rows_new, meta)
    assert _run(base, same).returncode == 1


def test_missing_baseline_soft_pass_and_strict(tmp_path):
    new = _write(tmp_path / "new.json",
                 [{"name": "a", "us_per_call": 1.0, "config": {}}])
    missing = str(tmp_path / "nope.json")
    out = _run(missing, new)
    assert out.returncode == 0
    assert "soft pass" in out.stdout
    assert _run(missing, new, "--strict").returncode == 2

    # unreadable/wrong-schema new document is always an error
    garbage = str(tmp_path / "garbage.json")
    with open(garbage, "w") as f:
        f.write("{not json")
    assert _run(new, garbage).returncode == 2


def test_expect_glob_keeps_workloads_on_trajectory(tmp_path):
    rows = [{"name": "solver_poisson/N16/mesh4x2/us_per_step",
             "us_per_call": 900.0, "config": {}}]
    base = _write(tmp_path / "base.json", rows)
    new = _write(tmp_path / "new.json", rows)
    assert _run(base, new, "--expect", "solver_*").returncode == 0
    # a new document that stopped emitting the workload fails, even when
    # there is no baseline at all (first CI run)
    empty = _write(tmp_path / "empty.json",
                   [{"name": "fft_switched/fwd", "us_per_call": 1.0,
                     "config": {}}])
    out = _run(base, empty, "--expect", "solver_*")
    assert out.returncode == 2 and "fell off the perf trajectory" in out.stdout
    missing = str(tmp_path / "nope.json")
    assert _run(missing, empty, "--expect", "solver_*").returncode == 2
    assert _run(missing, new, "--expect", "solver_*").returncode == 0


def test_expect_comma_separated_globs(tmp_path):
    # one --expect argument can carry several comma-separated globs, each of
    # which must match independently (the CI engine-row gate)
    rows = [{"name": "fft_overlap_ring/N16/mesh4x2/fwd", "us_per_call": 700.0,
             "config": {}},
            {"name": "fft_pallas_ring/N16/mesh4x2/fwd", "us_per_call": 800.0,
             "config": {}}]
    both = _write(tmp_path / "both.json", rows)
    one = _write(tmp_path / "one.json", rows[:1])
    missing = str(tmp_path / "nope.json")
    glob = "fft_overlap_ring*,fft_pallas_ring*"
    assert _run(missing, both, "--expect", glob).returncode == 0
    # dropping either engine's rows fails the gate, baseline or not
    out = _run(missing, one, "--expect", glob)
    assert out.returncode == 2 and "fft_pallas_ring*" in out.stdout
    # equivalent to passing the globs as separate repeated flags
    assert _run(missing, both, "--expect", "fft_overlap_ring*",
                "--expect", "fft_pallas_ring*").returncode == 0
    assert _run(missing, one, "--expect", "fft_overlap_ring*",
                "--expect", "fft_pallas_ring*").returncode == 2


def test_v2_schema_interop_with_v1_baseline(tmp_path):
    # a v1 baseline diffs against a v2 document (and vice versa): the
    # measured-row comparison only needs name/us_per_call
    base = _write(tmp_path / "base.json",
                  [{"name": "fft_switched/fwd", "us_per_call": 100.0,
                    "config": {}}])
    new = _write(tmp_path / "new.json", [_mrow("fft_switched/fwd", 101.0, 0.05)],
                 schema="bench-fft/v2")
    assert _run(base, new).returncode == 0
    assert _run(new, base).returncode == 0
    # an unknown schema generation is still rejected
    bad = _write(tmp_path / "bad.json", [], schema="bench-fft/v99")
    assert _run(base, bad).returncode == 2


def test_model_drift_gate_fails_on_drift_alone(tmp_path):
    rows_ok = [_mrow("fft_a/fwd", 100.0, 0.05), _mrow("fft_b/fwd", 200.0, -0.04),
               _mrow("fft_c/fwd", 300.0, 0.06)]
    base = _write(tmp_path / "base.json", rows_ok, schema="bench-fft/v2")
    same = _write(tmp_path / "same.json", rows_ok, schema="bench-fft/v2")
    out = _run(base, same, "--model-drift-threshold", "0.5")
    assert out.returncode == 0, out.stdout
    assert "model drift" in out.stdout and "OK" in out.stdout

    # measured times unchanged (no perf regression) but the predictions
    # walked away from reality -> the drift gate alone fails the run
    rows_bad = [_mrow("fft_a/fwd", 100.0, 0.9), _mrow("fft_b/fwd", 200.0, -0.04),
                _mrow("fft_c/fwd", 300.0, 0.85)]
    bad = _write(tmp_path / "bad.json", rows_bad, schema="bench-fft/v2")
    out = _run(base, bad, "--model-drift-threshold", "0.5")
    assert out.returncode == 1, out.stdout
    assert "model drifted" in out.stdout
    # without the flag the gate is off and the same documents pass
    assert _run(base, bad).returncode == 0
    # --ignore excludes rows from the drift median too: dropping the two
    # drifted rows leaves only the healthy one and the gate passes
    out = _run(base, bad, "--model-drift-threshold", "0.5",
               "--ignore", "fft_a/*", "--ignore", "fft_c/*")
    assert out.returncode == 0, out.stdout


def test_model_drift_gate_requires_predictions_in_new_doc(tmp_path):
    # the gate guards the model's health: a new document that stopped
    # emitting predictions fails loud (like --expect), baseline or not
    base = _write(tmp_path / "base.json", [_mrow("a", 100.0, 0.05)],
                  schema="bench-fft/v2")
    plain = _write(tmp_path / "plain.json",
                   [{"name": "a", "us_per_call": 100.0, "config": {}}])
    out = _run(base, plain, "--model-drift-threshold", "0.5")
    assert out.returncode == 2
    assert "no model_err rows" in out.stdout
    missing = str(tmp_path / "nope.json")
    assert _run(missing, plain,
                "--model-drift-threshold", "0.5").returncode == 2


def test_model_drift_gate_pre_v2_baseline_soft_records(tmp_path):
    # a pre-v2 baseline artifact has no error reference yet: record this
    # run's error as the new reference instead of gating against nothing
    base = _write(tmp_path / "base.json",
                  [{"name": "a", "us_per_call": 100.0, "config": {}}])
    new = _write(tmp_path / "new.json", [_mrow("a", 100.0, 0.9)],
                 schema="bench-fft/v2")
    out = _run(base, new, "--model-drift-threshold", "0.5")
    assert out.returncode == 0, out.stdout
    assert "new reference" in out.stdout


def test_bench_run_list_prints_workload_names():
    # --list is the discovery aid for the exit-2 unknown-name path: every
    # known --only workload, one per line, no benchmark executed
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--list"],
        cwd=REPO, capture_output=True, text=True, timeout=120,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert out.returncode == 0, out.stderr[-2000:]
    names = out.stdout.split()
    assert names == sorted(names)
    assert {"fft_engines", "fft_wallclock", "solvers", "fft_autotune"} <= set(names)
    assert "name,us_per_call,derived" not in out.stdout  # nothing ran


def test_bench_run_unknown_only_name_fails(tmp_path):
    # a typo'd --only must exit non-zero instead of emitting an empty
    # document the perf gate would then wave through
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "fft_enginez",
         "--json", str(tmp_path / "out.json")],
        cwd=REPO, capture_output=True, text=True, timeout=120,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert out.returncode != 0
    assert "fft_enginez" in out.stderr
    assert not (tmp_path / "out.json").exists()
