"""Driver-level fault tolerance: kill training, relaunch, verify exact
resume (checkpoint + stateless data pipeline ⇒ the restarted run continues
the original loss trajectory)."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _train(ckpt, steps, extra=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "smollm-360m",
         "--smoke", "--steps", str(steps), "--batch", "4", "--seq", "64",
         "--ckpt-dir", ckpt, "--ckpt-every", "5", "--log-every", "1", *extra],
        env=env, capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _losses(stdout):
    out = {}
    for line in stdout.splitlines():
        if line.startswith("step"):
            parts = line.split()
            out[int(parts[1])] = float(parts[3])
    return out


def test_kill_and_resume_continues_trajectory(tmp_path):
    ck = str(tmp_path / "ck")
    # uninterrupted reference run
    ref = _losses(_train(str(tmp_path / "ref"), 12))
    # "crash" after 7 steps (same schedule constants), then resume
    out1 = _train(ck, 12, extra=("--halt-after", "7"))
    assert "[halt]" in out1
    out2 = _train(ck, 12)
    assert "[resume] from step" in out2
    got = _losses(out2)
    # steps after resume must match the uninterrupted trajectory exactly
    for step in (8, 9, 10, 11):
        assert abs(got[step] - ref[step]) < 1e-4, (step, got[step], ref[step])
