"""Multi-device distributed-FFT correctness checks (run in a subprocess so
the fake-device XLA flag doesn't leak into the main pytest process).

Usage: python tests/_dist_fft_check.py [--mesh PUxPV|AxBxC] [--engine NAME]
(expects PYTHONPATH=src). ``--mesh AxBxC`` builds the 3-axis
``("pod", "data", "model")`` mesh with the u grid dimension spanning
``("pod", "data")`` — the staged per-axis transpose path. ``--engine``
restricts the comm-engine sweep to one engine (the CI mesh-shape ×
comm-engine matrix runs one cell per job); the full run also covers
backends, packed r2c, vector modes, and the multi-axis mesh. Prints
CHECK <name> OK / raises on failure. Final line: ALL_OK.
"""

import argparse
import math
import sys

from repro.launch.mesh import ensure_host_devices


def _parse_mesh(spec: str) -> tuple[int, ...]:
    dims = tuple(int(t) for t in spec.lower().split("x"))
    if len(dims) not in (2, 3) or any(d < 1 for d in dims):
        raise SystemExit(f"bad --mesh {spec!r}; want e.g. 4x2 or 2x2x2")
    return dims


# the fake-device flag must be set before jax initializes, and the count
# depends on the --mesh argument — peek at argv ahead of argparse
_dims = (4, 2)
if "--mesh" in sys.argv[:-1]:
    _dims = _parse_mesh(sys.argv[sys.argv.index("--mesh") + 1])
ensure_host_devices(max(8, math.prod(_dims)))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import compat  # noqa: E402
from repro.core.engine_spec import EngineSpec  # noqa: E402
from repro.core.fft3d import make_fft3d  # noqa: E402


def rel(a, b):
    a, b = np.asarray(a, np.complex128), np.asarray(b, np.complex128)
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30)


def expected_c2c(g):
    return np.fft.fftn(np.asarray(g, np.complex128), axes=(0, 1, 2)).transpose(2, 0, 1)


def check_wire_metrics(mesh, n, axes_kw, engines, xr, xi):
    """The ``repro.obs`` trace-time wire counters must pin each engine's
    analytic round complexity: per communicating mesh axis (or product
    group, for the switched crossbar), ``comm.exchange_rounds.<ax>`` ==
    ``comm.exchanges.<ax>`` × the engine's ``wire_rounds(q)``."""
    from repro import obs
    from repro.core import transpose as tr
    from repro.core.comm import ENGINES

    sizes = dict(mesh.shape)
    for name in engines:
        with obs.capture() as (_tracer, met):
            fwd, _inv, _plan = make_fft3d(mesh, n,
                                          spec=EngineSpec(engine=name),
                                          **axes_kw)
            fwd(xr, xi)
        if name == "switched":
            # one all_to_all per fold over the (possibly multi-axis)
            # product group — a single crossbar round whatever its size
            assert met.get("comm.all_to_all_dispatches") > 0, met.snapshot()
            groups = [("*".join(g), math.prod(sizes[a] for a in g))
                      for g in (axes_kw["u_axes"], axes_kw["v_axes"])]
            per_exchange = lambda q: 1  # noqa: E731
        else:
            # ring engines transpose axis-by-axis (the staged multi-axis
            # path), so the counters carry per-axis labels
            groups = [(a, sizes[a])
                      for g in (axes_kw["u_axes"], axes_kw["v_axes"])
                      for a in g]
            per_exchange = getattr(ENGINES[name], "wire_rounds",
                                   tr.ring_rounds)
        for ax, q in groups:
            if q <= 1:  # 1-rank dimension: the exchange degenerates away
                continue
            n_ex = met.get(f"comm.exchanges.{ax}")
            assert n_ex > 0, (name, ax, met.snapshot())
            got = met.get(f"comm.exchange_rounds.{ax}")
            want = n_ex * per_exchange(q)
            assert got == want, (name, ax, got, want, met.snapshot())
        assert met.get("comm.wire_bytes") > 0, (name, met.snapshot())
        print(f"CHECK wire_metrics_{name} OK", flush=True)


def run(dims: tuple[int, ...] = (4, 2), engine: str = ""):
    if len(dims) == 2:
        mesh = compat.make_mesh(dims, ("data", "model"))
        axes_kw = dict(u_axes=("data",), v_axes=("model",))
    else:
        mesh = compat.make_mesh(dims, ("pod", "data", "model"))
        axes_kw = dict(u_axes=("pod", "data"), v_axes=("model",))
    n = (16, 16, 16)
    ny, nz, nx = 16, 16, 16
    rng = np.random.RandomState(0)
    g_re = rng.randn(ny, nz, nx)
    g_im = rng.randn(ny, nz, nx)
    want = expected_c2c(g_re + 1j * g_im)

    xr = jnp.asarray(g_re)
    xi = jnp.asarray(g_im)

    if engine:
        # one matrix cell: the selected engine sequential + pipelined, and
        # (below) its r2c path — vs the same analytic NumPy reference
        configs = [
            (engine, EngineSpec(engine=engine)),
            (f"{engine}_pipelined4",
             EngineSpec(engine=engine, schedule="pipelined", chunks=4)),
        ]
    else:
        configs = [
            ("switched_seq", EngineSpec()),
            ("torus", EngineSpec(engine="torus")),
            ("overlap_ring", EngineSpec(engine="overlap_ring")),
            ("pallas_ring", EngineSpec(engine="pallas_ring")),
            ("pipelined4", EngineSpec(schedule="pipelined", chunks=4)),
            ("pallas_backend", EngineSpec(backend="pallas")),
            ("ref_backend", EngineSpec(backend="ref")),
        ]
    base = None
    for name, cfg in configs:
        fwd, inv, plan = make_fft3d(mesh, n, spec=cfg, **axes_kw)
        kr, ki = fwd(xr, xi)
        got = np.asarray(kr) + 1j * np.asarray(ki)
        assert rel(got, want) < 1e-9, (name, rel(got, want))
        if base is None:
            base = got
        else:
            assert rel(got, base) < 1e-9, name
        br, bi = inv(kr, ki)
        assert rel(np.asarray(br) + 1j * np.asarray(bi), g_re + 1j * g_im) < 1e-9, name
        print("CHECK", name, "OK", flush=True)

    # real-to-complex path (paper §3.2.5 data model)
    fwd, inv, plan = make_fft3d(
        mesh, n, spec=EngineSpec(engine=engine or "switched", real=True),
        **axes_kw)
    kr, ki = fwd(xr)
    keep = nx // 2 + 1
    wr = np.fft.fftn(np.fft.rfft(g_re, axis=2), axes=(0, 1)).transpose(2, 0, 1)
    got = (np.asarray(kr) + 1j * np.asarray(ki))[:keep]
    assert rel(got, wr) < 1e-9, rel(got, wr)
    back = inv(kr, ki)
    assert rel(np.asarray(back), g_re) < 1e-9
    print("CHECK r2c OK", flush=True)

    # fused spectral roundtrip: forward → diagonal multiply → inverse with
    # the Y↔Z phase pair streamed through run_roundtrip must match the
    # composed three-phase path to 1e-10 — on this mesh shape (including
    # the 3-axis staged-transpose cell, where no solver check runs) and,
    # in the CI matrix, on this comm engine
    from repro.core import spectral as sp
    from repro.core.decomposition import PencilGrid
    from repro.core.fft3d import (DiagonalKernel, FFT3DPlan,
                                  spectral_roundtrip_local)

    grid = PencilGrid.from_mesh(mesh, **axes_kw)
    pspec = grid.pencil_spec()
    fused_engines = [engine] if engine else ["switched", "overlap_ring",
                                             "pallas_ring"]
    for ename in fused_engines:
        for schedule, chunks in (("sequential", 1), ("pipelined", 2)):
            outs = {}
            for fuse in (False, True):
                plan = FFT3DPlan(n=n, grid=grid, comm_engine=ename,
                                 schedule=schedule, chunks=chunks,
                                 fused_roundtrip=fuse)

                def local(ar, ai, plan=plan):
                    # heat-like decay in k-space, built rank-local like the
                    # solvers build theirs
                    kern = DiagonalKernel(
                        dr=jnp.exp(-5e-3 * sp.k_squared(plan, ar.dtype)))
                    return spectral_roundtrip_local(plan, kern, ar, ai)

                f = jax.jit(compat.shard_map(
                    local, mesh=mesh, in_specs=(pspec, pspec),
                    out_specs=(pspec, pspec), check_vma=False))
                rr, ri = f(xr, xi)
                outs[fuse] = np.asarray(rr) + 1j * np.asarray(ri)
            diff = np.max(np.abs(outs[True] - outs[False]))
            assert diff < 1e-10, (ename, schedule, diff)
            tag = "seq" if schedule == "sequential" else f"pipe{chunks}"
            print(f"CHECK fused_roundtrip_{ename}_{tag} OK "
                  f"(max|fused-composed|={diff:.1e})", flush=True)

    if engine:
        check_wire_metrics(mesh, n, axes_kw, [engine], xr, xi)
        print("ALL_OK", flush=True)
        return

    # packed r2c (beyond-paper) must agree with the faithful path
    fwdp, invp, _ = make_fft3d(
        mesh, n, spec=EngineSpec(backend="ref", real=True, r2c_packed=True),
        **axes_kw)
    kr2, ki2 = fwdp(xr)
    assert rel(np.asarray(kr2)[:keep] + 1j * np.asarray(ki2)[:keep], wr) < 1e-9
    print("CHECK r2c_packed OK", flush=True)

    # μ-component vector field: streaming vs parallel identical (Table 4.1)
    v_re = jnp.asarray(rng.randn(3, ny, nz, nx))
    v_im = jnp.asarray(rng.randn(3, ny, nz, nx))
    outs = {}
    for vm in ("streaming", "parallel"):
        fwd, inv, plan = make_fft3d(mesh, n, components=3,
                                    spec=EngineSpec(vector_mode=vm), **axes_kw)
        kr, ki = fwd(v_re, v_im)
        outs[vm] = np.asarray(kr) + 1j * np.asarray(ki)
        br, bi = inv(kr, ki)
        assert rel(np.asarray(br), v_re) < 1e-9, vm
    assert rel(outs["streaming"], outs["parallel"]) < 1e-12
    for c in range(3):
        assert rel(outs["parallel"][c],
                   expected_c2c(np.asarray(v_re[c]) + 1j * np.asarray(v_im[c]))) < 1e-9
    print("CHECK vector_modes OK", flush=True)

    # multi-axis u (multi-pod style): u over both axes of a (2,2,2) mesh —
    # on the ring engines this is the staged per-axis RDMA transpose path
    mesh3 = compat.make_mesh((2, 2, 2), ("pod", "data", "model"))
    for eng3 in ("switched", "pallas_ring", "bidi_ring"):
        fwd, inv, plan = make_fft3d(mesh3, n, u_axes=("pod", "data"),
                                    v_axes=("model",),
                                    spec=EngineSpec(engine=eng3))
        kr, ki = fwd(xr, xi)
        assert rel(np.asarray(kr) + 1j * np.asarray(ki), want) < 1e-9, eng3
    print("CHECK multipod_u_axes OK", flush=True)

    # on the same 3-axis mesh, the wire counters must see one staged ring
    # per u axis on the ring engines and one crossbar exchange on switched
    check_wire_metrics(mesh3, n,
                       dict(u_axes=("pod", "data"), v_axes=("model",)),
                       ("switched", "pallas_ring", "bidi_ring"), xr, xi)

    print("ALL_OK", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="4x2",
                    help="PUxPV pencil grid, or AxBxC for the 3-axis mesh")
    ap.add_argument("--engine", default="",
                    help="restrict the engine sweep to one comm engine")
    args = ap.parse_args()
    run(_parse_mesh(args.mesh), args.engine)
