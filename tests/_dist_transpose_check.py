"""Multi-device TransposeEngine equivalence checks (subprocess: the fake
device-count XLA flag must be set before jax initializes).

Usage: python tests/_dist_transpose_check.py MESH [--engine NAME]
(expects PYTHONPATH=src). ``MESH`` is either ``PUxPV`` (2D mesh,
``u=("data",)``, ``v=("model",)`` — e.g. ``4x2``, ``4x4``, ``8x4``) or
``AxBxC`` (3-axis mesh ``("pod", "data", "model")`` with the u grid
dimension spanning ``("pod", "data")`` — the multi-axis pencil where every
ring engine must run one **staged per-axis ring per mesh axis**, never a
flat ``ppermute`` over the product group). Asserts, for every registered
engine (``switched`` all-to-all / ``torus`` ring / ``overlap_ring`` fused
ring / ``pallas_ring`` async-RDMA ring, which runs its Pallas kernels in
interpret mode off-TPU / ``bidi_ring``, the bidirectional two-NIC ring —
including the P=2 mesh where both directions hit the same neighbor and
odd-P meshes with an unbalanced direction split, whose grid extent adapts
to stay pencil-divisible):

* every engine's ``fold("xy")``/``fold("yz")`` relayout is **bit-identical**
  to the ``switched`` reference (the two fabrics and the overlapped
  schedules compute the same data movement, §5.5),
* ``unfold ∘ fold`` is the identity for every engine and every CommStep
  (randomized over several inputs — the property the pipeline rests on),
* the full distributed 3D FFT built on each engine is allclose (fp64,
  1e-10) to the ``switched`` build for forward and forward∘inverse,
  including the real and pipelined paths of the overlapped rings, and
* every ring engine's ``exchange_rounds`` counter matches the per-axis
  round model — Σᵢ(qᵢ−1) wire rounds over the fold's communicating mesh
  axes for the unidirectional rings, Σᵢ⌈(qᵢ−1)/2⌉ for ``bidi_ring`` (on a
  multi-axis u dimension this is strictly fewer rounds than one flat ring
  over Pu ranks — the staging win the per-axis perf model prices).

``--engine NAME`` restricts the sweep to one engine (always keeping the
``switched`` reference) — the CI mesh-shape × comm-engine matrix runs one
(mesh, engine) cell per job. Prints CHECK <name> OK per property, then
ALL_OK.
"""

import argparse
import math
import sys

from repro.launch.mesh import ensure_host_devices


def _parse_shape(shape: str) -> tuple[int, ...]:
    try:
        dims = tuple(int(t) for t in shape.lower().split("x"))
    except ValueError:
        raise SystemExit(f"bad mesh shape {shape!r}; want e.g. 4x2 or 2x2x2")
    if len(dims) not in (2, 3) or any(d < 1 for d in dims):
        raise SystemExit(f"bad mesh shape {shape!r}; want 2 or 3 positive "
                         f"x-separated sizes")
    return dims


# the device count depends on the mesh argument, and the fake-device flag
# must be set before jax initializes — peek at argv ahead of argparse
_dims = _parse_shape(sys.argv[1]) if len(sys.argv) > 1 else (4, 2)
ensure_host_devices(max(8, math.prod(_dims)))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import comm  # noqa: E402
from repro.core.decomposition import PencilGrid  # noqa: E402
from repro.core.engine_spec import EngineSpec  # noqa: E402
from repro.core.fft3d import make_fft3d  # noqa: E402

TOL = 1e-10


def rel(a, b):
    a, b = np.asarray(a, np.complex128), np.asarray(b, np.complex128)
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30)


def _engine(name, grid, **kw):
    return comm.build_engine(EngineSpec(engine=name, **kw), grid)


def run(dims: tuple[int, ...], engine: str = "") -> None:
    if engine and engine not in comm.ENGINE_NAMES:
        raise SystemExit(f"unknown --engine {engine!r}; "
                         f"have {sorted(comm.ENGINE_NAMES)}")
    # the switched reference always runs; --engine narrows what it's
    # compared against (CI matrix: one engine per job)
    names = tuple(e for e in comm.ENGINE_NAMES
                  if not engine or e in ("switched", engine))
    ring_names = tuple(e for e in names
                       if e in ("overlap_ring", "pallas_ring", "bidi_ring"))
    if len(dims) == 2:
        mesh = compat.make_mesh(dims, ("data", "model"))
        u_axes, v_axes = ("data",), ("model",)
    else:
        mesh = compat.make_mesh(dims, ("pod", "data", "model"))
        u_axes, v_axes = ("pod", "data"), ("model",)
    grid = PencilGrid.from_mesh(mesh, u_axes, v_axes)
    pu, pv = grid.pu, grid.pv
    # smallest pencil-divisible cubic extent >= 12 (16 when it divides, the
    # historical value; e.g. the odd 3x2 mesh runs at 12^3)
    lcm = math.lcm(pu, pv)
    nd = 16 if 16 % lcm == 0 else lcm * -(-12 // lcm)
    n = (nd, nd, nd)
    grid.validate(n)
    spec = grid.pencil_spec()
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(*n))  # X-pencil global (Ny, Nz, Nx)

    def sm(f, out_spec=spec):
        return jax.jit(compat.shard_map(f, mesh=mesh, in_specs=(spec,),
                                        out_specs=out_spec, check_vma=False))

    # ---- relayout primitives: per-engine roundtrip + bit-exactness --------
    for which in ("xy", "yz"):
        folded = {}
        roundtrips = {}
        for name in names:
            eng = _engine(name, grid)
            folded[name] = sm(lambda a, e=eng, w=which: e.fold(w, a))
            roundtrips[name] = sm(
                lambda a, e=eng, w=which: e.unfold(w, e.fold(w, a)))
            # property: fold∘unfold is the identity, over several inputs
            for seed in range(3):
                xs = jnp.asarray(np.random.RandomState(100 + seed).randn(*n))
                back = roundtrips[name](xs)
                assert np.array_equal(np.asarray(back), np.asarray(xs)), \
                    (which, name, "roundtrip", seed)
            print(f"CHECK {which}_roundtrip_{name} OK", flush=True)
        ref = np.asarray(folded["switched"](x))
        for name in names[1:]:
            got = np.asarray(folded[name](x))
            assert np.array_equal(got, ref), (which, name, "relayout")
            print(f"CHECK {which}_relayout_bitexact_{name} OK", flush=True)

    # both folds composed (the full forward relayout), leading batch axis
    xb = jnp.asarray(rng.randn(2, *n))
    bspec = P(None, *spec)
    outs = {}
    for name in names:
        eng = _engine(name, grid)
        f = jax.jit(compat.shard_map(
            lambda a, e=eng: e.fold("yz", e.fold("xy", a)),
            mesh=mesh, in_specs=(bspec,), out_specs=bspec, check_vma=False))
        outs[name] = np.asarray(f(xb))
    for name in names[1:]:
        assert np.array_equal(outs[name], outs["switched"]), name
    print("CHECK composed_folds_bitexact OK", flush=True)

    # ---- exchange-round complexity (traced through the engine hooks) ------
    # one fold over the u grid dimension costs Σᵢ wire_rounds(qᵢ) rounds over
    # its communicating mesh axes: a multi-axis dimension runs one staged
    # ring per axis, NOT one flat ring over the Pu-rank product group
    u_comm = tuple(q for q in grid.u_sizes if q > 1)
    for name in ring_names:
        eng = _engine(name, grid)
        f = sm(lambda a, e=eng: e.fold("xy", a))
        np.asarray(f(x))
        want = sum(eng.wire_rounds(q) for q in u_comm)
        assert eng.exchange_rounds == want, (name, eng.exchange_rounds, want)
        if name == "bidi_ring" and pu > 1:
            assert want == sum((q - 1 + 1) // 2 for q in u_comm)  # Σ⌈(q−1)/2⌉
        if len(u_comm) > 1:
            # the staging win: never more rounds than one flat Pu ring
            # (strictly fewer for the unidirectional rings; the bidi ring
            # ties on (2,2) where both schedules need 2 rounds)
            assert want <= eng.wire_rounds(pu), (name, want, pu)
            if name != "bidi_ring":
                assert want < eng.wire_rounds(pu), (name, want, pu)
    print("CHECK exchange_round_counts OK", flush=True)

    # ---- full distributed FFT per engine vs the switched reference --------
    xr = jnp.asarray(rng.randn(*n))
    xi = jnp.asarray(rng.randn(*n))
    fwd0, inv0, _ = make_fft3d(mesh, n, spec=EngineSpec(engine="switched"),
                               u_axes=u_axes, v_axes=v_axes)
    kr0, ki0 = fwd0(xr, xi)
    want = np.asarray(kr0) + 1j * np.asarray(ki0)
    for name in names[1:]:
        fwd, inv, plan = make_fft3d(mesh, n, spec=EngineSpec(engine=name),
                                    u_axes=u_axes, v_axes=v_axes)
        kr, ki = fwd(xr, xi)
        got = np.asarray(kr) + 1j * np.asarray(ki)
        assert rel(got, want) < TOL, (name, rel(got, want))
        br, bi = inv(kr, ki)
        back = np.asarray(br) + 1j * np.asarray(bi)
        assert rel(back, np.asarray(xr) + 1j * np.asarray(xi)) < TOL, name
        print(f"CHECK fft_{name}_allclose OK", flush=True)

    # overlapped rings with the pipelined schedule and the real (r2c) data
    # model — the interpret-mode fallback of pallas_ring rides this path too
    fwdr0, invr0, _ = make_fft3d(mesh, n, u_axes=u_axes, v_axes=v_axes,
                                 spec=EngineSpec(engine="switched", real=True))
    krr0, kir0 = fwdr0(xr)
    for name in ring_names:
        fwdp, invp, _ = make_fft3d(
            mesh, n, u_axes=u_axes, v_axes=v_axes,
            spec=EngineSpec(engine=name, schedule="pipelined", chunks=2))
        krp, kip = fwdp(xr, xi)
        assert rel(np.asarray(krp) + 1j * np.asarray(kip), want) < TOL
        print(f"CHECK fft_{name}_pipelined OK", flush=True)

        fwdr, invr, _ = make_fft3d(mesh, n, u_axes=u_axes, v_axes=v_axes,
                                   spec=EngineSpec(engine=name, real=True))
        krr, kir = fwdr(xr)
        assert rel(np.asarray(krr) + 1j * np.asarray(kir),
                   np.asarray(krr0) + 1j * np.asarray(kir0)) < TOL
        backr = invr(krr, kir)
        assert rel(np.asarray(backr), np.asarray(xr)) < TOL
        print(f"CHECK fft_{name}_real OK", flush=True)

    print("ALL_OK", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("shape", help="mesh: PUxPV (e.g. 4x2, 8x4) or AxBxC "
                                  "(3-axis mesh, u spans the first two)")
    ap.add_argument("--engine", default="",
                    help="restrict to one comm engine (default: all; the "
                         "switched reference always runs)")
    args = ap.parse_args()
    run(_parse_shape(args.shape), args.engine)
