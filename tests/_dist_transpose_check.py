"""Multi-device TransposeEngine equivalence checks (subprocess: the fake
device-count XLA flag must be set before jax initializes).

Usage: python tests/_dist_transpose_check.py PUxPV [--engine NAME]
(expects PYTHONPATH=src). Asserts, for a non-trivial Pu×Pv grid and every
registered engine (``switched`` all-to-all / ``torus`` ring /
``overlap_ring`` fused ring / ``pallas_ring`` async-RDMA ring, which runs
its Pallas kernels in interpret mode off-TPU / ``bidi_ring``, the
bidirectional two-NIC ring — including the P=2 mesh where both directions
hit the same neighbor and odd-P meshes with an unbalanced direction split,
whose grid extent adapts to stay pencil-divisible):

* every engine's ``fold_xy``/``fold_yz`` relayout is **bit-identical** to the
  ``switched`` reference (the two fabrics and the overlapped schedules compute
  the same data movement, §5.5),
* ``unfold ∘ fold`` is the identity for every engine (randomized over several
  inputs — the property the whole pipeline rests on), and
* the full distributed 3D FFT built on each engine is allclose (fp64,
  1e-10) to the ``switched`` build for forward and forward∘inverse,
  including the real and pipelined paths of the overlapped rings, and
* every ring engine's ``exchange_rounds`` counter matches its round model —
  P−1 wire rounds for the unidirectional rings, ``ceil((P−1)/2)`` for
  ``bidi_ring`` (the two-NIC halving this engine exists for).

``--engine NAME`` restricts the sweep to one engine (always keeping the
``switched`` reference) — the CI mesh-shape × comm-engine matrix runs one
(mesh, engine) cell per job. Prints CHECK <name> OK per property, then
ALL_OK.
"""

import argparse
import math

from repro.launch.mesh import ensure_host_devices

ensure_host_devices(8)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import comm  # noqa: E402
from repro.core.decomposition import PencilGrid  # noqa: E402
from repro.core.fft3d import make_fft3d  # noqa: E402

TOL = 1e-10


def rel(a, b):
    a, b = np.asarray(a, np.complex128), np.asarray(b, np.complex128)
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30)


def run(pu: int, pv: int, engine: str = "") -> None:
    if engine and engine not in comm.ENGINE_NAMES:
        raise SystemExit(f"unknown --engine {engine!r}; "
                         f"have {sorted(comm.ENGINE_NAMES)}")
    # the switched reference always runs; --engine narrows what it's
    # compared against (CI matrix: one engine per job)
    names = tuple(e for e in comm.ENGINE_NAMES
                  if not engine or e in ("switched", engine))
    ring_names = tuple(e for e in names
                       if e in ("overlap_ring", "pallas_ring", "bidi_ring"))
    mesh = compat.make_mesh((pu, pv), ("data", "model"))
    grid = PencilGrid.from_mesh(mesh)
    # smallest pencil-divisible cubic extent >= 12 (16 when it divides, the
    # historical value; e.g. the odd 3x2 mesh runs at 12^3)
    lcm = math.lcm(pu, pv)
    nd = 16 if 16 % lcm == 0 else lcm * -(-12 // lcm)
    n = (nd, nd, nd)
    grid.validate(n)
    spec = grid.pencil_spec()
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(*n))  # X-pencil global (Ny, Nz, Nx)

    def sm(f, out_spec=spec):
        return jax.jit(compat.shard_map(f, mesh=mesh, in_specs=(spec,),
                                        out_specs=out_spec, check_vma=False))

    # ---- relayout primitives: per-engine roundtrip + bit-exactness --------
    for which in ("xy", "yz"):
        folded = {}
        roundtrips = {}
        for name in names:
            eng = comm.make_engine(name, grid)
            folded[name] = sm(lambda a, e=eng, w=which: e.fold(w, a))
            roundtrips[name] = sm(
                lambda a, e=eng, w=which: e.unfold(w, e.fold(w, a)))
            # property: fold∘unfold is the identity, over several inputs
            for seed in range(3):
                xs = jnp.asarray(np.random.RandomState(100 + seed).randn(*n))
                back = roundtrips[name](xs)
                assert np.array_equal(np.asarray(back), np.asarray(xs)), \
                    (which, name, "roundtrip", seed)
            print(f"CHECK {which}_roundtrip_{name} OK", flush=True)
        ref = np.asarray(folded["switched"](x))
        for name in names[1:]:
            got = np.asarray(folded[name](x))
            assert np.array_equal(got, ref), (which, name, "relayout")
            print(f"CHECK {which}_relayout_bitexact_{name} OK", flush=True)

    # both folds composed (the full forward relayout), leading batch axis
    xb = jnp.asarray(rng.randn(2, *n))
    bspec = P(None, *spec)
    outs = {}
    for name in names:
        eng = comm.make_engine(name, grid)
        f = jax.jit(compat.shard_map(
            lambda a, e=eng: e.fold_yz(e.fold_xy(a)),
            mesh=mesh, in_specs=(bspec,), out_specs=bspec, check_vma=False))
        outs[name] = np.asarray(f(xb))
    for name in names[1:]:
        assert np.array_equal(outs[name], outs["switched"]), name
    print("CHECK composed_folds_bitexact OK", flush=True)

    # ---- exchange-round complexity (traced through the engine hooks) ------
    # one fold over the Pu ranks costs wire_rounds(Pu) rounds: Pu−1 for the
    # unidirectional rings, ceil((Pu−1)/2) for the bidirectional one
    for name in ring_names:
        eng = comm.make_engine(name, grid)
        f = sm(lambda a, e=eng: e.fold_xy(a))
        np.asarray(f(x))
        want = eng.wire_rounds(pu) if pu > 1 else 0
        assert eng.exchange_rounds == want, (name, eng.exchange_rounds, want)
        if name == "bidi_ring" and pu > 1:
            assert want == (pu - 1 + 1) // 2  # ceil((P−1)/2)
    print("CHECK exchange_round_counts OK", flush=True)

    # ---- full distributed FFT per engine vs the switched reference --------
    xr = jnp.asarray(rng.randn(*n))
    xi = jnp.asarray(rng.randn(*n))
    fwd0, inv0, _ = make_fft3d(mesh, n, comm_engine="switched")
    kr0, ki0 = fwd0(xr, xi)
    want = np.asarray(kr0) + 1j * np.asarray(ki0)
    for name in names[1:]:
        fwd, inv, plan = make_fft3d(mesh, n, comm_engine=name)
        kr, ki = fwd(xr, xi)
        got = np.asarray(kr) + 1j * np.asarray(ki)
        assert rel(got, want) < TOL, (name, rel(got, want))
        br, bi = inv(kr, ki)
        back = np.asarray(br) + 1j * np.asarray(bi)
        assert rel(back, np.asarray(xr) + 1j * np.asarray(xi)) < TOL, name
        print(f"CHECK fft_{name}_allclose OK", flush=True)

    # overlapped rings with the pipelined schedule and the real (r2c) data
    # model — the interpret-mode fallback of pallas_ring rides this path too
    fwdr0, invr0, _ = make_fft3d(mesh, n, real=True, comm_engine="switched")
    krr0, kir0 = fwdr0(xr)
    for name in ring_names:
        fwdp, invp, _ = make_fft3d(mesh, n, comm_engine=name,
                                   schedule="pipelined", chunks=2)
        krp, kip = fwdp(xr, xi)
        assert rel(np.asarray(krp) + 1j * np.asarray(kip), want) < TOL
        print(f"CHECK fft_{name}_pipelined OK", flush=True)

        fwdr, invr, _ = make_fft3d(mesh, n, real=True, comm_engine=name)
        krr, kir = fwdr(xr)
        assert rel(np.asarray(krr) + 1j * np.asarray(kir),
                   np.asarray(krr0) + 1j * np.asarray(kir0)) < TOL
        backr = invr(krr, kir)
        assert rel(np.asarray(backr), np.asarray(xr)) < TOL
        print(f"CHECK fft_{name}_real OK", flush=True)

    print("ALL_OK", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("shape", help="PUxPV pencil grid, e.g. 4x2")
    ap.add_argument("--engine", default="",
                    help="restrict to one comm engine (default: all; the "
                         "switched reference always runs)")
    args = ap.parse_args()
    pu, pv = (int(t) for t in args.shape.lower().split("x"))
    run(pu, pv, args.engine)
