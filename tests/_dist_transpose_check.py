"""Multi-device pencil-transpose equivalence checks (subprocess: the fake
device-count XLA flag must be set before jax initializes).

Usage: python tests/_dist_transpose_check.py PUxPV   (expects PYTHONPATH=src)
Asserts, for a non-trivial Pu×Pv grid:

* ``net="torus"`` (ring of ppermutes, Eq. 5.6 routing) is **bit-identical**
  to ``net="switched"`` (single all_to_all, Eq. 5.5) for both folds, and
* ``xy/yz unfold∘fold`` round-trips to the input exactly.

Prints CHECK <name> OK per property, then ALL_OK.
"""

import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.core import transpose as tr  # noqa: E402
from repro.core.decomposition import PencilGrid  # noqa: E402


def run(pu: int, pv: int) -> None:
    mesh = compat.make_mesh((pu, pv), ("data", "model"))
    grid = PencilGrid.from_mesh(mesh)
    n = (16, 16, 16)
    grid.validate(n)
    spec = grid.pencil_spec()
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(*n))  # X-pencil global (Ny, Nz, Nx)

    def sm(f, out_spec=spec):
        return jax.jit(compat.shard_map(f, mesh=mesh, in_specs=(spec,),
                                        out_specs=out_spec, check_vma=False))

    for fold, unfold, axes, name in [
        (tr.xy_fold, tr.xy_unfold, grid.u_axes, "xy"),
        (tr.yz_fold, tr.yz_unfold, grid.v_axes, "yz"),
    ]:
        folded = {}
        for mode in ("switched", "torus"):
            folded[mode] = np.asarray(
                sm(lambda a, m=mode: fold(a, axes, mode=m))(x))
            back = sm(lambda a, m=mode: unfold(fold(a, axes, mode=m), axes,
                                               mode=m))(x)
            assert np.array_equal(np.asarray(back), np.asarray(x)), \
                (name, mode, "roundtrip")
            print(f"CHECK {name}_roundtrip_{mode} OK", flush=True)
        assert np.array_equal(folded["switched"], folded["torus"]), \
            (name, "torus != switched")
        print(f"CHECK {name}_torus_bitexact OK", flush=True)

    # both folds composed (the full forward relayout), leading batch axis
    xb = jnp.asarray(rng.randn(2, *n))
    bspec = P(None, *spec)
    outs = {}
    for mode in ("switched", "torus"):
        f = jax.jit(compat.shard_map(
            lambda a, m=mode: tr.yz_fold(tr.xy_fold(a, grid.u_axes, mode=m),
                                         grid.v_axes, mode=m),
            mesh=mesh, in_specs=(bspec,), out_specs=bspec, check_vma=False))
        outs[mode] = np.asarray(f(xb))
    assert np.array_equal(outs["switched"], outs["torus"])
    print("CHECK composed_folds_bitexact OK", flush=True)
    print("ALL_OK", flush=True)


if __name__ == "__main__":
    pu, pv = (int(t) for t in sys.argv[1].lower().split("x"))
    run(pu, pv)
