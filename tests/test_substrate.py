"""Substrate tests: optimizer, data pipeline determinism, checkpointing
(atomicity, resume, resharding restore), gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline, write_token_file
from repro.distributed import compression as comp
from repro.optim import adamw


def test_adamw_reduces_quadratic():
    c = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(c, params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, m = adamw.update(c, g, state, params)
    assert float(loss(params)) < 1e-2
    assert float(m["lr"]) < 0.1  # cosine decayed


def test_adamw_bf16_moments_close_to_f32():
    params = {"w": jnp.ones((32,)) * 2.0}
    loss = lambda p: jnp.sum(jnp.sin(p["w"]) ** 2)
    outs = {}
    for dt in ("float32", "bfloat16"):
        c = adamw.AdamWConfig(lr=0.05, warmup_steps=0, total_steps=100,
                              weight_decay=0.0, moment_dtype=dt)
        p = jax.tree.map(jnp.copy, params)
        s = adamw.init(c, p)
        for _ in range(50):
            g = jax.grad(loss)(p)
            p, s, _ = adamw.update(c, g, s, p)
        outs[dt] = float(loss(p))
    assert abs(outs["float32"] - outs["bfloat16"]) < 0.05


def test_clip_norm():
    c = adamw.AdamWConfig(clip_norm=1.0, warmup_steps=0)
    g = {"w": jnp.full((100,), 10.0)}
    p = {"w": jnp.zeros((100,))}
    s = adamw.init(c, p)
    _, _, m = adamw.update(c, g, s, p)
    assert float(m["grad_norm"]) > 1.0  # reported raw norm


def test_pipeline_deterministic_and_sharded():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    a = Pipeline(cfg, shard=0, num_shards=2).batch_for_step(7)
    b = Pipeline(cfg, shard=0, num_shards=2).batch_for_step(7)
    c = Pipeline(cfg, shard=1, num_shards=2).batch_for_step(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])   # replayable
    assert not np.array_equal(a["tokens"], c["tokens"])       # shard-disjoint
    assert a["tokens"].shape == (4, 16)
    d = Pipeline(cfg, shard=0, num_shards=2).batch_for_step(8)
    assert not np.array_equal(a["tokens"], d["tokens"])       # step-fresh


def test_pipeline_memmap(tmp_path):
    path = str(tmp_path / "tokens.bin")
    write_token_file(path, np.arange(10000) % 777)
    cfg = DataConfig(vocab=777, seq_len=32, global_batch=4, token_file=path)
    b = Pipeline(cfg).batch_for_step(0)
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].max() < 777
    # windows are consecutive slices of the corpus
    row = b["tokens"][0]
    assert np.all(np.diff(row.astype(np.int64)) % 777 == 1)


def test_checkpoint_roundtrip_and_resume(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
    for step in (0, 5, 10):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree), block=True)
    assert mgr.latest_step() == 10
    # keep=2 garbage collection
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert kept == ["step_00000005", "step_00000010"]
    got, meta = mgr.restore(tree)
    np.testing.assert_allclose(np.asarray(got["a"]), np.asarray(tree["a"]) + 10)
    assert meta["step"] == 10


def test_checkpoint_atomic_against_torn_write(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d)
    tree = {"a": jnp.ones((3,))}
    mgr.save(1, tree, block=True)
    # simulate a torn write of a later checkpoint
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    with open(os.path.join(d, "LATEST")) as f:
        assert f.read().strip() == "step_00000001"
    got, meta = mgr.restore(tree)
    assert meta["step"] == 1


def test_checkpoint_reshard_restore(tmp_path):
    """Elastic: save unsharded, restore with explicit shardings (mesh B)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d)
    tree = {"w": jnp.arange(8.0)}
    mgr.save(3, tree, block=True)
    from repro.compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    shard = {"w": NamedSharding(mesh, P("data"))}
    got, _ = mgr.restore(tree, shardings=shard)
    np.testing.assert_allclose(np.asarray(got["w"]), np.arange(8.0))
    assert got["w"].sharding == shard["w"]


def test_compression_error_feedback_unbiased():
    """Accumulated dequantized updates track the true sum (error feedback)."""
    rng = np.random.RandomState(0)
    g_true = [rng.randn(64).astype(np.float32) * 10 ** rng.uniform(-3, 1)
              for _ in range(50)]
    res = {"g": jnp.zeros(64)}
    acc_q = np.zeros(64)
    for g in g_true:
        q, res = comp.compress_with_feedback({"g": jnp.asarray(g)}, res)
        acc_q += np.asarray(comp.dequantize_int8(*q["g"]))
    acc_true = np.sum(g_true, axis=0)
    # residual bounds the difference by one quantization step
    assert np.max(np.abs(acc_q - acc_true)) <= np.max(np.abs(np.asarray(res["g"]))) + 1e-4


def test_quantize_int8_range():
    g = jnp.asarray([-3.0, 0.0, 1.5, 3.0])
    q, s = comp.quantize_int8(g)
    assert q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q))) == 127
    back = comp.dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(back), np.asarray(g), atol=3.0 / 127)
