"""Four-step MXU FFT kernel vs jnp.fft ground truth + the radix-2 engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fft_mxu import (fft1d_mxu, fft_mxu_flops,
                                   mxu_vs_butterfly_napkin)
from repro.kernels.fft_radix2 import fft1d_pallas


def rel(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30)


@pytest.mark.parametrize("n", [16, 64, 128, 512, 1024, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_mxu_matches_fft(n, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n))
    xr = jax.random.normal(k1, (5, n), dtype)
    xi = jax.random.normal(k2, (5, n), dtype)
    yr, yi = fft1d_mxu(xr, xi)
    z = np.fft.fft(np.asarray(xr, np.float64) + 1j * np.asarray(xi, np.float64))
    tol = 2e-4 if dtype == jnp.float32 else 1e-10
    assert rel(yr, z.real) < tol
    assert rel(yi, z.imag) < tol


@pytest.mark.parametrize("n", [64, 256])
def test_mxu_matches_radix2_engine(n):
    xr = jax.random.normal(jax.random.PRNGKey(0), (7, n), jnp.float64)
    xi = jax.random.normal(jax.random.PRNGKey(1), (7, n), jnp.float64)
    ar, ai = fft1d_mxu(xr, xi)
    br, bi = fft1d_pallas(xr, xi)
    assert rel(ar, br) < 1e-10
    assert rel(ai, bi) < 1e-10


def test_mxu_odd_log2_and_lead_axes():
    # N with odd log2 (n1 != n2) and multi leading dims
    xr = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 128), jnp.float32)
    xi = jnp.zeros_like(xr)
    yr, yi = fft1d_mxu(xr, xi)
    z = np.fft.fft(np.asarray(xr, np.float64))
    assert rel(yr, z.real) < 2e-4
    assert rel(yi, z.imag) < 2e-4


def test_napkin_math_favors_mxu():
    for n in (512, 4096, 8192):
        r = mxu_vs_butterfly_napkin(n)
        assert r["speedup"] > 1.5, (n, r)   # the §Perf claim
    assert fft_mxu_flops(4096) == 8 * 4096 * (64 + 64)


def test_mxu_backend_via_ops_and_inverse():
    from repro.kernels.ops import fft1d
    xr = jax.random.normal(jax.random.PRNGKey(3), (4, 64), jnp.float64)
    xi = jax.random.normal(jax.random.PRNGKey(4), (4, 64), jnp.float64)
    yr, yi = fft1d(xr, xi, backend="mxu")
    z = np.fft.fft(np.asarray(xr) + 1j * np.asarray(xi))
    assert rel(yr, z.real) < 1e-10 and rel(yi, z.imag) < 1e-10
    br, bi = fft1d(yr, yi, backend="mxu", inverse=True)
    assert rel(br, xr) < 1e-10 and rel(bi, xi) < 1e-10
