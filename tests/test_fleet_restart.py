"""Driver-level fleet fault tolerance: kill-injected ensemble workers must
resume from their checkpoints and land on observables identical to the
unkilled campaign, and a job with an exhausted retry budget must be
quarantined without wedging its siblings — the acceptance proof the CI
chaos smoke re-runs at 4-job scale."""

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fleet(workdir, report, extra=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FAULT_SPEC", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.fleet.cli", "--case", "heat",
         "--n", "16", "--steps", "4", "--jobs", "2", "--submesh", "2x1",
         "--slots", "4", "--ckpt-every", "2", "--workdir", workdir,
         "--report", report, *extra],
        env=env, capture_output=True, text=True, timeout=1200)
    return out


def _report(path):
    with open(path) as f:
        doc = json.load(f)
    assert doc["schema"] == "fleet-report/v1"
    return doc


def test_killed_ensemble_resumes_to_identical_observables(tmp_path):
    clean = _fleet(str(tmp_path / "clean"), str(tmp_path / "clean.json"))
    assert clean.returncode == 0, (clean.stdout[-1500:], clean.stderr[-3000:])
    chaos = _fleet(str(tmp_path / "chaos"), str(tmp_path / "chaos.json"),
                   extra=("--inject", "kill-at-step:3"))
    assert chaos.returncode == 0, (chaos.stdout[-1500:], chaos.stderr[-3000:])
    assert "retry in" in chaos.stdout            # the controller rescheduled

    ref, got = _report(tmp_path / "clean.json"), _report(tmp_path / "chaos.json")
    assert got["counters"]["fleet.jobs.retried"] == 2
    assert got["counters"]["fleet.jobs.quarantined"] == 0
    for jid in ("job0", "job1"):
        cj, kj = ref["jobs"][jid], got["jobs"][jid]
        assert cj["status"] == kj["status"] == "completed"
        assert cj["attempts"] == 1 and kj["attempts"] == 2
        assert kj["failures"][0]["kind"] == "crash"
        # the headline identity: the merged per-step observables of the
        # killed-and-resumed run equal the unkilled run's, bit for bit
        assert kj["history"] == cj["history"], jid
        assert kj["restore_latency_us"] > 0      # it really resumed
    # the retried attempt resumed from the step-2 snapshot
    for log in sorted(os.listdir(tmp_path / "chaos")):
        if log.endswith(".attempt1.log"):
            with open(tmp_path / "chaos" / log) as f:
                assert "[resume]" in f.read()


def test_exhausted_job_is_quarantined_without_blocking_siblings(tmp_path):
    out = _fleet(str(tmp_path / "q"), str(tmp_path / "q.json"),
                 extra=("--inject", "kill-at-step:1:times=99@job=job0",
                        "--max-retries", "1"))
    # quarantine => campaign exit code 1, but the campaign still finished
    assert out.returncode == 1, (out.stdout[-1500:], out.stderr[-3000:])
    assert "QUARANTINED" in out.stdout
    doc = _report(tmp_path / "q.json")
    j0, j1 = doc["jobs"]["job0"], doc["jobs"]["job1"]
    assert j0["status"] == "quarantined" and j0["attempts"] == 2
    assert [f["kind"] for f in j0["failures"]] == ["crash", "crash"]
    assert all(f["exit_code"] == 13 for f in j0["failures"])
    assert j1["status"] == "completed" and j1["attempts"] == 1
    assert doc["counters"]["fleet.jobs.quarantined"] == 1
