"""``repro.solvers`` subsystem: contract, per-case analytic validation,
integrators, the x64/dtype gate, and the multi-device smoke (subprocess).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import precision
from repro.solvers import SOLVERS, SolverState, make_solver
from repro.solvers import integrators

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def mesh11():
    return compat.make_mesh((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# registry + contract
# ---------------------------------------------------------------------------

def test_registry_lists_all_cases():
    assert set(SOLVERS) == {"poisson", "heat", "navier_stokes", "nls"}
    with pytest.raises(ValueError, match="unknown solver case"):
        make_solver("burgers", None, 8)


def test_contract_shapes_and_state(mesh11):
    s = make_solver("heat", mesh11, 8)
    st = s.init_state()
    assert isinstance(st, SolverState) and st.t == 0.0 and st.n_steps == 0
    st2 = s.step(st)
    assert st2.n_steps == 1 and st2.t == pytest.approx(s.dt)
    obs = s.observables(st2)
    assert {"amp", "mean", "energy", "t"} <= set(obs)
    assert all(isinstance(v, float) for v in obs.values())
    # fields keep the declared dtype through a step
    assert all(a.dtype == jnp.float64 for a in st2.fields)


# ---------------------------------------------------------------------------
# per-case analytic validation (single device; 4x2 mesh in the subprocess)
# ---------------------------------------------------------------------------

def test_poisson_manufactured_solution(mesh11):
    s = make_solver("poisson", mesh11, 16)
    _, history = s.run(1)
    ok, lines = s.validate(history)
    assert ok, lines
    assert history[-1]["err_inf"] < 1e-10  # acceptance: ~1e-10 in f64


def test_heat_decay_rate(mesh11):
    s = make_solver("heat", mesh11, 16, kappa=0.05, dt=2e-2, mode=(1, 2, 2))
    _, history = s.run(4)
    ok, lines = s.validate(history)
    assert ok, lines
    # decay is e^{-kappa*|m|^2 t} with |m|^2 = 9, exact per step
    amp = history[-1]["amp"] / history[0]["amp"]
    assert amp == pytest.approx(np.exp(-0.05 * 9 * history[-1]["t"]),
                                rel=1e-10)


def test_navier_stokes_taylor_green(mesh11):
    s = make_solver("navier_stokes", mesh11, 16, nu=0.1, dt=2e-3)
    _, history = s.run(3)
    ok, lines = s.validate(history)
    assert ok, lines
    energies = [h["energy"] for h in history]
    assert energies[-1] < energies[0]  # viscous dissipation
    assert all(h["max_div"] < 1e-8 for h in history)


def test_nls_norm_conservation(mesh11):
    s = make_solver("nls", mesh11, 16, g=2.0, dt=1e-3)
    _, history = s.run(5)
    ok, lines = s.validate(history)
    assert ok, lines
    drift = abs(history[-1]["norm"] - history[0]["norm"]) / history[0]["norm"]
    assert drift < 1e-10


def test_solver_accepts_plan_cfg(mesh11):
    cfg = {"backend": "jnp", "schedule": "sequential", "chunks": 1,
           "net": "torus", "vector_mode": "parallel", "r2c_packed": False}
    s = make_solver("navier_stokes", mesh11, 8, plan_cfg=cfg)
    # legacy net-only config maps onto the engine axis; vector mode rides in
    assert s.plan.comm_engine == "torus" and s.vector_mode == "parallel"
    _, history = s.run(1)
    ok, lines = s.validate(history)
    assert ok, lines


def test_checkpoint_contract_roundtrip(mesh11, tmp_path):
    # the fleet's resume path: state_tree -> CheckpointManager ->
    # restore_state into a *fresh* solver continues the exact trajectory
    from repro.checkpoint.checkpoint import CheckpointManager

    s = make_solver("heat", mesh11, 8)
    st, ref = s.init_state(), []
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)
    for i in range(1, 5):
        st = s.step(st)
        ref.append(s.observables(st))
        if i == 2:
            mgr.save(i, s.state_tree(st), meta={"case": "heat"}, block=True)

    s2 = make_solver("heat", mesh11, 8)
    st2, meta = s2.restore_state(mgr)
    assert st2.n_steps == 2 and st2.t == ref[1]["t"]
    assert meta["case"] == "heat" and meta["step"] == 2
    got = []
    for _ in range(2):
        st2 = s2.step(st2)
        got.append(s2.observables(st2))
    assert got == ref[2:]            # bitwise: resumed == uninterrupted


def test_state_tree_is_checkpointable(mesh11):
    s = make_solver("nls", mesh11, 8)
    st = s.step(s.init_state())
    tree = s.state_tree(st)
    assert set(tree) == {"fields", "t", "n_steps"}
    assert float(tree["t"]) == st.t and int(tree["n_steps"]) == 1
    assert len(tree["fields"]) == len(st.fields)


def test_multi_device_solver_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_dist_solver_check.py")],
        env=env, capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, (out.stdout[-1500:], out.stderr[-3000:])
    assert "ALL_OK" in out.stdout


# ---------------------------------------------------------------------------
# integrators
# ---------------------------------------------------------------------------

def test_rk4_order_on_scalar_ode():
    # ∂y = -y, exact e^{-t}; RK4 global error ~ dt^4
    def integrate(dt, steps):
        y = (jnp.asarray(1.0),)
        rhs = lambda t: tuple(-a for a in t)
        for _ in range(steps):
            y = integrators.rk4(rhs, y, dt)
        return float(y[0])

    err1 = abs(integrate(0.1, 10) - np.exp(-1.0))
    err2 = abs(integrate(0.05, 20) - np.exp(-1.0))
    assert err1 < 1e-6
    assert err2 < err1 / 10  # ~16x for a 4th-order method


def test_ifrk4_exact_on_pure_linear():
    decay = jnp.asarray([-5.0, -1.0, 0.0])
    y = (jnp.ones(3), 2 * jnp.ones(3))
    zero = lambda t: tuple(jnp.zeros_like(a) for a in t)
    out = integrators.ifrk4(zero, decay, y, 0.7)
    want = np.exp(-0.7 * np.array([5.0, 1.0, 0.0]))
    np.testing.assert_allclose(np.asarray(out[0]), want, rtol=1e-12)
    np.testing.assert_allclose(np.asarray(out[1]), 2 * want, rtol=1e-12)


def test_ifrk4_matches_rk4_on_nonstiff():
    # ∂y = -y + sin(y): IFRK4 with decay=-1 vs plain RK4, tiny dt
    y0 = (jnp.asarray(0.8),)
    nonlin = lambda t: tuple(jnp.sin(a) for a in t)
    full = lambda t: tuple(-a + jnp.sin(a) for a in t)
    a = integrators.ifrk4(nonlin, jnp.asarray(-1.0), y0, 1e-3)
    b = integrators.rk4(full, y0, 1e-3)
    assert float(a[0]) == pytest.approx(float(b[0]), abs=1e-12)


def test_exp_decay_is_exact_propagator():
    y = (jnp.asarray([1.0, 4.0]),)
    out = integrators.exp_decay(jnp.asarray([-2.0, 0.5]), y, 0.25)
    np.testing.assert_allclose(np.asarray(out[0]),
                               [np.exp(-0.5), 4 * np.exp(0.125)], rtol=1e-12)


# ---------------------------------------------------------------------------
# precision policy: the float64 gate
# ---------------------------------------------------------------------------

def test_require_dtype_raises_without_x64(mesh11):
    assert precision.x64_enabled()  # conftest turned it on
    assert precision.require_dtype("float64") == np.dtype("float64")
    jax.config.update("jax_enable_x64", False)
    try:
        with pytest.raises(ValueError, match="jax_enable_x64 is off"):
            precision.require_dtype("float64")
        # explicit demotion is allowed
        assert precision.require_dtype(
            "float64", allow_downcast=True) == np.dtype("float32")
        # ...and the gate fires from plan/solver construction too
        from repro.core.decomposition import PencilGrid
        from repro.core.fft3d import FFT3DPlan
        grid = PencilGrid(pu=1, pv=1, u_axes=(), v_axes=())
        with pytest.raises(ValueError, match="FFT3DPlan"):
            FFT3DPlan(n=(8, 8, 8), grid=grid, dtype="float64")
        with pytest.raises(ValueError, match="solvers.heat"):
            make_solver("heat", mesh11, 8, dtype="float64")
        # the step-tuner must refuse too — never tune f32 under an f64 label
        from repro.tuning.solver import autotune_solver_step
        with pytest.raises(ValueError, match="autotune_solver_step"):
            autotune_solver_step(mesh11, "heat", 8, dtype="float64")
        assert precision.default_real_dtype() == jnp.float32
    finally:
        jax.config.update("jax_enable_x64", True)
    assert precision.default_real_dtype() == jnp.float64


def test_solver_explicit_float32(mesh11):
    s = make_solver("heat", mesh11, 8, dtype="float32")
    assert s.dtype == np.dtype("float32") and s.plan.dtype == "float32"
    st = s.step(s.init_state())
    assert all(a.dtype == jnp.float32 for a in st.fields)


# ---------------------------------------------------------------------------
# solver-step tuning objective
# ---------------------------------------------------------------------------

def test_autotune_solver_step_caches_per_case(tmp_path, mesh11):
    from repro.tuning import problem_fingerprint
    from repro.tuning.solver import autotune_solver_step

    cache = str(tmp_path / "plans.json")
    res = autotune_solver_step(mesh11, "heat", 8, dtype="float64",
                               cache_path=cache, max_candidates=1, iters=1)
    assert not res.cache_hit and res.key.startswith("solver_heat_")
    assert res.rows and res.best_us > 0
    hit = autotune_solver_step(mesh11, "heat", 8, dtype="float64",
                               cache_path=cache, max_candidates=1, iters=1)
    assert hit.cache_hit and hit.best_config == res.best_config

    # the case and its physics params are part of the fingerprint
    k1, p1 = problem_fingerprint(8, 1, 1, real=True, case="heat",
                                 solver_params={"dt": 1e-2})
    k2, _ = problem_fingerprint(8, 1, 1, real=True, case="poisson",
                                solver_params={"dt": 1e-2})
    k3, _ = problem_fingerprint(8, 1, 1, real=True, case="heat",
                                solver_params={"dt": 5e-3})
    k4, _ = problem_fingerprint(8, 1, 1, real=True)
    assert len({k1, k2, k3, k4}) == 4
    assert p1["case"] == "heat" and "case" not in \
        problem_fingerprint(8, 1, 1, real=True)[1]

    with pytest.raises(ValueError, match="unknown solver case"):
        autotune_solver_step(mesh11, "nope", 8, cache_path=cache)
    with pytest.raises(ValueError, match="iters"):
        autotune_solver_step(mesh11, "heat", 8, cache_path=cache, iters=0)
