"""Pallas flash-attention kernel vs the direct softmax oracle (interpret
mode), sweeping shapes, GQA group sizes, dtypes, causality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import flash_attention
from repro.models.layers import AttnDims, _sdpa_direct


def _mk(b, s, t, h, hkv, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, t, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, t, hkv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("s,t", [(64, 64), (128, 64), (64, 128)])
@pytest.mark.parametrize("h,hkv", [(4, 4), (8, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_direct(s, t, h, hkv, causal):
    if causal and s > t:
        pytest.skip("causal requires T >= S here")
    q, k, v = _mk(2, s, t, h, hkv, 32, jnp.float32)
    a = AttnDims(d_model=h * 32, n_heads=h, n_kv_heads=hkv, head_dim=32)
    mask = None
    if causal:
        off = t - s
        mask = (jnp.arange(t)[None, :] <= (jnp.arange(s) + off)[:, None])[None, None, None]
        # flash kernel assumes aligned diagonals; test square causal only
        if s != t:
            pytest.skip("kernel causal mask assumes S == T")
    ref = _sdpa_direct(q, k, v, a, mask)
    got = flash_attention(q, k, v, causal=causal, blk_q=32, blk_k=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    q, k, v = _mk(1, 64, 64, 4, 2, 64, dtype, seed=3)
    a = AttnDims(d_model=256, n_heads=4, n_kv_heads=2, head_dim=64)
    mask = (jnp.arange(64)[None, :] <= jnp.arange(64)[:, None])[None, None, None]
    ref = _sdpa_direct(q.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32), a, mask)
    got = flash_attention(q, k, v, causal=True, blk_q=16, blk_k=16)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref),
                               rtol=tol, atol=tol)
    assert got.dtype == dtype


def test_flash_block_shape_sweep():
    q, k, v = _mk(1, 128, 128, 2, 2, 16, jnp.float32, seed=5)
    a = AttnDims(d_model=32, n_heads=2, n_kv_heads=2, head_dim=16)
    mask = (jnp.arange(128)[None, :] <= jnp.arange(128)[:, None])[None, None, None]
    ref = _sdpa_direct(q, k, v, a, mask)
    outs = []
    for bq, bk in [(16, 64), (64, 16), (128, 128), (32, 32)]:
        got = flash_attention(q, k, v, causal=True, blk_q=bq, blk_k=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        outs.append(got)
