"""The docs tree stays truthful: every relative link in ``README.md`` and
``docs/`` resolves to a real file (anchors to a real heading), and every
``python -m <module>`` invocation the docs show names an importable
module. Runnable standalone (``python tests/test_docs.py`` — the CI docs
link-check step) or under pytest as part of tier-1.
"""

import importlib.util
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# standalone invocation has tests/ as sys.path[0]; the repo root covers
# the benchmarks namespace package, src/ the repro package
for p in (REPO, os.path.join(REPO, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
MODULE_RE = re.compile(r"python -m ([A-Za-z0-9_.]+)")
HEADING_RE = re.compile(r"^#+\s+(.*)$", re.MULTILINE)


def doc_files() -> list:
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    for root, _, names in os.walk(docs):
        files += [os.path.join(root, n) for n in sorted(names)
                  if n.endswith(".md")]
    return files


def github_slug(heading: str) -> str:
    """The anchor GitHub renders for a heading (backticks stripped,
    non-alphanumerics dropped, spaces hyphenated)."""
    text = heading.strip().lower().replace("`", "")
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def iter_relative_links(path: str):
    with open(path) as f:
        body = f.read()
    for target in LINK_RE.findall(body):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        yield target


def check_links() -> list:
    """Broken relative links / anchors across the doc set."""
    problems = []
    for path in doc_files():
        rel = os.path.relpath(path, REPO)
        for target in iter_relative_links(path):
            file_part, _, anchor = target.partition("#")
            dest = path if not file_part else os.path.normpath(
                os.path.join(os.path.dirname(path), file_part))
            if not os.path.exists(dest):
                problems.append(f"{rel}: link target missing: {target}")
                continue
            if anchor and dest.endswith(".md"):
                with open(dest) as f:
                    slugs = {github_slug(h)
                             for h in HEADING_RE.findall(f.read())}
                if anchor not in slugs:
                    problems.append(f"{rel}: anchor #{anchor} not a "
                                    f"heading in {os.path.relpath(dest, REPO)}")
    return problems


def documented_modules() -> set:
    mods = set()
    for path in doc_files():
        with open(path) as f:
            mods.update(MODULE_RE.findall(f.read()))
    return mods


def check_modules() -> list:
    """``python -m`` invocations whose module doesn't resolve."""
    problems = []
    for mod in sorted(documented_modules()):
        try:
            spec = importlib.util.find_spec(mod)
        except (ImportError, ModuleNotFoundError) as e:
            spec, problems_entry = None, str(e)
        else:
            problems_entry = "not found"
        if spec is None:
            problems.append(f"python -m {mod}: {problems_entry}")
    return problems


# ---------------------------------------------------------------------------
# pytest entry points
# ---------------------------------------------------------------------------

def test_docs_tree_exists():
    files = [os.path.relpath(p, REPO) for p in doc_files()]
    assert "README.md" in files
    for required in ("docs/architecture.md", "docs/serving.md",
                     "docs/benchmarks.md"):
        assert required in files, files


def test_relative_links_resolve():
    assert check_links() == []


def test_python_m_invocations_resolve():
    mods = documented_modules()
    # the load-bearing entry points must actually be documented
    assert {"repro.solvers.cli", "repro.tuning.cli", "repro.serving.cli",
            "repro.launch.serve", "benchmarks.run",
            "benchmarks.compare"} <= mods, mods
    assert check_modules() == []


if __name__ == "__main__":
    failures = check_links() + check_modules()
    for line in failures:
        print(f"DOCS BROKEN: {line}", file=sys.stderr)
    n_links = sum(len(list(iter_relative_links(p))) for p in doc_files())
    print(f"checked {len(doc_files())} docs, {n_links} relative links, "
          f"{len(documented_modules())} python -m entry points: "
          f"{'FAILED' if failures else 'OK'}")
    sys.exit(1 if failures else 0)
