"""Multi-device spectral-solver smoke (run in a subprocess so the fake
device-count XLA flag is set before jax initializes).

Usage: python tests/_dist_solver_check.py [--mesh PUxPV] [--engine NAME]
(expects PYTHONPATH=src)

The tier-1 solver smoke the CI job names: on the 8-fake-device Pu×Pv
pencil mesh (default 4x2), the Poisson manufactured solution must be
recovered to ~1e-10 (f64) and a 2-step Navier–Stokes Taylor–Green run must
dissipate energy monotonically while staying divergence-free; heat and NLS
ride along with their own analytic checks. ``--engine`` runs every case on
that comm engine (the CI mesh × engine matrix); the full run also
exercises the solver-step autotuner on the distributed mesh with a
throwaway cache. Prints CHECK <case> OK per case, then ALL_OK.
"""

import argparse
import math
import sys

from repro.launch.mesh import ensure_host_devices

# the fake-device flag must be set before jax initializes, and the count
# depends on the --mesh argument — peek at argv ahead of argparse
_ndev = 8
if "--mesh" in sys.argv[:-1]:
    _dims = [int(t) for t in sys.argv[sys.argv.index("--mesh") + 1].split("x")]
    _ndev = max(8, math.prod(_dims))
ensure_host_devices(_ndev)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import os  # noqa: E402
import tempfile  # noqa: E402

from repro import compat  # noqa: E402
from repro.solvers import SOLVERS, make_solver  # noqa: E402


def run(pu: int = 4, pv: int = 2, engine: str = ""):
    assert len(jax.devices()) >= pu * pv, jax.devices()
    mesh = compat.make_mesh((pu, pv), ("data", "model"))
    # --engine pins every case's fold communications to one TransposeEngine
    # (the CI matrix); default keeps each case's own plan default
    plan_cfg = {"comm_engine": engine} if engine else None

    for case, steps, kwargs in [
        ("poisson", 1, {}),
        ("navier_stokes", 2, {"nu": 0.1, "dt": 2e-3}),
        ("heat", 3, {}),
        ("nls", 3, {}),
    ]:
        solver = make_solver(case, mesh, 16, plan_cfg=plan_cfg, **kwargs)
        assert not engine or solver.plan.comm_engine == engine
        _, history = solver.run(steps)
        ok, lines = solver.validate(history)
        assert ok, (case, lines, history)
        print(f"CHECK {case} OK  ({'; '.join(lines)})", flush=True)
    assert set(SOLVERS) == {"poisson", "heat", "navier_stokes", "nls"}

    # fused-roundtrip executor: every diagonal-kernel case must produce the
    # same step (≤ 1e-10, f64) whether the spectral roundtrip runs as three
    # barriered phases or streams through the engine's run_roundtrip — on
    # this mesh and (in the CI matrix) this comm engine
    import jax.numpy as jnp

    from repro.solvers.base import SpectralSolver

    cfg = dict(plan_cfg or {})
    for case in ("poisson", "heat", "nls"):
        assert SOLVERS[case].spectral_kernel is not SpectralSolver.spectral_kernel
        composed = make_solver(case, mesh, 16,
                               plan_cfg={**cfg, "fused_roundtrip": False})
        fused = make_solver(case, mesh, 16,
                            plan_cfg={**cfg, "fused_roundtrip": True})
        assert fused.plan.fused_roundtrip and not composed.plan.fused_roundtrip
        fields = composed.init_state().fields
        diff = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(composed._stepj(fields), fused._stepj(fields)))
        assert diff < 1e-10, (case, diff)
        print(f"CHECK {case}_fused OK  (max|fused-composed|={diff:.1e})",
              flush=True)
    # Navier-Stokes' spectral stage is not a diagonal multiply: no fused path
    assert SOLVERS["navier_stokes"].spectral_kernel is \
        SpectralSolver.spectral_kernel

    if engine:
        print("ALL_OK", flush=True)
        return

    # elastic restore: snapshot heat mid-run on this pencil grid, restore
    # onto reshaped grids (checkpoints store full logical arrays), continue,
    # and land back on the reference trajectory — same-shape restores are
    # bitwise, cross-shape ones only reassociate the observable reductions
    from repro.checkpoint.checkpoint import CheckpointManager

    mgr = CheckpointManager(os.path.join(tempfile.mkdtemp(), "ck"), keep=2)
    ref_solver = make_solver("heat", mesh, 16)
    st, ref_hist = ref_solver.init_state(), []
    for i in range(1, 5):
        st = ref_solver.step(st)
        ref_hist.append(ref_solver.observables(st))
        if i == 2:
            mgr.save(i, ref_solver.state_tree(st),
                     meta={"mesh": [pu, pv]}, block=True)

    shapes = [(pu, pv), (pv, pu), (pu * pv, 1)]
    for shape in dict.fromkeys(shapes):
        m2 = compat.make_mesh(shape, ("data", "model"))
        s2 = make_solver("heat", m2, 16)
        st2, meta = s2.restore_state(mgr)
        assert st2.n_steps == 2 and tuple(meta["mesh"]) == (pu, pv)
        hist2 = []
        for _ in range(2):
            st2 = s2.step(st2)
            hist2.append(s2.observables(st2))
        exact = shape == (pu, pv)
        worst = 0.0
        for a, b in zip(ref_hist[2:], hist2):
            for k in a:
                if exact:
                    assert a[k] == b[k], (shape, k, a[k], b[k])
                else:
                    rel = abs(a[k] - b[k]) / max(1e-300, abs(a[k]))
                    worst = max(worst, rel)
                    assert rel < 1e-10, (shape, k, a[k], b[k])
        tag = "bitwise" if exact else f"rel<=|{worst:.1e}|"
        print(f"CHECK restore_{shape[0]}x{shape[1]} OK  ({tag})", flush=True)

    # step-level autotune on the distributed mesh: runs, caches, replays
    from repro.tuning.solver import autotune_solver_step

    cache = os.path.join(tempfile.mkdtemp(), "plans.json")
    res = autotune_solver_step(mesh, "poisson", 16, dtype="float64",
                               cache_path=cache, max_candidates=2, iters=1)
    assert not res.cache_hit and res.rows
    hit = autotune_solver_step(mesh, "poisson", 16, dtype="float64",
                               cache_path=cache, max_candidates=2, iters=1)
    assert hit.cache_hit and hit.best_config == res.best_config
    solver = make_solver("poisson", mesh, 16, plan_cfg=res.best_config)
    _, history = solver.run(1)
    ok, lines = solver.validate(history)
    assert ok, lines
    print(f"CHECK solver_autotune OK  (best {res.best.name})", flush=True)

    print("ALL_OK", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="4x2", help="PUxPV pencil grid")
    ap.add_argument("--engine", default="",
                    help="run every case on this comm engine")
    args = ap.parse_args()
    pu, pv = (int(t) for t in args.mesh.lower().split("x"))
    run(pu, pv, args.engine)
