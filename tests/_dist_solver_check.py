"""Multi-device spectral-solver smoke (run in a subprocess so the fake
device-count XLA flag is set before jax initializes).

Usage: python tests/_dist_solver_check.py  (expects PYTHONPATH=src)

The tier-1 solver smoke the CI job names: on the 8-fake-device 4x2 pencil
mesh, the Poisson manufactured solution must be recovered to ~1e-10 (f64)
and a 2-step Navier–Stokes Taylor–Green run must dissipate energy
monotonically while staying divergence-free; heat and NLS ride along with
their own analytic checks. Also exercises the solver-step autotuner on the
distributed mesh with a throwaway cache. Prints CHECK <case> OK per case,
then ALL_OK.
"""

from repro.launch.mesh import ensure_host_devices

ensure_host_devices(8)

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import os  # noqa: E402
import tempfile  # noqa: E402

from repro import compat  # noqa: E402
from repro.solvers import SOLVERS, make_solver  # noqa: E402


def run():
    assert len(jax.devices()) >= 8, jax.devices()
    mesh = compat.make_mesh((4, 2), ("data", "model"))

    for case, steps, kwargs in [
        ("poisson", 1, {}),
        ("navier_stokes", 2, {"nu": 0.1, "dt": 2e-3}),
        ("heat", 3, {}),
        ("nls", 3, {}),
    ]:
        solver = make_solver(case, mesh, 16, **kwargs)
        _, history = solver.run(steps)
        ok, lines = solver.validate(history)
        assert ok, (case, lines, history)
        print(f"CHECK {case} OK  ({'; '.join(lines)})", flush=True)
    assert set(SOLVERS) == {"poisson", "heat", "navier_stokes", "nls"}

    # step-level autotune on the distributed mesh: runs, caches, replays
    from repro.tuning.solver import autotune_solver_step

    cache = os.path.join(tempfile.mkdtemp(), "plans.json")
    res = autotune_solver_step(mesh, "poisson", 16, dtype="float64",
                               cache_path=cache, max_candidates=2, iters=1)
    assert not res.cache_hit and res.rows
    hit = autotune_solver_step(mesh, "poisson", 16, dtype="float64",
                               cache_path=cache, max_candidates=2, iters=1)
    assert hit.cache_hit and hit.best_config == res.best_config
    solver = make_solver("poisson", mesh, 16, plan_cfg=res.best_config)
    _, history = solver.run(1)
    ok, lines = solver.validate(history)
    assert ok, lines
    print(f"CHECK solver_autotune OK  (best {res.best.name})", flush=True)

    print("ALL_OK", flush=True)


if __name__ == "__main__":
    run()
