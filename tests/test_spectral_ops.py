"""``core.spectral`` operators against NumPy references.

Until now these were exercised only indirectly through the Navier–Stokes
example; here each operator is checked directly on a single-rank grid
(pu=pv=1, empty axis tuples — runs outside shard_map, like
``test_single_device_local_matches_fftn``) where the local slab is the
whole spectral box.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import precision
from repro.core import spectral as sp
from repro.core.decomposition import PencilGrid
from repro.core.fft3d import FFT3DPlan, fft3d_local, ifft3d_local

N = 16


def _plan(real=False):
    grid = PencilGrid(pu=1, pv=1, u_axes=(), v_axes=())
    return FFT3DPlan(n=(N, N, N), grid=grid, real=real)


def _spectral(g):
    """(re, im) planar Z-pencil spectrum of a complex numpy box."""
    k = np.fft.fftn(g, axes=(0, 1, 2)).transpose(2, 0, 1)
    return jnp.asarray(k.real), jnp.asarray(k.imag)


def _np_wavenumbers():
    k = np.fft.fftfreq(N, 1.0 / N)  # integer wavenumbers, fftfreq order
    return np.meshgrid(k, k, k, indexing="ij")  # (kx, ky, kz) natural order


def test_local_wavenumbers_match_fftfreq():
    kx, ky, kz = sp.local_wavenumbers(_plan())
    want = np.fft.fftfreq(N, 1.0 / N)
    np.testing.assert_array_equal(np.asarray(kx)[:, 0, 0], want)
    np.testing.assert_array_equal(np.asarray(ky)[0, :, 0], want)
    np.testing.assert_array_equal(np.asarray(kz)[0, 0, :], want)
    # r2c: kx is the non-negative half (padded grid is trivial at pu=1)
    kxr, _, _ = sp.local_wavenumbers(_plan(real=True))
    np.testing.assert_array_equal(np.asarray(kxr)[:, 0, 0], np.arange(N // 2 + 1))


def test_dealias_mask_two_thirds_rule():
    mask = np.asarray(sp.dealias_mask(_plan()))
    KX, KY, KZ = _np_wavenumbers()
    want = ((np.abs(KX) < N / 3.0) & (np.abs(KY) < N / 3.0)
            & (np.abs(KZ) < N / 3.0)).astype(mask.dtype)
    np.testing.assert_array_equal(mask, want)


def test_poisson_solve_matches_numpy():
    rng = np.random.RandomState(0)
    f = rng.randn(N, N, N)
    fr, fi = _spectral(f.astype(np.complex128))
    pr, pi = sp.poisson_solve(_plan(), fr, fi)
    KX, KY, KZ = _np_wavenumbers()
    k2 = KX ** 2 + KY ** 2 + KZ ** 2
    fk = np.fft.fftn(f, axes=(0, 1, 2)).transpose(2, 0, 1)
    want = np.where(k2 > 0, -fk / np.where(k2 > 0, k2, 1.0), 0.0)
    got = np.asarray(pr) + 1j * np.asarray(pi)
    assert np.linalg.norm(got - want) / np.linalg.norm(want) < 1e-12
    assert got[0, 0, 0] == 0.0  # zero-mean gauge


def test_invert_laplacian_roundtrip_and_mean():
    # manufactured: φ = sin(x)cos(2y)sin(3z), f = ∇²φ = −14 φ
    x = np.linspace(0, 2 * np.pi, N, endpoint=False)
    Y, Z, X = np.meshgrid(x, x, x, indexing="ij")
    phi = np.sin(X) * np.cos(2 * Y) * np.sin(3 * Z)
    f = -14.0 * phi
    plan = _plan(real=True)
    fr, fi = fft3d_local(plan, jnp.asarray(f))
    pr, pi = sp.invert_laplacian(plan, fr, fi, mean=0.0)
    got = np.asarray(ifft3d_local(plan, pr, pi))
    assert np.max(np.abs(got - phi)) < 1e-12
    # non-zero gauge: same solve shifted by a constant mean
    pr2, pi2 = sp.invert_laplacian(plan, fr, fi, mean=2.5)
    got2 = np.asarray(ifft3d_local(plan, pr2, pi2))
    assert np.max(np.abs(got2 - (phi + 2.5))) < 1e-12
    assert abs(np.mean(got2) - 2.5) < 1e-12


def test_gradient_and_curl_match_numpy():
    rng = np.random.RandomState(1)
    g = rng.randn(N, N, N) + 1j * rng.randn(N, N, N)
    fr, fi = _spectral(g)
    KX, KY, KZ = _np_wavenumbers()
    ks = [k.transpose(0, 1, 2) for k in (KX, KY, KZ)]
    fk = np.fft.fftn(g, axes=(0, 1, 2)).transpose(2, 0, 1)
    for (gr, gi), k in zip(sp.gradient(_plan(), fr, fi), ks):
        got = np.asarray(gr) + 1j * np.asarray(gi)
        np.testing.assert_allclose(got, 1j * k * fk, atol=1e-9)

    v = rng.randn(3, N, N, N)
    vk = np.stack([np.fft.fftn(v[c]).transpose(2, 0, 1) for c in range(3)])
    vr = jnp.asarray(vk.real)
    vi = jnp.asarray(vk.imag)
    wr, wi = sp.curl(_plan(), vr, vi)
    got = np.asarray(wr) + 1j * np.asarray(wi)
    want = 1j * np.stack([ks[1] * vk[2] - ks[2] * vk[1],
                          ks[2] * vk[0] - ks[0] * vk[2],
                          ks[0] * vk[1] - ks[1] * vk[0]])
    np.testing.assert_allclose(got, want, atol=1e-9)


def test_project_divergence_free_matches_numpy():
    rng = np.random.RandomState(2)
    v = rng.randn(3, N, N, N)
    vk = np.stack([np.fft.fftn(v[c]).transpose(2, 0, 1) for c in range(3)])
    pr, pi = sp.project_divergence_free(
        _plan(), jnp.asarray(vk.real), jnp.asarray(vk.imag))
    got = np.asarray(pr) + 1j * np.asarray(pi)
    KX, KY, KZ = _np_wavenumbers()
    ks = np.stack([KX, KY, KZ])
    k2 = (ks ** 2).sum(0)
    dot = (ks * vk).sum(0)
    want = vk - ks * np.where(k2 > 0, dot / np.where(k2 > 0, k2, 1.0), 0.0)
    np.testing.assert_allclose(got, want, atol=1e-9)
    # idempotent and annihilates divergence
    div = (ks * got).sum(0)
    assert np.max(np.abs(div)) < 1e-9
    pr2, pi2 = sp.project_divergence_free(_plan(), pr, pi)
    np.testing.assert_allclose(np.asarray(pr2), np.asarray(pr), atol=1e-9)


def test_energy_spectrum_total_is_parseval_sum():
    rng = np.random.RandomState(3)
    v = rng.randn(3, N, N, N)
    vk = np.stack([np.fft.fftn(v[c]).transpose(2, 0, 1) for c in range(3)])
    e = sp.energy_spectrum_total(
        _plan(), jnp.asarray(vk.real), jnp.asarray(vk.imag))
    want = float(np.sum(np.abs(vk) ** 2))
    assert abs(float(e) - want) / want < 1e-12
    # Parseval: Σ|v̂|² = N³ Σ|v|²
    assert abs(float(e) - N ** 3 * float(np.sum(v ** 2))) / want < 1e-12


def test_grid_reductions_trivial_on_single_rank():
    plan = _plan()
    assert float(sp.grid_sum(plan, jnp.asarray(3.0))) == 3.0
    assert float(sp.grid_max(plan, jnp.asarray(4.0))) == 4.0


def test_spectral_dtype_follows_precision_policy():
    # conftest enables x64, so the default must actually be float64
    assert precision.x64_enabled()
    kx, _, _ = sp.local_wavenumbers(_plan())
    assert kx.dtype == jnp.float64
    assert sp.dealias_mask(_plan()).dtype == jnp.float64


def test_pad_mask_zeroes_r2c_padding():
    grid = PencilGrid(pu=4, pv=2)
    plan = FFT3DPlan(n=(16, 16, 16), grid=grid, real=True)
    # padded kx = 12 bins, keep = 9: mask kills the top 3 (they live in the
    # last rank's slab; single-rank view here covers the full padded axis)
    full = PencilGrid(pu=1, pv=1, u_axes=(), v_axes=())
    plan1 = FFT3DPlan(n=(16, 16, 16), grid=full, real=True)
    mask = np.asarray(sp.pad_mask(plan1))[:, 0, 0]
    assert mask.shape[0] == plan1.kx == 9  # pu=1: keep == padded
    assert mask.all()
    assert plan.kx == 12 and plan.kx_keep == 9


def test_rotational_nonlinear_term_is_dealiased_and_solenoidal():
    plan = _plan(real=True)
    x = np.linspace(0, 2 * np.pi, N, endpoint=False)
    Y, Z, X = np.meshgrid(x, x, x, indexing="ij")
    u = np.stack([np.cos(X) * np.sin(Y) * np.sin(Z),
                  -np.sin(X) * np.cos(Y) * np.sin(Z),
                  np.zeros((N, N, N))])
    from repro.core.fft3d import fft3d_vector_local
    vr, vi = fft3d_vector_local(plan, jnp.asarray(u), None)
    nr, ni = sp.rotational_nonlinear_term(plan, vr, vi)
    # projected: k·N = 0
    assert float(sp.max_divergence(plan, nr, ni)) < 1e-8
    # dealiased: nothing above the 2/3 cutoff
    mask = np.asarray(sp.dealias_mask(plan))
    assert np.all(np.abs(np.asarray(nr)) * (1 - mask) == 0)
    assert np.all(np.abs(np.asarray(ni)) * (1 - mask) == 0)


@pytest.mark.parametrize("mean", [0.0, 1.5])
def test_invert_laplacian_mean_modes(mean):
    plan = _plan(real=True)
    rng = np.random.RandomState(4)
    f = rng.randn(N, N, N)
    f -= f.mean()  # solvable source
    fr, fi = fft3d_local(plan, jnp.asarray(f))
    pr, pi = sp.invert_laplacian(plan, fr, fi, mean=mean)
    phi = np.asarray(ifft3d_local(plan, pr, pi))
    assert abs(phi.mean() - mean) < 1e-12
    # residual: ∇²φ = f away from the mean mode
    lap = np.fft.ifftn(
        -(sum(k ** 2 for k in _np_wavenumbers()))
        * np.fft.fftn(phi - mean)).real
    assert np.max(np.abs(lap - f)) < 1e-9
