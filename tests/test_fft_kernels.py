"""Per-kernel validation: shape/dtype sweeps of the Pallas engine vs the
pure-jnp oracle and vs jnp.fft ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.fft_radix2 import fft1d_pallas, ifft1d_pallas, pick_batch_tile
from repro.kernels.ops import fft1d, irfft1d, rfft1d

TOL = {jnp.float32: 2e-4, jnp.float64: 1e-10}


def rel_l2(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-30)


def rand_planar(shape, dtype, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(k1, shape, dtype=dtype),
            jax.random.normal(k2, shape, dtype=dtype))


@pytest.mark.parametrize("n", [2, 4, 8, 64, 128, 512, 1024])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_ref_matches_jnp_fft(n, dtype):
    xr, xi = rand_planar((5, n), dtype)
    yr, yi = ref.fft_dif_planar(xr, xi)
    z = np.fft.fft(np.asarray(xr, np.float64) + 1j * np.asarray(xi, np.float64))
    assert rel_l2(yr, z.real) < TOL[dtype]
    assert rel_l2(yi, z.imag) < TOL[dtype]


@pytest.mark.parametrize("n", [8, 128, 256, 1024, 4096])
@pytest.mark.parametrize("batch", [1, 3, 8, 37])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_pallas_matches_ref(n, batch, dtype):
    xr, xi = rand_planar((batch, n), dtype, seed=n + batch)
    pr, pi = fft1d_pallas(xr, xi)
    rr, ri = ref.fft_dif_planar(xr, xi)
    tol = dict(rtol=1e-4, atol=1e-3) if dtype == jnp.float32 else dict(rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(pr), np.asarray(rr), **tol)
    np.testing.assert_allclose(np.asarray(pi), np.asarray(ri), **tol)


@pytest.mark.parametrize("n", [64, 512])
def test_pallas_multi_lead_axes(n):
    xr, xi = rand_planar((2, 3, 4, n), jnp.float32, seed=1)
    pr, pi = fft1d_pallas(xr, xi)
    z = np.fft.fft(np.asarray(xr, np.float64) + 1j * np.asarray(xi, np.float64))
    assert rel_l2(pr, z.real) < 2e-4
    assert rel_l2(pi, z.imag) < 2e-4


@pytest.mark.parametrize("n", [16, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_pallas_roundtrip(n, dtype):
    xr, xi = rand_planar((4, n), dtype, seed=2)
    yr, yi = fft1d_pallas(xr, xi)
    br, bi = ifft1d_pallas(yr, yi)
    assert rel_l2(br, xr) < TOL[dtype]
    assert rel_l2(bi, xi) < TOL[dtype]


@pytest.mark.parametrize("backend", ["pallas", "ref", "jnp"])
@pytest.mark.parametrize("axis", [0, 1, -1])
def test_fft1d_axis(backend, axis):
    xr, xi = rand_planar((8, 16, 32), jnp.float32, seed=3)
    yr, yi = fft1d(xr, xi, axis=axis, backend=backend)
    z = np.fft.fft(np.asarray(xr, np.float64) + 1j * np.asarray(xi, np.float64), axis=axis)
    assert rel_l2(yr, z.real) < 2e-4
    assert rel_l2(yi, z.imag) < 2e-4


@pytest.mark.parametrize("backend", ["pallas", "ref"])
@pytest.mark.parametrize("packed", [False, True])
def test_rfft_and_inverse(backend, packed):
    n = 128
    x = jax.random.normal(jax.random.PRNGKey(4), (6, n), dtype=jnp.float64)
    yr, yi = rfft1d(x, backend=backend, packed=packed)
    z = np.fft.rfft(np.asarray(x, np.float64))
    assert rel_l2(yr, z.real) < 1e-10
    assert rel_l2(yi, z.imag) < 1e-10
    back = irfft1d(yr, yi, n=n, backend=backend)
    assert rel_l2(back, x) < 1e-10


@pytest.mark.parametrize("backend", ["pallas", "ref", "jnp"])
def test_rfft_packed_rejects_odd_length(backend):
    # even/odd packing assumes n % 2 == 0; odd lengths must fail loudly at
    # trace time, not silently mangle the spectrum
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 9), dtype=jnp.float64)
    with pytest.raises(ValueError, match="even transform length"):
        rfft1d(x, backend=backend, packed=True)
    # the faithful unpacked path still handles odd lengths (jnp engine)
    yr, yi = rfft1d(x, backend="jnp", packed=False)
    z = np.fft.rfft(np.asarray(x, np.float64))
    assert rel_l2(yr, z.real) < 1e-10 and rel_l2(yi, z.imag) < 1e-10


def test_pick_batch_tile_respects_vmem():
    for n in [512, 1024, 4096, 8192]:
        tb = pick_batch_tile(n, 4096, 4)
        assert 6 * tb * n * 4 <= 8 * 1024 * 1024 or tb == 8


def test_twiddle_table_is_rom_like():
    twr, twi = ref.twiddle_table_np(16)
    assert twr.shape == (4, 8)
    # stage 0 row: W_16^j, j=0..7
    j = np.arange(8)
    np.testing.assert_allclose(twr[0], np.cos(-2 * np.pi * j / 16), atol=1e-15)
    # last stage: all-ones (W_2^0 tiled)
    np.testing.assert_allclose(twr[-1], np.ones(8), atol=1e-15)
    np.testing.assert_allclose(twi[-1], np.zeros(8), atol=1e-15)
