"""TransposeEngine layer unit tests: registry, plan wiring, fabric mapping.

(Distributed numerical equivalence of the engines lives in the subprocess
checks of ``test_transpose_dist.py``; this file covers the in-process
plumbing every layer above relies on.)
"""

import numpy as np
import pytest

from repro.core import comm
from repro.core import perfmodel as pm
from repro.core import topology as topo
from repro.core.decomposition import PencilGrid
from repro.core.fft3d import FFT3DPlan


def test_registry_names_and_fabrics():
    assert comm.ENGINE_NAMES == ("switched", "torus", "overlap_ring",
                                 "pallas_ring", "bidi_ring")
    assert comm.engine_fabric("switched") == "switched"
    assert comm.engine_fabric("torus") == "torus"
    # the overlapped rings are still ring traffic — they size the torus
    # fabric (RDMA changes who posts the sends, not how many links exist;
    # the bidirectional ring drives links the torus node already owns)
    assert comm.engine_fabric("overlap_ring") == "torus"
    assert comm.engine_fabric("pallas_ring") == "torus"
    assert comm.engine_fabric("bidi_ring") == "torus"
    with pytest.raises(ValueError, match="unknown comm engine"):
        comm.engine_fabric("carrier_pigeon")
    # an unknown engine cannot even be spelled as a spec, so build_engine
    # (the one constructor since the make_engine shim was removed) is safe
    with pytest.raises(ValueError, match="unknown comm engine"):
        comm.EngineSpec(engine="carrier_pigeon")


def test_fabric_maps_consistent_across_layers():
    # perfmodel keeps a jax-free copy of the engine→fabric map; topology,
    # the candidate space, and Candidate.net all derive from it, and it must
    # stay in lockstep with the engine registry in core.comm
    from repro.tuning.space import ALL_ENGINES, Candidate

    assert set(pm.ENGINE_FABRIC) == set(comm.ENGINE_NAMES)
    assert ALL_ENGINES == comm.ENGINE_NAMES
    for name in comm.ENGINE_NAMES:
        assert pm.ENGINE_FABRIC[name] == comm.engine_fabric(name)
        assert topo.ENGINE_FABRIC[name] == comm.engine_fabric(name)
        assert Candidate(comm_engine=name).net == comm.engine_fabric(name)
    # the analytic model is as strict as every other layer
    with pytest.raises(ValueError, match="unknown comm engine"):
        pm.estimate_plan_seconds(64, 2, 2, comm_engine="carrier_pigeon")


def test_network_plan_for_spec():
    for name in comm.ENGINE_NAMES:
        plan = topo.NetworkPlan.for_spec(comm.EngineSpec(engine=name),
                                         p=64, r=4, f_mhz=180.0)
        assert plan.topology == comm.engine_fabric(name)
        assert plan.required_bw_gbit_s > 0
        assert plan.engine == name and plan.chunks == 0  # problem unknown
        assert plan.message_overhead_s == pm.ENGINE_MESSAGE_OVERHEAD_S[name]

    # every ring engine needs the 4-link torus NICs, the switched engine 2
    def nics(name):
        return topo.NetworkPlan.for_spec(comm.EngineSpec(engine=name),
                                         64, 4, 180.0).nics_per_node
    assert nics("overlap_ring") == 4
    assert nics("pallas_ring") == 4
    assert nics("bidi_ring") == 4
    assert nics("switched") == 2
    with pytest.raises(ValueError, match="unknown comm engine"):
        topo.NetworkPlan.for_spec(comm.EngineSpec(engine="carrier_pigeon"),
                                  64, 4, 180.0)


def test_network_plan_consumes_chunk_model():
    # given the problem size, the fabric plan carries the engine-aware
    # optimal slab count — the RDMA ring's cheap NIC-doorbell sends support
    # finer slabs than the XLA ring on the same fabric
    def plan_for(name, p=64, **kw):
        return topo.NetworkPlan.for_spec(comm.EngineSpec(engine=name),
                                         p, 4, 180.0, **kw)
    ring = plan_for("overlap_ring", n=256)
    rdma = plan_for("pallas_ring", n=256)
    assert ring.chunks == pm.optimal_chunks(256, 8, 8,
                                            comm_engine="overlap_ring",
                                            f_hz=180e6)
    assert rdma.chunks >= ring.chunks >= 1
    assert rdma.message_overhead_s < ring.message_overhead_s
    # non-square p uses the closest-to-square factorization (8 -> 4x2),
    # and the actual pencil grid can be passed explicitly
    a = plan_for("torus", p=8, n=256)
    b = plan_for("torus", p=8, n=256, pu=4, pv=2)
    assert a.chunks == b.chunks == pm.optimal_chunks(256, 4, 2,
                                                     comm_engine="torus",
                                                     f_hz=180e6)
    with pytest.raises(ValueError, match="pu\\*pv"):
        plan_for("torus", p=8, n=256, pu=3, pv=2)
    # per-axis factorization of a grid dimension reaches the chunk model
    c = topo.NetworkPlan.for_spec(comm.EngineSpec(engine="torus"), 16, 4,
                                  180.0, n=256, pu=4, pv=4,
                                  pu_axes=(2, 2), pv_axes=(2, 2))
    assert c.chunks == pm.optimal_chunks(256, 4, 4, comm_engine="torus",
                                         f_hz=180e6, pu_axes=(2, 2),
                                         pv_axes=(2, 2))


def test_plan_engine_field_derivation():
    grid = PencilGrid(pu=1, pv=1, u_axes=(), v_axes=())
    # legacy net-only construction names the engine
    plan = FFT3DPlan(n=(8, 8, 8), grid=grid, net="torus")
    assert plan.comm_engine == "torus" and plan.net == "torus"
    # engine choice overrides/derives the fabric
    plan = FFT3DPlan(n=(8, 8, 8), grid=grid, comm_engine="overlap_ring")
    assert plan.net == "torus"
    assert isinstance(plan.engine(), comm.OverlapRingEngine)
    plan = FFT3DPlan(n=(8, 8, 8), grid=grid)
    assert plan.comm_engine == "switched" and plan.net == "switched"
    assert isinstance(plan.engine(), comm.SwitchedEngine)
    with pytest.raises(ValueError, match="unknown comm_engine"):
        FFT3DPlan(n=(8, 8, 8), grid=grid, comm_engine="carrier_pigeon")


def test_engine_chunks_follow_plan_schedule():
    grid = PencilGrid(pu=1, pv=1, u_axes=(), v_axes=())
    plan = FFT3DPlan(n=(8, 8, 8), grid=grid, schedule="pipelined", chunks=4,
                     comm_engine="overlap_ring")
    assert plan.engine().chunks == 4
    # sequential plans collapse to one slab (base engines) — the overlap
    # ring still slices at ring-block granularity internally
    plan = FFT3DPlan(n=(8, 8, 8), grid=grid, schedule="sequential", chunks=4)
    assert plan.chunks == 1 and plan.engine().chunks == 1


def test_overlap_estimate_hides_communication():
    # at a scale where fold traffic dominates, the overlapped ring's estimate
    # approaches max(T_comp, T_net) instead of the serial sum
    kw = dict(backend="jnp", schedule="sequential", chunks=1)
    serial = pm.estimate_plan_seconds(256, 8, 8, net="torus", **kw)
    overlap = pm.estimate_plan_seconds(256, 8, 8, comm_engine="overlap_ring",
                                       **kw)
    assert overlap < serial
    # the RDMA ring's explicit double buffering + NIC-posted sends beat the
    # XLA-scheduled overlap on every communicating mesh
    for pu, pv in [(4, 2), (2, 2), (2, 1), (8, 8)]:
        rdma = pm.estimate_plan_seconds(256, pu, pv,
                                        comm_engine="pallas_ring", **kw)
        xla = pm.estimate_plan_seconds(256, pu, pv,
                                       comm_engine="overlap_ring", **kw)
        assert rdma < xla, (pu, pv)
        # driving both torus directions can only help: the bidi ring never
        # estimates above the unidirectional RDMA ring, and is strictly
        # faster once a ring dimension exceeds the 2-rank degenerate case
        # (where both directions name the same neighbor)
        bidi = pm.estimate_plan_seconds(256, pu, pv,
                                        comm_engine="bidi_ring", **kw)
        assert bidi <= rdma, (pu, pv)
        if max(pu, pv) > 2:
            assert bidi < rdma, (pu, pv)
    # degenerate grid: no communication, engines estimate identically
    assert pm.estimate_plan_seconds(64, 1, 1, comm_engine="overlap_ring") == \
        pytest.approx(pm.estimate_plan_seconds(64, 1, 1))
    assert pm.estimate_plan_seconds(64, 1, 1, comm_engine="pallas_ring") == \
        pytest.approx(pm.estimate_plan_seconds(64, 1, 1))
    assert pm.estimate_plan_seconds(64, 1, 1, comm_engine="bidi_ring") == \
        pytest.approx(pm.estimate_plan_seconds(64, 1, 1))
    # the wire-time ratio behind the bidi estimate: ceil((q-1)/2)/(q-1)
    assert pm.bidi_round_ratio(2) == 1.0
    assert pm.bidi_round_ratio(3) == pytest.approx(0.5)
    assert pm.bidi_round_ratio(8) == pytest.approx(4 / 7)
    # ...and the dispatch count it pays per fold
    assert pm.fold_messages(8, "torus", "bidi_ring") == 4
    assert pm.fold_messages(8, "torus", "pallas_ring") == 7
    assert pm.fold_messages(8, "switched") == 1
    assert pm.fold_messages(1, "torus", "bidi_ring") == 0


def test_engine_aware_chunk_model():
    # optimal chunks balance pipeline fill against per-message overhead:
    # cheaper messages -> finer slabs, no communication -> nothing to chunk
    for eng in comm.ENGINE_NAMES:
        k = pm.optimal_chunks(64, 4, 2, comm_engine=eng)
        assert k >= 1 and (k & (k - 1)) == 0  # power of two
        assert pm.optimal_chunks(64, 1, 1, comm_engine=eng) == 1
    assert pm.optimal_chunks(256, 8, 8, comm_engine="pallas_ring") >= \
        pm.optimal_chunks(256, 8, 8, comm_engine="overlap_ring")
    # bigger problems amortize the same per-message cost over more fill
    assert pm.optimal_chunks(512, 8, 8, comm_engine="torus") >= \
        pm.optimal_chunks(32, 8, 8, comm_engine="torus")
    with pytest.raises(ValueError, match="unknown comm engine"):
        pm.optimal_chunks(64, 4, 2, comm_engine="carrier_pigeon")
    # the tuning space consumes the model: candidates carry per-engine
    # chunk choices (the optimum and its power-of-two neighbors)
    from repro.tuning.space import candidate_space
    for eng in comm.ENGINE_NAMES:
        cands = pm.chunk_candidates(64, 4, 2, eng)
        assert cands and all(c >= 2 for c in cands)
        opt = pm.optimal_chunks(64, 4, 2, comm_engine=eng)
        assert opt in cands or opt <= 1
        piped = {c.chunks for c in candidate_space(64, 4, 2, backends=["jnp"])
                 if c.comm_engine == eng and c.schedule == "pipelined"}
        assert piped == set(cands)
    # no-communication grids fall back to the engine-blind legacy choices
    assert pm.chunk_candidates(64, 1, 1, "switched") == (2, 4, 8)


def test_ring_exchange_rdma_tpu_path_preserves_interleave(monkeypatch):
    # the fused kernel is atomic, so on the TPU path a JAX-level interleave
    # thunk must still run (serialized, before the kernel) and its result
    # must come back as `follow` — dropping it would crash the slab
    # pipeline of every non-fusable phase (kernel stubbed: no TPU here)
    import jax.numpy as jnp

    from repro.kernels import ring_rdma

    monkeypatch.setattr(ring_rdma, "_ring_rdma_tpu",
                        lambda arrs, axes, **kw: (list(arrs), None))
    monkeypatch.setattr(ring_rdma.compat, "axes_size", lambda axes: 4)
    outs, follow = ring_rdma.ring_exchange_rdma(
        (jnp.ones((4, 2)),), ("data",), split_axis=0, concat_axis=1,
        interleave=lambda: "butterflies-ran", interpret=False)
    assert follow == "butterflies-ran" and len(outs) == 1


def test_pallas_ring_engine_kwargs():
    # plan-derived engines know the butterfly backend and data model they
    # schedule (the fusion decision of the RDMA kernel)
    from repro.core.fft3d import FFT3DPlan

    grid = PencilGrid(pu=1, pv=1, u_axes=(), v_axes=())
    plan = FFT3DPlan(n=(8, 8, 8), grid=grid, comm_engine="pallas_ring",
                     backend="pallas", real=True)
    eng = plan.engine()
    assert isinstance(eng, comm.PallasRingEngine)
    assert eng.backend == "pallas" and eng.real is True
    assert plan.net == "torus"
    # the bidi ring is a full engine too: plan-selectable, fusion-aware
    plan = FFT3DPlan(n=(8, 8, 8), grid=grid, comm_engine="bidi_ring",
                     backend="pallas")
    eng = plan.engine()
    assert isinstance(eng, comm.BidiRingEngine)
    assert isinstance(eng, comm.PallasRingEngine)  # shares the RDMA hooks
    assert plan.net == "torus" and eng.backend == "pallas"


def test_spectral_roundtrip_fused_matches_composed():
    # single-device slice of the fused executor: the streamed yz roundtrip
    # (fold k+1 ∥ kernel k ∥ unfold k−1) must reproduce the composed
    # fft → multiply → ifft to fp64 round-off on every engine, schedule,
    # and data model (the distributed version lives in _dist_solver_check /
    # _dist_fft_check; this covers the slab bookkeeping in-process)
    import jax.numpy as jnp

    from repro.core.fft3d import DiagonalKernel, spectral_roundtrip_local

    grid = PencilGrid(pu=1, pv=1, u_axes=(), v_axes=())
    rng = np.random.RandomState(7)
    for real in (False, True):
        for engine in comm.ENGINE_NAMES:
            for schedule, chunks in (("sequential", 1), ("pipelined", 2),
                                     ("pipelined", 4)):
                base = dict(n=(8, 8, 8), grid=grid, real=real,
                            schedule=schedule, chunks=chunks,
                            comm_engine=engine)
                composed = FFT3DPlan(**base)
                fused = FFT3DPlan(**base, fused_roundtrip=True)
                assert not composed.fused_roundtrip and fused.fused_roundtrip
                # a complex diagonal (NLS-style rotation) exercises both
                # multiplier parts through the slab-sliced apply()
                theta = jnp.asarray(rng.randn(composed.kx, 8, 8))
                kern = DiagonalKernel(dr=jnp.cos(theta), di=jnp.sin(theta))
                xr = jnp.asarray(rng.randn(8, 8, 8))
                args = (xr,) if real else (xr, jnp.asarray(rng.randn(8, 8, 8)))
                want = spectral_roundtrip_local(composed, kern, *args)
                got = spectral_roundtrip_local(fused, kern, *args)
                want = (want,) if real else want
                got = (got,) if real else got
                for g, w in zip(got, want):
                    np.testing.assert_allclose(
                        np.asarray(g), np.asarray(w), rtol=0, atol=1e-10,
                        err_msg=f"{engine}/{schedule}{chunks}/real={real}")


def test_roundtrip_estimate_fused_never_slower():
    # the analytic roundtrip model: composed = 2·transform + kernel sweep;
    # fused hides min(kernel, yz wire) of that — never predicting a fused
    # schedule above the composed one, and collapsing to equality when the
    # yz fold does not communicate (pv == 1: nothing to hide behind)
    kw = dict(backend="jnp", schedule="sequential", chunks=1)
    for engine in comm.ENGINE_NAMES:
        for pu, pv in [(8, 8), (4, 2), (2, 4), (2, 1), (1, 2), (1, 1)]:
            comp = pm.estimate_roundtrip_seconds(256, pu, pv, fused=False,
                                                 comm_engine=engine, **kw)
            fus = pm.estimate_roundtrip_seconds(256, pu, pv, fused=True,
                                                comm_engine=engine, **kw)
            one = pm.estimate_plan_seconds(256, pu, pv, comm_engine=engine,
                                           **kw)
            assert comp > 2 * one  # the kernel sweep costs something
            assert fus <= comp, (engine, pu, pv)
            if pv == 1:
                assert fus == pytest.approx(comp), (engine, pu)
            else:
                assert fus < comp, (engine, pu, pv)
    # a weightless kernel leaves nothing to hide: fused == composed
    assert pm.estimate_roundtrip_seconds(
        256, 4, 2, fused=True, kernel_weight=0.0, comm_engine="torus") == \
        pytest.approx(pm.estimate_roundtrip_seconds(
            256, 4, 2, fused=False, kernel_weight=0.0, comm_engine="torus"))
    # spec spelling: fused defaults from the spec knob, explicit wins
    spec = comm.EngineSpec(engine="overlap_ring", schedule="pipelined",
                           chunks=4, fused_roundtrip=True)
    via_spec = pm.estimate_roundtrip_seconds(256, 4, 2, spec=spec)
    assert via_spec == pm.estimate_roundtrip_seconds(
        256, 4, 2, fused=True, comm_engine="overlap_ring",
        schedule="pipelined", chunks=4)
    assert pm.estimate_roundtrip_seconds(256, 4, 2, spec=spec,
                                         fused=False) > via_spec
    with pytest.raises(ValueError, match="unknown comm engine"):
        pm.estimate_roundtrip_seconds(64, 2, 2, comm_engine="carrier_pigeon")


def test_run_chunked_matches_unchunked():
    import jax.numpy as jnp

    x = jnp.asarray(np.random.RandomState(0).randn(6, 4, 8))
    fn = lambda a: (a * 2.0, a - 1.0)
    whole = fn(x)
    for chunks in (1, 2, 3, 5):  # 5 does not divide 6 -> falls back to 3
        out = comm.run_chunked(fn, (x,), axis=0, chunks=chunks)
        for got, want in zip(out, whole):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # negative axis is normalized
    out = comm.run_chunked(fn, (x,), axis=-3, chunks=2)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(whole[0]))
