"""Measured perf-model calibration (``repro.tuning.calibrate``): document
validation, the plan-cache-style fingerprint replay discipline, and — the
point of the subsystem — that a calibration actually changes what the
analytic model tells the autotuner (chunk choice and candidate ranking)
relative to the built-in priors."""

import json
import os
import subprocess
import sys

import pytest

from repro.core import perfmodel as pm
from repro.core import topology as topo
from repro.tuning import calibrate as cal
from repro.tuning.space import candidate_space

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def synth_doc(engine_overheads=None, backend_weights=None):
    """A valid calibration document for the *current* substrate."""
    return {
        "schema": cal.SCHEMA,
        "fingerprint": cal.substrate_fingerprint(),
        "mesh": "4x2",
        "quick": True,
        "iters": 1,
        "engine_message_overhead_s": dict(engine_overheads or {}),
        "backend_compute_weight": dict(backend_weights or {"jnp": 1.0}),
        "created": "2026-07-31T00:00:00+00:00",
    }


# ---------------------------------------------------------------------------
# document well-formedness + replay discipline
# ---------------------------------------------------------------------------

def test_validate_calibration():
    assert cal.validate_calibration(synth_doc({"torus": 1e-6})) == []
    assert cal.validate_calibration("nope")  # not an object
    assert any("schema" in p for p in cal.validate_calibration(
        {**synth_doc({"torus": 1e-6}), "schema": "bench-fft/v1"}))
    # incomplete fingerprint
    doc = synth_doc({"torus": 1e-6})
    del doc["fingerprint"]["platform"]
    assert any("fingerprint.platform" in p for p in cal.validate_calibration(doc))
    # unknown names and non-positive / non-finite values are rejected
    assert any("carrier_pigeon" in p for p in cal.validate_calibration(
        synth_doc({"carrier_pigeon": 1e-6})))
    assert any("not a positive" in p for p in cal.validate_calibration(
        synth_doc({"torus": -1.0})))
    assert any("not a positive" in p for p in cal.validate_calibration(
        synth_doc({"torus": float("nan")})))
    assert any("not a positive" in p for p in cal.validate_calibration(
        synth_doc(backend_weights={"jnp": True})))
    # an all-empty calibration carries no signal
    empty = synth_doc()
    empty["backend_compute_weight"] = {}
    assert any("no measured values" in p for p in cal.validate_calibration(empty))


def test_save_load_and_fingerprint_discipline(tmp_path):
    path = str(tmp_path / "sub" / "calibration.json")
    doc = synth_doc({"torus": 3e-6}, {"jnp": 1.0, "ref": 4.0})
    assert cal.save_calibration(doc, path) == path
    assert cal.load_calibration(path) == doc
    assert cal.load_active_calibration(path) == doc
    # a calibration measured on another substrate must never be replayed
    foreign = dict(doc, fingerprint={**doc["fingerprint"],
                                     "device_kind": "TPU v5e"})
    cal.save_calibration(foreign, path)
    assert cal.load_active_calibration(path) is None
    # malformed documents degrade to None, never raise
    with open(path, "w") as f:
        f.write("{not json")
    assert cal.load_calibration(path) is None
    assert cal.load_active_calibration(path) is None
    assert cal.load_active_calibration(str(tmp_path / "missing.json")) is None


def test_default_path_env_override(tmp_path, monkeypatch):
    monkeypatch.setenv(cal.ENV_VAR, str(tmp_path / "c.json"))
    assert cal.default_calibration_path() == str(tmp_path / "c.json")
    monkeypatch.delenv(cal.ENV_VAR)
    assert cal.default_calibration_path().endswith(
        os.path.join(".cache", "repro", "calibration.json"))


# ---------------------------------------------------------------------------
# the calibration must change what the model tells the autotuner
# ---------------------------------------------------------------------------

def test_calibration_changes_chunk_choice():
    prior_k = pm.optimal_chunks(256, 8, 8, comm_engine="overlap_ring")
    prior_cands = pm.chunk_candidates(256, 8, 8, "overlap_ring")
    assert prior_k > 1  # the trade is live on this problem

    # messages measured 1000x more expensive -> far coarser slabs
    pm.set_calibration(synth_doc({"overlap_ring": 2e-3}))
    k_slow = pm.optimal_chunks(256, 8, 8, comm_engine="overlap_ring")
    cands_slow = pm.chunk_candidates(256, 8, 8, "overlap_ring")
    assert k_slow < prior_k
    assert cands_slow != prior_cands
    # ...and the tuning space consumes the calibrated enumeration
    piped = {c.chunks for c in candidate_space(256, 8, 8, backends=["jnp"])
             if c.comm_engine == "overlap_ring" and c.schedule == "pipelined"}
    assert piped == set(cands_slow)

    # messages measured cheaper -> finer slabs
    pm.set_calibration(synth_doc({"overlap_ring": 2e-8}))
    assert pm.optimal_chunks(256, 8, 8, comm_engine="overlap_ring") > k_slow

    # engines the calibration did not measure keep their priors
    pm.set_calibration(synth_doc({"overlap_ring": 2e-3}))
    assert pm.message_overhead_s("torus") == pm.ENGINE_MESSAGE_OVERHEAD_S["torus"]


def test_calibration_changes_candidate_ranking():
    def ranking():
        cands = [c for c in candidate_space(64, 4, 2, backends=["jnp"])]
        cands.sort(key=lambda c: pm.estimate_plan_seconds(
            64, 4, 2, backend=c.backend, schedule=c.schedule, chunks=c.chunks,
            comm_engine=c.comm_engine, r2c_packed=c.r2c_packed))
        return [c.name for c in cands]

    prior = ranking()
    # under the priors the RDMA rings' cheap NIC-doorbell sends win; measure
    # them catastrophically expensive and they must fall behind the fabrics
    # whose dispatches stayed cheap
    pm.set_calibration(synth_doc({"pallas_ring": 5e-3, "bidi_ring": 5e-3}))
    calibrated = ranking()
    assert calibrated != prior
    est = lambda engine: pm.estimate_plan_seconds(64, 4, 2, comm_engine=engine)
    assert est("pallas_ring") > est("torus")
    assert est("bidi_ring") > est("torus")
    pm.set_calibration(None)
    assert est("pallas_ring") < est("torus")  # priors restored


def test_calibration_changes_backend_weights():
    # priors: the interpreted pallas backend ranks far behind jnp
    prior = pm.estimate_plan_seconds(64, 4, 2, backend="pallas")
    assert prior > pm.estimate_plan_seconds(64, 4, 2, backend="jnp")
    # measured on a TPU-like substrate the kernel beats XLA's FFT
    pm.set_calibration(synth_doc(backend_weights={"jnp": 1.0, "pallas": 0.5}))
    assert pm.backend_compute_weight("pallas") == 0.5
    calibrated = pm.estimate_plan_seconds(64, 4, 2, backend="pallas")
    assert calibrated < pm.estimate_plan_seconds(64, 4, 2, backend="jnp")
    assert calibrated < prior
    # unmeasured backends keep their priors
    assert pm.backend_compute_weight("mxu") == pm.BACKEND_COMPUTE_WEIGHT["mxu"]


def test_validate_calibration_link_bandwidth():
    # the optional wire-bandwidth slope: positive finite numbers pass and
    # count as signal, anything else is rejected
    doc = synth_doc({"torus": 1e-6})
    assert cal.validate_calibration({**doc, "link_bytes_per_s": 12.5e9}) == []
    for bad in (-1.0, 0.0, float("nan"), float("inf"), True, "fast"):
        assert any("link_bytes_per_s" in p for p in cal.validate_calibration(
            {**doc, "link_bytes_per_s": bad})), bad
    # a document whose only measurement is the link slope still carries signal
    empty = synth_doc()
    empty["backend_compute_weight"] = {}
    assert cal.validate_calibration(
        {**empty, "link_bytes_per_s": 12.5e9}) == []


def test_calibration_changes_link_bandwidth():
    # unmeasured -> the built-in prior
    assert pm.link_bytes_per_s() == pm.LINK_BYTES_PER_S
    prior = pm.estimate_plan_seconds(256, 8, 8, comm_engine="torus")
    prior_rt = pm.estimate_roundtrip_seconds(256, 8, 8, fused=True,
                                             comm_engine="torus")
    # wires measured 10x slower -> every wire-bound estimate grows (the doc
    # carries only the slope, so message overheads keep their priors)
    pm.set_calibration({**synth_doc(),
                        "link_bytes_per_s": pm.LINK_BYTES_PER_S / 10})
    assert pm.link_bytes_per_s() == pytest.approx(pm.LINK_BYTES_PER_S / 10)
    assert pm.estimate_plan_seconds(256, 8, 8, comm_engine="torus") > prior
    assert pm.estimate_roundtrip_seconds(256, 8, 8, fused=True,
                                         comm_engine="torus") > prior_rt
    # an explicit caller value still overrides the calibrated slope
    assert pm.estimate_plan_seconds(
        256, 8, 8, comm_engine="torus",
        link_bytes_per_s=pm.LINK_BYTES_PER_S) == pytest.approx(prior)
    pm.set_calibration(None)
    assert pm.link_bytes_per_s() == pm.LINK_BYTES_PER_S


def test_network_plan_reports_calibrated_overhead():
    from repro.core.engine_spec import EngineSpec

    spec = EngineSpec(engine="pallas_ring")
    pm.set_calibration(synth_doc({"pallas_ring": 42e-6}))
    plan = topo.NetworkPlan.for_spec(spec, p=64, r=4, f_mhz=180.0)
    assert plan.message_overhead_s == pytest.approx(42e-6)
    pm.set_calibration(None)
    assert topo.NetworkPlan.for_spec(
        spec, p=64, r=4, f_mhz=180.0).message_overhead_s == \
        pm.ENGINE_MESSAGE_OVERHEAD_S["pallas_ring"]


def test_lazy_load_from_calibration_file(tmp_path, monkeypatch):
    # the on-disk route the autotuner takes: $REPRO_CALIBRATION -> lazily
    # loaded on first model query after reset_calibration()
    path = str(tmp_path / "calibration.json")
    cal.save_calibration(synth_doc({"torus": 7e-5}), path)
    monkeypatch.setenv(cal.ENV_VAR, path)
    pm.reset_calibration()
    assert pm.message_overhead_s("torus") == pytest.approx(7e-5)
    assert pm.active_calibration()["engine_message_overhead_s"]["torus"] == 7e-5
    # a foreign-substrate file is ignored end to end
    doc = synth_doc({"torus": 7e-5})
    doc["fingerprint"]["jax_version"] = "0.0.0"
    cal.save_calibration(doc, path)
    pm.reset_calibration()
    assert pm.message_overhead_s("torus") == pm.ENGINE_MESSAGE_OVERHEAD_S["torus"]


# ---------------------------------------------------------------------------
# CLI (subprocess: owns its XLA device-count flag)
# ---------------------------------------------------------------------------

def test_cli_writes_wellformed_calibration(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out_path = str(tmp_path / "calibration.json")
    out = subprocess.run(
        [sys.executable, "-m", "repro.tuning.calibrate", "--quick",
         "--mesh", "2x1", "--iters", "1", "--out", out_path],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=str(tmp_path))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "wrote" in out.stdout and "message overhead" in out.stdout
    doc = json.load(open(out_path))
    assert cal.validate_calibration(doc) == []
    assert doc["schema"] == cal.SCHEMA
    assert doc["mesh"] == "2x1" and doc["quick"] is True
    # the 2-rank fold communicates, so engines get measured — but the
    # zero-payload fit legitimately drops any engine whose 1-iteration
    # timing came out noise-negative, so only membership is pinned, not
    # completeness (validate_calibration already rejects unknown names)
    assert set(doc["engine_message_overhead_s"]) <= \
        set(pm.ENGINE_MESSAGE_OVERHEAD_S)
    assert doc["backend_compute_weight"].get("jnp") == 1.0
