"""``repro.fleet`` unit coverage: the fault-spec grammar, the structured
failure records, and the controller's supervision loop — retry, backoff
mesh reshaping, quarantine, timeout kills, env hygiene — exercised against
a fast jax-free stub worker (the real-worker integration lives in
``tests/test_fleet_restart.py``).
"""

import json
import os
import sys

import pytest

from repro import obs
from repro.fleet import (Fault, FailureRecord, FleetController, FleetJob,
                         classify_exit, parse_fault_spec)
from repro.fleet.faults import plan_from_env
from repro.fleet.records import KILL_EXIT, POISON_EXIT

# ---------------------------------------------------------------------------
# fault-spec grammar
# ---------------------------------------------------------------------------


def test_parse_fault_spec_grammar():
    plan = parse_fault_spec(
        "kill-at-step:3; torn-checkpoint:2:times=2@job=job1;"
        "slow-at-step:1:30.5")
    assert len(plan.faults) == 3 and bool(plan)
    kill, torn, slow = plan.faults
    assert kill == Fault(kind="kill-at-step", step=3)
    assert torn.times == 2 and torn.job == "job1"
    assert slow.seconds == 30.5
    assert not parse_fault_spec("") and not parse_fault_spec(None)


def test_fault_filtering_by_job_and_attempt():
    plan = parse_fault_spec("kill-at-step:3@job=job0;torn-checkpoint:1:times=2")
    # default times=1: attempt 0 only — a retry sails through
    assert [f.kind for f in plan.active("job0", 0)] == \
        ["kill-at-step", "torn-checkpoint"]
    assert [f.kind for f in plan.active("job0", 1)] == ["torn-checkpoint"]
    assert plan.active("job0", 2) == []
    assert [f.kind for f in plan.active("job1", 0)] == ["torn-checkpoint"]


@pytest.mark.parametrize("bad", [
    "explode-at-step:3",            # unknown kind
    "kill-at-step",                 # missing step
    "kill-at-step:x",               # non-integer step
    "kill-at-step:3:5",             # extra positional arg
    "slow-at-step:3",               # missing seconds
    "kill-at-step:3:whens=2",       # unknown option
    "kill-at-step:3:times=0",       # times < 1
    "kill-at-step:3@job=",          # empty job id
    "kill-at-step:3@jid=j0",        # malformed filter
])
def test_parse_fault_spec_rejects(bad):
    with pytest.raises(ValueError):
        parse_fault_spec(bad)


def test_plan_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_FAULT_SPEC", "kill-at-step:7")
    assert plan_from_env().faults[0].step == 7
    monkeypatch.delenv("REPRO_FAULT_SPEC")
    assert not plan_from_env()
    assert plan_from_env("slow-at-step:1:2").faults[0].seconds == 2.0


# ---------------------------------------------------------------------------
# failure records + exit classification
# ---------------------------------------------------------------------------

def test_failure_record_roundtrip_and_validation():
    rec = FailureRecord(kind="timeout", where="fleet.worker", job_id="j0",
                        attempt=1, detail="deadline", exit_code=None,
                        retryable=True, time_s=1.5)
    clone = FailureRecord.from_dict({**rec.to_dict(), "extra": "ignored"})
    assert clone == rec
    with pytest.raises(ValueError, match="unknown failure kind"):
        FailureRecord(kind="gremlin", where="x", job_id="j0")


def test_classify_exit():
    assert classify_exit(POISON_EXIT) == ("poison", False)
    assert classify_exit(KILL_EXIT) == ("crash", True)
    assert classify_exit(1) == ("crash", True)


# ---------------------------------------------------------------------------
# the controller against a stub worker (no jax in the subprocess)
# ---------------------------------------------------------------------------

_STUB = r'''
import argparse, json, os, sys, time

ap = argparse.ArgumentParser()
ap.add_argument("--spec")
ap.add_argument("--attempt", type=int, default=0)
a = ap.parse_args()
with open(a.spec) as f:
    spec = json.load(f)
mode = spec["params"].get("mode", "ok")
if mode == "poison":
    sys.exit(4)                                   # records.POISON_EXIT
if mode == "hang":
    time.sleep(60)
if mode == "crash-once" and a.attempt == 0:
    with open(spec["progress_path"], "a") as f:
        f.write(json.dumps({"step": 0, "attempt": 0,
                            "obs": {"amp": 1.0}}) + "\n")
        f.write('{"step": 1, "att')               # torn tail, then die
        f.flush()
    os._exit(13)                                  # records.KILL_EXIT
for step in range(spec["steps"] + 1):
    obs = {"amp": 1.0 / (step + 1), "mesh": spec["mesh"]}
    if mode == "env":
        obs = {"has_xla": int("XLA_FLAGS" in os.environ),
               "fault": os.environ.get("REPRO_FAULT_SPEC", "")}
    with open(spec["progress_path"], "a") as f:
        f.write(json.dumps({"step": step, "attempt": a.attempt,
                            "obs": obs}) + "\n")
tmp = spec["result_path"] + ".tmp"
with open(tmp, "w") as f:
    json.dump({"job_id": spec["job_id"], "attempt": a.attempt,
               "final_step": spec["steps"], "restore_latency_us": 12.5,
               "checkpoint_bytes": 2048}, f)
os.replace(tmp, spec["result_path"])
'''


@pytest.fixture()
def stub(tmp_path):
    path = tmp_path / "stub_worker.py"
    path.write_text(_STUB)
    return (sys.executable, str(path))


def _job(jid, mode, **kw):
    kw.setdefault("steps", 3)
    kw.setdefault("mesh", (1, 1))
    return FleetJob(job_id=jid, case="heat", params={"mode": mode}, **kw)


def _controller(jobs, stub, tmp_path, **kw):
    kw.setdefault("total_slots", 4)
    kw.setdefault("backoff_base_s", 0.01)
    kw.setdefault("backoff_cap_s", 0.05)
    kw.setdefault("verbose", False)
    return FleetController(jobs, workdir=str(tmp_path / "work"),
                           worker_argv=stub, **kw)


def test_crash_is_retried_and_completes(stub, tmp_path):
    ctl = _controller([_job("j0", "crash-once")], stub, tmp_path)
    with obs.capture() as (_, metrics):
        results = ctl.run()
    res = results["j0"]
    assert res.ok and res.attempts == 2
    assert [f.kind for f in res.failures] == ["crash"]
    assert res.failures[0].exit_code == KILL_EXIT and res.failures[0].retryable
    # torn tail tolerated; attempt-1 lines win the merge
    assert sorted(res.history) == [0, 1, 2, 3]
    assert res.final_observables()["amp"] == 0.25
    assert res.restore_latency_us == 12.5 and res.checkpoint_bytes == 2048
    assert ctl.counters["fleet.jobs.scheduled"] == 2
    assert ctl.counters["fleet.jobs.retried"] == 1
    assert ctl.counters["fleet.jobs.failures"] == 1
    assert ctl.counters["fleet.jobs.completed"] == 1
    assert ctl.counters["fleet.jobs.quarantined"] == 0
    # mirrored into repro.obs when tracing is on
    assert metrics.counters()["fleet.jobs.retried"] == 1


def test_poison_quarantines_without_retry_and_siblings_survive(stub, tmp_path):
    ctl = _controller([_job("bad", "poison"), _job("good", "ok")],
                      stub, tmp_path, max_retries=3)
    results = ctl.run()
    bad, good = results["bad"], results["good"]
    assert bad.status == "quarantined" and bad.attempts == 1
    assert [(f.kind, f.retryable) for f in bad.failures] == [("poison", False)]
    assert bad.failures[0].exit_code == POISON_EXIT
    assert good.ok and good.attempts == 1          # never blocked on bad
    assert ctl.counters["fleet.jobs.quarantined"] == 1
    assert ctl.counters["fleet.jobs.retried"] == 0


def test_timeout_kill_is_classified_and_budget_quarantines(stub, tmp_path):
    ctl = _controller([_job("hung", "hang")], stub, tmp_path,
                      max_retries=0, timeout_s=0.5)
    results = ctl.run()
    res = results["hung"]
    assert res.status == "quarantined"
    assert [f.kind for f in res.failures] == ["timeout"]
    assert "deadline" in res.failures[0].detail


def test_retry_budget_exhaustion_collects_the_full_trail(stub, tmp_path):
    # every attempt poisons itself crash-like? no — hang at tiny timeout
    ctl = _controller([_job("hung", "hang")], stub, tmp_path,
                      max_retries=2, timeout_s=0.3)
    results = ctl.run()
    res = results["hung"]
    assert res.status == "quarantined" and res.attempts == 3
    assert [f.kind for f in res.failures] == ["timeout"] * 3
    assert [f.attempt for f in res.failures] == [0, 1, 2]


def test_reshape_on_retry_changes_the_attempt_submesh(stub, tmp_path):
    ctl = _controller([_job("j0", "crash-once", mesh=(2, 1))], stub, tmp_path,
                      reshape_on_retry=((1, 2), (2, 2)))
    assert ctl._retry_mesh(ctl.jobs[0], 0) == (2, 1)
    assert ctl._retry_mesh(ctl.jobs[0], 1) == (1, 2)
    assert ctl._retry_mesh(ctl.jobs[0], 2) == (2, 2)
    assert ctl._retry_mesh(ctl.jobs[0], 3) == (1, 2)
    results = ctl.run()
    assert results["j0"].ok
    # the retried attempt's spec really carried the reshaped submesh
    with open(os.path.join(ctl.workdir, "j0.attempt1.spec.json")) as f:
        assert json.load(f)["mesh"] == [1, 2]
    assert results["j0"].history[3]["mesh"] == [1, 2]


def test_worker_env_is_scrubbed_and_faults_forwarded(stub, tmp_path,
                                                     monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    spec = "kill-at-step:99@job=nobody"
    ctl = _controller([_job("j0", "env")], stub, tmp_path, fault_spec=spec)
    results = ctl.run()
    obs0 = results["j0"].history[0]
    assert obs0["has_xla"] == 0            # inherited flag must not leak in
    assert obs0["fault"] == spec           # spec rides the env to the worker


def test_controller_validates_before_launching(stub, tmp_path):
    with pytest.raises(ValueError, match="duplicate job ids"):
        _controller([_job("a", "ok"), _job("a", "ok")], stub, tmp_path)
    with pytest.raises(ValueError, match="needs 8 slots"):
        _controller([_job("big", "ok", mesh=(4, 2))], stub, tmp_path,
                    total_slots=4)
    with pytest.raises(ValueError, match="exceeds the 4-slot pool"):
        _controller([_job("a", "ok")], stub, tmp_path,
                    reshape_on_retry=((8, 1),))
    with pytest.raises(ValueError, match="unknown fault kind"):
        _controller([_job("a", "ok")], stub, tmp_path,
                    fault_spec="explode:1")


def test_report_schema_is_json_serializable(stub, tmp_path):
    ctl = _controller([_job("j0", "crash-once"), _job("bad", "poison")],
                      stub, tmp_path)
    results = ctl.run()
    doc = json.loads(json.dumps(ctl.report(results)))
    assert doc["schema"] == "fleet-report/v1"
    assert doc["counters"]["fleet.jobs.completed"] == 1
    assert set(doc["jobs"]) == {"j0", "bad"}
    assert doc["jobs"]["j0"]["status"] == "completed"
    assert doc["jobs"]["j0"]["final_step"] == 3
    assert doc["jobs"]["bad"]["failures"][0]["kind"] == "poison"


# ---------------------------------------------------------------------------
# the CLI's ensemble builder (no subprocess)
# ---------------------------------------------------------------------------

def test_cli_build_jobs_sweep_and_replicas():
    from repro.fleet.cli import build_jobs, build_parser

    ap = build_parser()
    sweep = build_jobs(ap.parse_args(
        ["--sweep", "kappa=0.05,0.1,0.2", "--submesh", "2x2"]))
    assert [j.params for j in sweep] == \
        [{"kappa": 0.05}, {"kappa": 0.1}, {"kappa": 0.2}]
    assert all(j.mesh == (2, 2) for j in sweep)
    reps = build_jobs(ap.parse_args(["--jobs", "3"]))
    assert [j.scale for j in reps] == [1.0, 1.25, 1.5]
    assert [j.job_id for j in reps] == ["job0", "job1", "job2"]
    with pytest.raises(SystemExit):
        build_jobs(ap.parse_args(["--submesh", "banana"]))
    with pytest.raises(SystemExit):
        build_jobs(ap.parse_args(["--sweep", "kappa"]))
    with pytest.raises(SystemExit):
        build_jobs(ap.parse_args(["--jobs", "0"]))
