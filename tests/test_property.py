"""Property-based (hypothesis) tests of system invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import comm
from repro.core.decomposition import PencilGrid
from repro.core import perfmodel as pm
from repro.data.pipeline import DataConfig, Pipeline
from repro.distributed import compression as comp
from repro.kernels import ref

SET = dict(max_examples=25, deadline=None)

pow2 = st.sampled_from([4, 8, 16, 32, 64, 128])


@given(n=pow2, batch=st.integers(1, 5), seed=st.integers(0, 2 ** 16))
@settings(**SET)
def test_fft_linearity(n, batch, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(batch, n)
    y = rng.randn(batch, n)
    a, b = rng.randn(2)
    fx = np.asarray(ref.fft_dif_planar(jnp.asarray(x), jnp.zeros_like(jnp.asarray(x)))[0])
    fy = np.asarray(ref.fft_dif_planar(jnp.asarray(y), jnp.zeros_like(jnp.asarray(y)))[0])
    fz = np.asarray(ref.fft_dif_planar(jnp.asarray(a * x + b * y),
                                       jnp.zeros_like(jnp.asarray(x)))[0])
    np.testing.assert_allclose(fz, a * fx + b * fy, rtol=1e-9, atol=1e-9)


@given(n=pow2, seed=st.integers(0, 2 ** 16))
@settings(**SET)
def test_fft_parseval(n, seed):
    rng = np.random.RandomState(seed)
    xr = rng.randn(3, n)
    xi = rng.randn(3, n)
    yr, yi = ref.fft_dif_planar(jnp.asarray(xr), jnp.asarray(xi))
    e_t = np.sum(xr ** 2 + xi ** 2)
    e_f = float(jnp.sum(yr ** 2 + yi ** 2)) / n
    np.testing.assert_allclose(e_f, e_t, rtol=1e-10)


@given(n=pow2, seed=st.integers(0, 2 ** 16))
@settings(**SET)
def test_fft_roundtrip(n, seed):
    rng = np.random.RandomState(seed)
    xr = jnp.asarray(rng.randn(2, n))
    xi = jnp.asarray(rng.randn(2, n))
    yr, yi = ref.fft_dif_planar(xr, xi)
    br, bi = ref.ifft_dif_planar(yr, yi)
    np.testing.assert_allclose(np.asarray(br), np.asarray(xr), atol=1e-10)
    np.testing.assert_allclose(np.asarray(bi), np.asarray(xi), atol=1e-10)


@given(n=st.sampled_from([2, 4, 8, 16, 64, 256, 1024]))
@settings(**SET)
def test_bitrev_involution(n):
    p = ref.bitrev_permutation(n)
    np.testing.assert_array_equal(p[p], np.arange(n))
    assert sorted(p.tolist()) == list(range(n))  # permutation


@given(pu=st.sampled_from([1, 2, 4, 8]), pv=st.sampled_from([1, 2, 4]),
       n=st.sampled_from([32, 64, 128]))
@settings(**SET)
def test_pencil_shapes_tile_volume(pu, pv, n):
    g = PencilGrid(pu=pu, pv=pv)
    g.validate((n, n, n))
    for shape in (g.x_pencil_local((n, n, n)), g.y_pencil_local((n, n, n)),
                  g.z_pencil_local((n, n, n))):
        assert np.prod(shape) * g.p == n ** 3
    kxp = g.padded_r2c_len(n)
    assert kxp >= n // 2 + 1 and kxp % pu == 0


@given(engine=st.sampled_from(comm.ENGINE_NAMES),
       fold=st.sampled_from(["xy", "yz"]),
       n=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2 ** 16))
@settings(**SET)
def test_engine_fold_unfold_identity(engine, fold, n, seed):
    # any engine's unfold∘fold is the identity — including pallas_ring,
    # whose off-TPU exchanges run the kernel's interpret-mode fallback
    # (here on the degenerate 1×1 grid, where folds reduce to pure local
    # transposes — the distributed version of the same property runs in
    # tests/_dist_transpose_check.py on 4x2/2x4/8x1 meshes)
    g = PencilGrid(pu=1, pv=1, u_axes=(), v_axes=())
    eng = comm.build_engine(comm.EngineSpec(engine=engine), g)
    x = jnp.asarray(np.random.RandomState(seed).randn(n, n, n))
    back = eng.unfold(fold, eng.fold(fold, x))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


@given(p=st.sampled_from([2, 4, 8]), blk=st.sampled_from([1, 3, 4]),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_nic_staging_kernels_roundtrip(p, blk, seed):
    # the interpret-mode fallback's Pallas NIC staging: taking every block
    # out of a stacked buffer and placing each into its slot of a fresh
    # buffer reproduces the buffer exactly (the local data movement the
    # RDMA engine performs around each wire hop)
    from repro.kernels import ring_rdma

    xs = jnp.asarray(np.random.RandomState(seed).randn(p, blk, 5))
    out = jnp.zeros_like(xs)
    for i in range(p):
        b = ring_rdma.nic_take(xs, i)
        np.testing.assert_array_equal(np.asarray(b)[0], np.asarray(xs)[i])
        out = ring_rdma.nic_place(out, b, (i + 1) % p)  # land at a new slot
    want = np.roll(np.asarray(xs), 1, axis=0)
    np.testing.assert_array_equal(np.asarray(out), want)


@given(engine=st.sampled_from(comm.ENGINE_NAMES),
       n=st.sampled_from([32, 64, 256]),
       pu=st.sampled_from([1, 2, 4, 8]), pv=st.sampled_from([1, 2, 8]))
@settings(**SET)
def test_chunk_model_invariants(engine, n, pu, pv):
    # the engine-aware chunk model always proposes a power of two, returns
    # 1 exactly when nothing communicates, and never exceeds its cap
    k = pm.optimal_chunks(n, pu, pv, comm_engine=engine)
    assert 1 <= k <= pm.MAX_MODEL_CHUNKS and (k & (k - 1)) == 0
    if pu == 1 and pv == 1:
        assert k == 1
    cands = pm.chunk_candidates(n, pu, pv, engine)
    assert all(2 <= c <= pm.MAX_MODEL_CHUNKS and (c & (c - 1)) == 0
               for c in cands)


FACTORIZATIONS = [(2, 2), (4, 2), (2, 2, 2), (4, 4), (8,), (3, 2), (1, 4)]


@given(engine=st.sampled_from(comm.ENGINE_NAMES),
       sizes=st.sampled_from(FACTORIZATIONS))
@settings(**SET)
def test_fold_messages_per_axis(engine, sizes):
    # per-axis message counts: a grid dimension spanning mesh axes of sizes
    # (q0, q1, ...) posts the sum of its per-axis single-ring counts on the
    # torus fabric, and still one all-to-all on the switched fabric
    fabric = pm.ENGINE_FABRIC[engine]
    got = pm.fold_messages(sizes, fabric, engine)
    per_axis = sum(pm.fold_messages(q, fabric, engine) for q in sizes)
    if fabric == "switched":
        assert got == (1 if any(q > 1 for q in sizes) else 0)
    else:
        assert got == per_axis
    # a single-axis tuple and the bare int agree, size-1 axes are free
    q = int(np.prod(sizes))
    assert pm.fold_messages((q,), fabric, engine) == \
        pm.fold_messages(q, fabric, engine)
    assert pm.fold_messages(tuple(sizes) + (1, 1), fabric, engine) == got


@given(engine=st.sampled_from(comm.ENGINE_NAMES),
       n=st.sampled_from([32, 64]), sizes=st.sampled_from(FACTORIZATIONS))
@settings(**SET)
def test_staged_pricing_never_beaten_by_flat(engine, n, sizes):
    # pricing the u dimension as staged per-axis rings is never slower than
    # one flat ring over the product group (fewer, shorter rings — the
    # multi-hop torus penalty grows with the ring size), and is identical
    # on the switched fabric (still one all-to-all)
    pu = int(np.prod(sizes))
    flat = pm.estimate_plan_seconds(n, pu, 2, comm_engine=engine)
    staged = pm.estimate_plan_seconds(n, pu, 2, comm_engine=engine,
                                      pu_axes=sizes)
    if pm.ENGINE_FABRIC[engine] == "switched" or len(
            [q for q in sizes if q > 1]) <= 1:
        assert staged == pytest.approx(flat)
    else:
        assert staged <= flat * (1 + 1e-12)
    # pu_axes must factor pu
    with pytest.raises(ValueError):
        pm.estimate_plan_seconds(n, pu, 2, comm_engine=engine,
                                 pu_axes=(pu, 3))


@given(engine=st.sampled_from(comm.ENGINE_NAMES),
       n=st.sampled_from([32, 64, 256]), sizes=st.sampled_from(FACTORIZATIONS))
@settings(**SET)
def test_chunk_model_per_axis_invariants(engine, n, sizes):
    # the chunk model keeps its invariants under per-axis round pricing,
    # whether driven by explicit kwargs or an EngineSpec
    pu = int(np.prod(sizes))
    k = pm.optimal_chunks(n, pu, 2, comm_engine=engine, pu_axes=sizes)
    assert 1 <= k <= pm.MAX_MODEL_CHUNKS and (k & (k - 1)) == 0
    k2 = pm.optimal_chunks(n, pu, 2, spec=pm.EngineSpec(engine=engine),
                           pu_axes=sizes)
    assert k2 == pm.optimal_chunks(n, pu, 2, comm_engine=engine,
                                   pu_axes=sizes)


@given(seed=st.integers(0, 2 ** 20), step=st.integers(0, 1000),
       shards=st.sampled_from([1, 2, 4]))
@settings(**SET)
def test_pipeline_pure_function_of_step(seed, step, shards):
    cfg = DataConfig(vocab=101, seq_len=8, global_batch=4, seed=seed)
    a = Pipeline(cfg, 0, shards).batch_for_step(step)["tokens"]
    b = Pipeline(cfg, 0, shards).batch_for_step(step)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert a.max() < 101 and a.min() >= 0


@given(seed=st.integers(0, 2 ** 16), scale=st.floats(1e-5, 1e4))
@settings(**SET)
def test_quantization_error_bound(seed, scale):
    rng = np.random.RandomState(seed)
    g = jnp.asarray(rng.randn(64) * scale, jnp.float32)
    q, s = comp.quantize_int8(g)
    err = np.max(np.abs(np.asarray(comp.dequantize_int8(q, s)) - np.asarray(g)))
    assert err <= float(s) * 0.5 + 1e-12  # round-to-nearest bound


@given(n=st.sampled_from([512, 1024, 2048, 4096]),
       f=st.sampled_from([180e6, 250e6, 380e6]))
@settings(**SET)
def test_perfmodel_monotonicity(n, f):
    # more rows -> strictly faster engine, more throughput required
    ts = [pm.t_fft_seconds(n, r, 9, f) for r in (1, 2, 4)]
    assert ts[0] > ts[1] > ts[2]
    bs = [pm.b_fft_bytes_per_s(r, f) for r in (1, 2, 4)]
    assert bs[0] < bs[1] < bs[2]
    # torus bandwidth grows without bound in P; switched saturates
    assert pm.b_net_torus(1024, 4, f) > pm.b_net_torus(64, 4, f)
    assert pm.b_net_switched(1024, 4, f) <= pm.b_fft_bytes_per_s(4, f)


@given(mu=st.integers(1, 4))
@settings(**SET)
def test_pipelined_beats_sequential_at_equal_Q(mu):
    # Table 4.1: at k=1 (pipelined Q=4 vs sequential Q=1), pipelined total
    # time (mu+1)/2 < sequential 2*mu for all mu >= 1
    t = pm.table_4_1(mu)
    assert t["pipelined"]["T_tot"] < t["sequential"]["T_tot"] or mu == 1
