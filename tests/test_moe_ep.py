"""Expert-parallel MoE (shard_map all_to_all dispatch) must match the dense
single-device reference when no tokens are dropped."""

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = """
from repro.launch.mesh import ensure_host_devices
ensure_host_devices(8)
import jax, jax.numpy as jnp, numpy as np
from repro.models.moe import MoEDims, init_moe, apply_moe, apply_moe_ep
from repro.models.common import Initializer

from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
m = MoEDims(d_model=32, n_experts=8, top_k=2, d_ff_expert=16,
            capacity_factor=8.0, router_norm_topk=True)
ini = Initializer(key=jax.random.PRNGKey(0), dtype=jnp.float32)
p = init_moe(ini, m)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

ref = apply_moe(p, m, x)
for chunks in (1, 4):
    got = jax.jit(lambda xx: apply_moe_ep(p, m, xx, mesh, chunks=chunks))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("EP_OK chunks", chunks)

# shared-expert variant (deepseek-style)
m2 = MoEDims(d_model=32, n_experts=8, top_k=2, d_ff_expert=16, n_shared=1,
             d_ff_shared=24, capacity_factor=8.0, router_norm_topk=False)
ini2 = Initializer(key=jax.random.PRNGKey(2), dtype=jnp.float32)
p2 = init_moe(ini2, m2)
ref2 = apply_moe(p2, m2, x)
got2 = jax.jit(lambda xx: apply_moe_ep(p2, m2, xx, mesh))(x)
np.testing.assert_allclose(np.asarray(got2), np.asarray(ref2),
                           rtol=2e-5, atol=2e-5)
print("EP_SHARED_OK")
"""


def test_moe_ep_matches_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", CODE], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "EP_OK chunks 1" in out.stdout
    assert "EP_OK chunks 4" in out.stdout
    assert "EP_SHARED_OK" in out.stdout
