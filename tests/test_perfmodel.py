"""Validate the analytic model against the thesis' own published tables.

These are the paper's claims; the model must reproduce them (EXPERIMENTS.md
cites this file as the faithful-reproduction evidence for Tables 4.x/5.x).
"""


import pytest

from repro.core import perfmodel as pm
from repro.core import topology as topo


# --- Tables 5.1/5.2, R=1 rows (latency cycles, l_FFT, T_FFT, B_FFT, GFLOPS) --
# (N, l_op, f_MHz, latency_cycles, l_fft_us, t_fft_us, b_gib_s, gflops)
TABLE_5_2 = [
    (512, 3, 250, 382, 1.53, 2.55, 7.45, 22.5),
    (1024, 3, 247, 652, 2.64, 4.71, 7.36, 24.7),
    (2048, 3, 251, 1178, 4.69, 8.77, 7.48, 27.61),
    (4096, 3, 244, 2216, 9.08, 17.48, 7.27, 29.28),
    (8192, 3, 236, 4278, 18.13, 35.48, 7.03, 30.68),
    (512, 6, 348, 463, 1.33, 2.07, 10.37, 31.32),
    (2048, 9, 379, 1376, 3.63, 6.33, 11.30, 41.69),
]

# R=2 (Table 5.4) and R=4 (Table 5.6) spot rows
TABLE_5_4 = [(512, 3, 238, 254, 1.07, 1.61, 14.19, 42.84),
             (8192, 9, 377, 2464, 6.54, 11.97, 22.47, 98.8)]
TABLE_5_6 = [(512, 3, 226, 190, 0.84, 1.12, 26.94, 81.36),
             (4096, 9, 378, 896, 2.37, 3.72, 45.06, 181.44)]


@pytest.mark.parametrize("row", TABLE_5_2)
def test_table_5_2_r1(row):
    _check_engine_row(1, *row)


@pytest.mark.parametrize("row", TABLE_5_4)
def test_table_5_4_r2(row):
    _check_engine_row(2, *row)


@pytest.mark.parametrize("row", TABLE_5_6)
def test_table_5_6_r4(row):
    _check_engine_row(4, *row)


def _check_engine_row(r, n, l_op, f_mhz, lat, lfft_us, tfft_us, b_gib, gflops):
    pt = pm.EnginePoint(n=n, r=r, l_op=l_op, f_mhz=f_mhz)
    assert pt.latency_cycles == lat
    assert pt.l_fft_us == pytest.approx(lfft_us, rel=0.01)
    assert pt.t_fft_us == pytest.approx(tfft_us, rel=0.01)
    assert pt.b_fft_gib_s == pytest.approx(b_gib, rel=0.01)
    assert pt.gflops == pytest.approx(gflops, rel=0.01)


def test_l_butterfly_eq_5_2():
    # l_op=14 programmable max is reported as "14 (12)" in the tables; the
    # simple stages give l_but = 3*14+4 = 46
    assert pm.l_butterfly(3) == 13
    assert pm.l_butterfly(9) == 31


def test_table_4_1_normalized():
    t = pm.table_4_1(mu=3)
    assert t["sequential"]["T_tot"] == 6
    assert t["pipelined"]["T_tot"] == 2
    assert t["parallel"]["T_tot"] == 2
    assert t["pipelined"]["Q"] == 4 and t["pipelined"]["N_NET"] == 2
    assert t["parallel"]["M"] == 6


def test_table_4_2_fixed_q4():
    t = pm.table_4_2(mu=4)
    assert t["sequential"]["T_tot"] == 2.0 and t["sequential"]["B"] == 4
    assert t["pipelined"]["T_tot"] == 2.5 and t["pipelined"]["B"] == 1


# --- Table 5.7: the global projection, μ=1 and μ=3 ---------------------------
T57_MU1 = {(512, 1): 0.17, (512, 4): 0.047, (512, 16): 0.011, (512, 64): 0.0029,
           (512, 256): 0.00073, (512, 1024): 0.00018,
           (1024, 4): 0.37, (1024, 16): 0.093, (1024, 64): 0.023,
           (2048, 16): 0.74, (2048, 64): 0.19, (4096, 256): 0.37,
           (8192, 1024): 0.75}
T57_MU3 = {(512, 1): 0.37, (1024, 4): 0.75, (2048, 16): 1.49, (8192, 1024): 1.49}


@pytest.mark.parametrize("key,val", sorted(T57_MU1.items()))
def test_table_5_7_mu1(key, val):
    n, p = key
    got = pm.global_fft_time(n, p, mu=1)
    # thesis' own P=1 cell is self-inconsistent by ~9%; other cells are
    # printed to 2 significant digits (≤7% rounding)
    tol = 0.12 if (n, p) == (512, 1) else 0.07
    assert got == pytest.approx(val, rel=tol)


@pytest.mark.parametrize("key,val", sorted(T57_MU3.items()))
def test_table_5_7_mu3(key, val):
    n, p = key
    assert pm.global_fft_time(n, p, mu=3) == pytest.approx(val, rel=0.05)


def test_table_5_7_feasibility_mask():
    t = pm.table_5_7()
    # empty cells of the printed table
    for n, p in [(1024, 1), (2048, 1), (2048, 4), (4096, 16), (4096, 64),
                 (8192, 256)]:
        assert t[n][p] is None, (n, p)
    # filled boundary cells
    for n, p in [(2048, 16), (8192, 1024), (4096, 256), (1024, 4)]:
        assert t[n][p] is not None, (n, p)


# --- Network model (Figs 5.11/5.12) ------------------------------------------
def test_b_fft_r4_f380_exceeds_200g():
    # thesis: at R=4 and fast clock the required bandwidth "easily reaches
    # excessive values" — ~389 Gb/s > 200 Gb/s links
    b = pm.b_fft_bytes_per_s(4, 380e6) * 8 / 1e9
    assert b == pytest.approx(389.1, rel=0.01)


def test_torus_vs_switched_scalability():
    s = topo.scalability_summary(link_gbps=200.0)
    # torus suffers the (√P−1) multi-hop factor: fine only for small grids
    assert s[("torus", 4, 180.0)] <= 16
    # switched fabric scales to the full 32×32 grid at moderated frequency
    assert s[("switched", 4, 180.0)] == 32 * 32
    # switched required bw saturates below 4sRf — always fits if B_FFT fits
    for q in (2, 8, 32):
        assert pm.b_net_switched(q * q, 4, 180e6) < pm.b_fft_bytes_per_s(4, 180e6)


def test_nic_count_and_switch_count():
    tor = topo.NetworkPlan("torus", 256, 4, 180.0)
    sw = topo.NetworkPlan("switched", 256, 4, 180.0)
    assert tor.nics_per_node == 4 and sw.nics_per_node == 2
    assert tor.n_switches == 0 and sw.n_switches == 32


def test_required_ram_fig_1_1():
    # Fig 1.1: single node at N=256 ≈ 0.25 GB; N=4096 ≈ 1024 GB
    assert pm.required_ram_per_node(256, 1) / 2**30 == pytest.approx(0.25, rel=0.01)
    assert pm.required_ram_per_node(4096, 1) / 2**30 == pytest.approx(1024, rel=0.01)


def test_memory_models_ch4():
    # Eq 4.8 vs Eq 4.17: pipelined adds only the 2sN²/Pu plane buffer
    n, p, pu = 1024, 16, 4
    seq = pm.m_tot_sequential_bytes(n, p)
    pipe = pm.m_tot_pipelined_bytes(n, p, pu)
    assert pipe - seq == pytest.approx(2 * 8 * n**2 / pu)
