"""Distributed transpose equivalence: torus ring vs switched all-to-all must
be bit-identical, and folds must round-trip, on non-trivial Pu×Pv grids
(paper §5.5 — the two network models compute the same relayout)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("shape", ["4x2", "2x4", "8x1"])
def test_torus_matches_switched(shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_dist_transpose_check.py"),
         shape],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ALL_OK" in out.stdout
    assert "composed_folds_bitexact OK" in out.stdout
