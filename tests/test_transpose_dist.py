"""Distributed TransposeEngine equivalence: every engine (switched all-to-all,
torus ring, compute-overlapped ring, Pallas async-RDMA ring in interpret
mode, bidirectional two-NIC ring) must compute the identical relayout,
``unfold ∘ fold`` must be the identity, and the full 3D FFT built on each
engine must be allclose (fp64, 1e-10) to the switched reference for forward
and forward∘inverse, on non-trivial Pu×Pv grids (paper §5.5, Fig. 4.3).

The mesh list covers the ring degenerate cases the bidirectional engine
must get right: ``2x1`` (P=2 — both directions hit the same neighbor) and
``3x2`` (odd ring dimension — unbalanced direction split every round).
``2x2x2`` is the multi-axis pencil (u spans two mesh axes): every ring
engine must run one staged per-axis ring per mesh axis, bit-exact vs the
flat switched exchange. ``4x4`` runs per-axis rings on both mesh axes of a
square 16-device grid (the 8x4 CI cell covers 32 devices).
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

RING_ENGINES = ("torus", "overlap_ring", "pallas_ring", "bidi_ring")
OVERLAPPED = ("overlap_ring", "pallas_ring", "bidi_ring")


@pytest.mark.parametrize("shape", ["4x2", "2x4", "8x1", "2x1", "3x2",
                                   "2x2x2", "4x4"])
def test_engines_match_switched(shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_dist_transpose_check.py"),
         shape],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ALL_OK" in out.stdout
    assert "composed_folds_bitexact OK" in out.stdout
    assert "exchange_round_counts OK" in out.stdout
    for engine in RING_ENGINES:
        assert f"fft_{engine}_allclose OK" in out.stdout
        for fold in ("xy", "yz"):
            assert f"{fold}_roundtrip_{engine} OK" in out.stdout
            assert f"{fold}_relayout_bitexact_{engine} OK" in out.stdout
    # the overlapped rings also cover the pipelined schedule and the real
    # (r2c) data model — pallas_ring exercising its interpret-mode fallback
    # and bidi_ring its counter-rotating ppermute streams
    for engine in OVERLAPPED:
        assert f"fft_{engine}_pipelined OK" in out.stdout
        assert f"fft_{engine}_real OK" in out.stdout


def test_engine_filter_unknown_engine_fails():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_dist_transpose_check.py"),
         "4x2", "--engine", "carrier_pigeon"],
        env=env, capture_output=True, text=True, timeout=120)
    assert out.returncode != 0
    assert "carrier_pigeon" in out.stderr
