"""``repro.serving`` subsystem: the fingerprint contract, queue batching
and fairness, registry reuse, the batched-vs-solo identity, streaming
order, backpressure, load-generator stats, and the serving metrics.
"""

import pytest

from repro import compat, obs
from repro.serving import (EngineRegistry, LoadReport, QueueFullError,
                           RequestQueue, SimRequest, SimResult, SimServer,
                           StepUpdate, Ticket, percentile_us, request_key,
                           run_load, scaled_initial_fields)
from repro.solvers import SolverState


@pytest.fixture(scope="module")
def mesh11():
    return compat.make_mesh((1, 1), ("data", "model"))


def _req(**kw):
    base = dict(case="heat", n=8, steps=2, dtype="float64")
    base.update(kw)
    return SimRequest(**base)


def _ticket(seq, **kw):
    req = _req(**kw)
    return Ticket(req, request_key(req), seq)


# ---------------------------------------------------------------------------
# fingerprint contract
# ---------------------------------------------------------------------------

def test_request_key_ignores_per_request_knobs():
    # steps / scale / request_id never enter the fingerprint: requests
    # differing only there share one compiled engine and batch together
    a = _req(steps=1, scale=1.0, request_id="a")
    b = _req(steps=7, scale=2.5, request_id="b")
    assert request_key(a) == request_key(b)


def test_request_key_separates_engine_shaping_fields():
    base = request_key(_req())
    assert request_key(_req(case="nls")) != base
    assert request_key(_req(n=16)) != base
    assert request_key(_req(dtype="float32")) != base
    assert request_key(_req(params={"kappa": 0.5})) != base
    assert request_key(_req(plan_cfg={"comm_engine": "torus"})) != base


def test_request_key_normalizes_plan_cfg_spellings():
    # the tuning layer's legacy knob mapping (net -> comm_engine) applies
    # before hashing, so equivalent spellings collide onto one key
    a = _req(plan_cfg={"net": "torus"})
    b = _req(plan_cfg={"comm_engine": "torus"})
    assert request_key(a) == request_key(b)
    key = request_key(a)
    assert key.startswith("heat_n8x8x8_float64_")


# ---------------------------------------------------------------------------
# queue: lanes, fairness, backpressure
# ---------------------------------------------------------------------------

def test_queue_groups_by_fingerprint_and_drains_in_arrival_order():
    q = RequestQueue()
    t1 = _ticket(1, request_id="h1")
    t2 = _ticket(2, case="nls", request_id="n1")
    t3 = _ticket(3, request_id="h2")
    for t in (t1, t2, t3):
        q.submit(t)
    assert q.depth == 3
    assert sorted(q.lanes().values()) == [1, 2]
    # lane of the globally oldest head first (heat, seq 1), FIFO within it
    batch = q.next_batch(8)
    assert [t.request.request_id for t in batch] == ["h1", "h2"]
    assert q.next_batch(8) == [t2]
    assert q.next_batch(8) == [] and q.depth == 0


def test_queue_fairness_oldest_head_wins_even_in_smaller_lane():
    q = RequestQueue()
    q.submit(_ticket(1, case="nls"))          # oldest overall
    q.submit(_ticket(2, request_id="h1"))     # bigger lane, younger head
    q.submit(_ticket(3, request_id="h2"))
    first = q.next_batch(8)
    assert [t.request.case for t in first] == ["nls"]


def test_queue_max_batch_caps_the_drain():
    q = RequestQueue()
    for i in range(5):
        q.submit(_ticket(i + 1, request_id=f"r{i}"))
    assert len(q.next_batch(2)) == 2
    assert q.depth == 3


def test_queue_backpressure_rejects_above_max_pending():
    q = RequestQueue(max_pending=2)
    q.submit(_ticket(1))
    q.submit(_ticket(2))
    with pytest.raises(QueueFullError, match="max_pending=2"):
        q.submit(_ticket(3))
    assert q.depth == 2  # the rejected ticket never entered
    with pytest.raises(ValueError, match="max_pending"):
        RequestQueue(max_pending=0)


def test_queue_rejection_carries_retry_hint():
    q = RequestQueue(max_pending=2, retry_hint_s=0.1)
    q.submit(_ticket(1))
    q.submit(_ticket(2))
    with pytest.raises(QueueFullError) as e:
        q.submit(_ticket(3))
    # depth == bound at rejection: hint is exactly the base
    assert e.value.retry_after_hint == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# registry: one live engine per fingerprint
# ---------------------------------------------------------------------------

def test_registry_reuses_the_same_engine_instance(mesh11):
    reg = EngineRegistry(mesh11, use_plan_cache=False)
    a = reg.get(_req(steps=1, request_id="a"))
    b = reg.get(_req(steps=9, request_id="b"))   # same fingerprint
    assert a is b and len(reg) == 1              # shared jit cache
    c = reg.get(_req(params={"kappa": 0.5}))
    assert c is not a and len(reg) == 2
    assert c.params()["kappa"] == 0.5


def test_registry_picks_up_autotuned_plan_from_cache(mesh11, tmp_path):
    from repro.tuning.cache import PlanCache

    cache = str(tmp_path / "plans.json")
    probe = EngineRegistry(mesh11, use_plan_cache=False).get(_req())
    PlanCache(cache).put(probe.problem_key(),
                         {"best": {"comm_engine": "torus"}})
    reg = EngineRegistry(mesh11, use_plan_cache=True, cache_path=cache)
    solver = reg.get(_req())
    assert solver.plan.comm_engine == "torus"
    # an explicit plan_cfg bypasses the cache consult entirely
    pinned = reg.get(_req(plan_cfg={"comm_engine": "switched"}))
    assert pinned.plan.comm_engine == "switched"


# ---------------------------------------------------------------------------
# server: batched == solo, streaming, run-to-longest
# ---------------------------------------------------------------------------

def _solo_history(solver, scale, steps):
    st = SolverState(fields=scaled_initial_fields(solver, scale))
    history = [solver.observables(st)]
    for _ in range(steps):
        st = solver.step(st)
        history.append(solver.observables(st))
    return history


def test_batched_histories_identical_to_solo_runs(mesh11):
    server = SimServer(mesh11, max_batch=8, use_plan_cache=False)
    reqs = [_req(steps=2, scale=1.0, request_id="r0"),
            _req(steps=3, scale=1.5, request_id="r1"),
            _req(steps=1, scale=2.0, request_id="r2")]
    tickets = [server.submit(r) for r in reqs]
    assert server.serve_pending() == 3
    solver = server.registry.get(reqs[0])
    for req, ticket in zip(reqs, tickets):
        res = ticket.result(timeout=5)
        assert res.ok and res.batch_size == 3
        assert len(res.history) == req.steps + 1
        # bitwise: float(...) == float(...) per observable, including "t"
        assert res.history == _solo_history(solver, req.scale, req.steps)


def test_ticket_streams_updates_in_step_order(mesh11):
    server = SimServer(mesh11, use_plan_cache=False)
    ticket = server.submit(_req(steps=3))
    server.serve_pending()
    updates = list(ticket.updates(timeout=5))
    assert [u.step for u in updates] == [0, 1, 2, 3]
    assert all(isinstance(u, StepUpdate) for u in updates)
    assert updates[1].t == pytest.approx(updates[3].t / 3)
    assert ticket.done
    res = ticket.result()
    assert isinstance(res, SimResult) and res.latency_s >= 0
    assert [u.observables for u in updates] == res.history


def test_run_to_longest_finishes_short_lanes_at_their_horizon(mesh11):
    # lanes with differing steps batch; each gets exactly steps+1 entries
    server = SimServer(mesh11, use_plan_cache=False)
    short = server.submit(_req(steps=0, request_id="short"))
    long = server.submit(_req(steps=4, request_id="long"))
    assert server.serve_once() == 2
    assert len(short.result().history) == 1      # just the t=0 diagnostics
    assert len(long.result().history) == 5


def test_server_pushes_error_result_instead_of_dying(mesh11):
    server = SimServer(mesh11, use_plan_cache=False)
    ticket = server.submit(_req(case="burgers", request_id="bad"))
    assert server.serve_once() == 1
    res = ticket.result(timeout=5)
    assert not res.ok and "unknown solver case" in res.error
    assert res.history == []
    # the lane's death left a structured record (the fleet's shared type)
    from repro.fleet.records import FailureRecord
    assert len(server.failures) == 1
    rec = server.failures[0]
    assert isinstance(rec, FailureRecord)
    assert rec.kind == "batch_error" and rec.where == "serving.batch"
    assert rec.job_id == "bad" and not rec.retryable
    assert "unknown solver case" in rec.detail
    # the failed batch didn't wedge the server
    ok = server.submit(_req())
    server.serve_pending()
    assert ok.result(timeout=5).ok


def test_server_backpressure_and_validation():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    server = SimServer(mesh, max_pending=1, use_plan_cache=False)
    server.submit(_req())
    with pytest.raises(QueueFullError):
        server.submit(_req())
    with pytest.raises(ValueError, match="steps"):
        server.submit(_req(steps=-1))
    with pytest.raises(ValueError, match="max_batch"):
        SimServer(mesh, max_batch=0)


def test_threaded_server_serves_submissions(mesh11):
    server = SimServer(mesh11, use_plan_cache=False)
    server.start()
    try:
        assert server.running
        tickets = [server.submit(_req(request_id=f"r{i}", scale=1.0 + i))
                   for i in range(3)]
        results = [t.result(timeout=30) for t in tickets]
        assert all(r.ok for r in results)
    finally:
        server.stop()
    assert not server.running


# ---------------------------------------------------------------------------
# load generator + metrics
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank():
    lat = [1.0, 2.0, 3.0, 4.0]      # already in µs, nearest-rank convention
    assert percentile_us(lat, 0.50) == 2.0
    assert percentile_us(lat, 0.99) == 4.0
    assert percentile_us([], 0.5) == 0.0


def test_run_load_burst_stats(mesh11):
    server = SimServer(mesh11, use_plan_cache=False)
    reqs = [_req(request_id=f"r{i}", scale=1.0 + 0.5 * i) for i in range(4)]
    report = run_load(server, reqs)
    assert isinstance(report, LoadReport)
    s = report.stats()
    assert s["n_requests"] == 4 and s["n_failed"] == 0
    assert s["requests_per_s"] > 0
    assert s["p50_us"] <= s["p95_us"] <= s["p99_us"]


def test_serving_metrics_counters_and_gauges(mesh11):
    with obs.capture() as (_, metrics):
        server = SimServer(mesh11, max_batch=2, use_plan_cache=False)
        tickets = [server.submit(_req(request_id=f"r{i}")) for i in range(3)]
        server.serve_pending()
        for t in tickets:
            assert t.result(timeout=5).ok
    c = metrics.counters()
    assert c["serving.requests.submitted"] == 3
    assert c["serving.requests.admitted"] == 3
    assert c["serving.requests.completed"] == 3
    assert c["serving.batches"] == 2             # 3 requests, max_batch 2
    assert c["serving.engine_cache.misses"] == 1
    assert c["serving.engine_cache.hits"] == 1   # second batch, warm engine
    g = metrics.gauges()
    assert g["serving.queue_depth"] == 0
    assert g["serving.batch_size"] in (1, 2)


def test_run_load_retries_backpressure_within_budget(mesh11):
    # a burst 3x the queue bound: every rejection is retried after a drain
    # pass, so nothing is shed and nothing is lost
    server = SimServer(mesh11, max_pending=1, use_plan_cache=False)
    reqs = [_req(request_id=f"r{i}", scale=1.0 + 0.5 * i) for i in range(3)]
    report = run_load(server, reqs, max_submit_retries=2,
                      retry_backoff_s=0.001)
    assert len(report.results) == 3 and all(r.ok for r in report.results)
    assert report.n_rejected == 0 and report.submit_retries == 2
    assert report.stats()["submit_retries"] == 2


def test_run_load_records_rejections_after_budget(mesh11):
    from repro.fleet.records import FailureRecord

    server = SimServer(mesh11, max_pending=1, use_plan_cache=False)
    reqs = [_req(request_id=f"r{i}") for i in range(3)]
    report = run_load(server, reqs)          # max_submit_retries=0: shed
    assert len(report.results) == 1 and report.n_rejected == 2
    assert report.n_requests == 3            # shed load still counted
    for rec in report.rejected:
        assert isinstance(rec, FailureRecord)
        assert rec.kind == "rejected" and rec.where == "serving.queue"
    assert [r.job_id for r in report.rejected] == ["r1", "r2"]
    assert report.stats()["n_rejected"] == 2


def test_rejected_counter_on_backpressure(mesh11):
    with obs.capture() as (_, metrics):
        server = SimServer(mesh11, max_pending=1, use_plan_cache=False)
        server.submit(_req())
        with pytest.raises(QueueFullError):
            server.submit(_req())
    assert metrics.counters()["serving.requests.rejected"] == 1
