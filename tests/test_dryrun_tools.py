"""Dry-run tooling units: HLO collective-byte parser and roofline math."""

from repro.launch.dryrun import collective_bytes
from benchmarks.roofline import analyze


HLO = """
ENTRY main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ag = f32[256,128]{1,0} all-gather(%p0), replica_groups={}, dimensions={0}
  %ar = (bf16[512]{0}, bf16[512]{0}) all-reduce(%x, %y), to_apply=%add
  %a2a.1 = f32[16,128]{1,0} all-to-all(%p0), dimensions={0}
  %cp = u8[64]{0} collective-permute(%q), source_target_pairs={{0,1}}
  %cps = f32[4,4]{1,0} collective-permute-start(%q2)
  %other = f32[999,999]{1,0} add(%p0, %p0)
}
"""


def test_collective_parser():
    got = collective_bytes(HLO)
    assert got["all-gather"] == 256 * 128 * 4
    assert got["all-reduce"] == 2 * 512 * 2
    assert got["all-to-all"] == 16 * 128 * 4
    assert got["collective-permute"] == 64 + 4 * 4 * 4
    assert got["total"] == (got["all-gather"] + got["all-reduce"]
                            + got["all-to-all"] + got["collective-permute"])
    assert got["all-gather_count"] == 1
    assert got["collective-permute_count"] == 2


def test_roofline_analyze():
    rec = {
        "status": "ok", "arch": "x", "shape": "train_4k", "mesh": "pod16x16",
        "chips": 256,
        "cost": {"flops": 197e12 * 0.5, "bytes_accessed": 819e9 * 0.25},
        "collectives": {"total": 50e9 * 0.1},
        "model_params_active": 1e9,
        "memory": {"peak_per_device_bytes": 10 * 2 ** 30},
    }
    a = analyze(rec)
    assert abs(a["compute_s"] - 0.5) < 1e-9
    assert abs(a["memory_s"] - 0.25) < 1e-9
    assert abs(a["collective_s"] - 0.1) < 1e-9
    assert a["dominant"] == "compute"
    assert a["fits_hbm"]
    # useful ratio: 6*1e9*(4096*256)/256 chips / flops
    want = 6 * 1e9 * 4096 * 256 / 256 / (197e12 * 0.5)
    assert abs(a["useful_ratio"] - want) < 1e-9
