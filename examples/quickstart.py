"""Quickstart: the paper's distributed 3D FFT in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Runs an 8-device (fake) 4x2 pencil grid, forward+inverse 3D FFT with the
pipelined schedule on every TransposeEngine (switched all-to-all, torus
ring, compute-overlapped ring), and checks against numpy.
"""

from repro.launch.mesh import ensure_host_devices

ensure_host_devices(8)

import jax  # noqa: F401  (device init)
import jax.numpy as jnp
import numpy as np

from repro import EngineSpec, compat, make_fft3d

mesh = compat.make_mesh((4, 2), ("data", "model"))
N = (32, 32, 32)

rng = np.random.RandomState(0)
field = rng.randn(*N).astype(np.float32)          # (y, z, x) X-pencil layout

for engine in ("switched", "torus", "overlap_ring"):
    spec = EngineSpec(engine=engine, schedule="pipelined", chunks=4, real=True)
    fwd, inv, plan = make_fft3d(mesh, N, spec=spec)
    kr, ki = fwd(jnp.asarray(field))              # spectral, (kx, ky, kz)
    back = inv(kr, ki)                            # physical again

    keep = N[0] // 2 + 1
    want = np.fft.fftn(np.fft.rfft(field, axis=2), axes=(0, 1)).transpose(2, 0, 1)
    got = (np.asarray(kr) + 1j * np.asarray(ki))[:keep]
    err_f = np.linalg.norm(got - want) / np.linalg.norm(want)
    err_b = np.linalg.norm(np.asarray(back) - field) / np.linalg.norm(field)
    print(f"engine={engine:12s} (net={plan.net})  forward rel-err {err_f:.2e}"
          f"   roundtrip {err_b:.2e}")
    assert err_f < 1e-5 and err_b < 1e-5

print("quickstart OK — pencil grid", (plan.grid.pu, plan.grid.pv),
      "schedule", plan.schedule)
