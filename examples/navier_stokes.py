"""Pseudo-spectral incompressible Navier–Stokes — the paper's case study
(§1.2). Thin CLI wrapper over the ``repro.solvers`` subsystem: the solver
itself lives in ``repro.solvers.navier_stokes`` (every time step is the
Fig. 3.3 cycle: forward 3D FFT -> spectral computation -> inverse 3D FFT ->
local computation, on the 2D pencil grid), the driver loop in
``repro.solvers.cli``.

    PYTHONPATH=src python examples/navier_stokes.py [--n 32] [--steps 10]

Taylor–Green vortex on a 2pi^3 box; prints kinetic energy decay (viscous
dissipation => monotone decrease) and checks divergence-free-ness.
Equivalent to:

    python -m repro.solvers.cli --case navier_stokes --mesh 4x2 ...
"""

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--nu", type=float, default=0.1)
    ap.add_argument("--dt", type=float, default=2e-3)
    ap.add_argument("--autotune", action="store_true",
                    help="pick the FFT plan by autotuning the whole "
                         "Navier–Stokes step (see repro.tuning.solver)")
    args = ap.parse_args(argv)

    from repro.solvers.cli import main as solver_main
    forwarded = ["--case", "navier_stokes", "--mesh", "4x2",
                 "--n", str(args.n), "--steps", str(args.steps),
                 "--nu", str(args.nu), "--dt", str(args.dt)]
    if args.autotune:
        forwarded.append("--autotune")
    return solver_main(forwarded)


if __name__ == "__main__":
    raise SystemExit(main())
