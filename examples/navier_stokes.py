"""End-to-end driver: pseudo-spectral incompressible Navier–Stokes — the
paper's case study (§1.2). Each time step is exactly Fig. 3.3's cycle:
forward 3D FFT -> spectral computation -> inverse 3D FFT -> local
computation, on the 2D pencil grid with the pipelined schedule.

    PYTHONPATH=src python examples/navier_stokes.py [--n 32] [--steps 10]

Taylor–Green vortex on a 2pi^3 box; prints kinetic energy decay (viscous
dissipation => monotone decrease) and checks divergence-free-ness.
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import spectral as sp
from repro.core.decomposition import PencilGrid
from repro.core.fft3d import FFT3DPlan, fft3d_vector_local, ifft3d_vector_local


def make_step(mesh, n, nu, dt, chunks=2, plan_cfg=None, vector_mode="streaming"):
    grid = PencilGrid.from_mesh(mesh)
    cfg = dict(schedule="pipelined", chunks=chunks, backend="jnp",
               comm_engine="switched", r2c_packed=False)
    if plan_cfg:
        from repro.tuning.space import normalize_config
        plan_cfg = normalize_config(plan_cfg)
        cfg.update({k: plan_cfg[k] for k in cfg if k in plan_cfg})
        vector_mode = plan_cfg.get("vector_mode", vector_mode)
    plan = FFT3DPlan(n=(n, n, n), grid=grid, real=True, **cfg)
    spec = P(None, *grid.pencil_spec())

    def rhs(vr, vi):
        """Spectral RHS: -P(u.grad u)^ - nu k^2 v^ (rotational form)."""
        # velocity to physical
        u = ifft3d_vector_local(plan, vr, vi, vector_mode=vector_mode)
        # vorticity w = curl u in spectral, to physical
        kx, ky, kz = sp.local_wavenumbers(plan, jnp.float64)
        def cross_spec(ar, ai):
            cr = jnp.stack([ky * ar[2] - kz * ar[1],
                            kz * ar[0] - kx * ar[2],
                            kx * ar[1] - ky * ar[0]])
            ci = jnp.stack([ky * ai[2] - kz * ai[1],
                            kz * ai[0] - kx * ai[2],
                            kx * ai[1] - ky * ai[0]])
            # i*k x v: (i k) x (vr + i vi) = -k x vi + i k x vr
            return -ci, cr
        wr, wi = cross_spec(vr, vi)
        w = ifft3d_vector_local(plan, wr, wi, vector_mode=vector_mode)
        # nonlinear term u x w in physical space
        uxw = jnp.stack([u[1] * w[2] - u[2] * w[1],
                         u[2] * w[0] - u[0] * w[2],
                         u[0] * w[1] - u[1] * w[0]])
        nr, ni = fft3d_vector_local(plan, uxw, None, vector_mode=vector_mode)
        mask = sp.dealias_mask(plan)
        nr, ni = nr * mask, ni * mask
        nr, ni = sp.project_divergence_free(plan, nr, ni)
        k2 = sp.k_squared(plan)
        return nr - nu * k2 * vr, ni - nu * k2 * vi

    def step(vr, vi):
        # RK2 (Heun)
        ar, ai = rhs(vr, vi)
        pr, pi = vr + dt * ar, vi + dt * ai
        br, bi = rhs(pr, pi)
        vr = vr + 0.5 * dt * (ar + br)
        vi = vi + 0.5 * dt * (ai + bi)
        vr, vi = sp.project_divergence_free(plan, vr, vi)
        e = sp.energy_spectrum_total(plan, vr, vi)
        # divergence diagnostic: max |k.v|
        kx, ky, kz = sp.local_wavenumbers(plan, jnp.float64)
        div = jnp.max(jnp.abs(kx * vr[0] + ky * vr[1] + kz * vr[2])) + \
            jnp.max(jnp.abs(kx * vi[0] + ky * vi[1] + kz * vi[2]))
        axes = tuple(grid.u_axes) + tuple(grid.v_axes)
        div = jax.lax.pmax(div, axes)
        return vr, vi, e, div

    fwd = jax.jit(compat.shard_map(
        functools.partial(fft3d_vector_local, plan, vector_mode=vector_mode),
        mesh=mesh, in_specs=(spec, None), out_specs=(spec, spec),
        check_vma=False))
    stepj = jax.jit(compat.shard_map(step, mesh=mesh, in_specs=(spec, spec),
                                  out_specs=(spec, spec, P(), P()),
                                  check_vma=False))
    return plan, fwd, stepj


def taylor_green(n):
    x = np.linspace(0, 2 * np.pi, n, endpoint=False)
    Y, Z, X = np.meshgrid(x, x, x, indexing="ij")  # (y, z, x) pencil layout
    u = np.cos(X) * np.sin(Y) * np.sin(Z)
    v = -np.sin(X) * np.cos(Y) * np.sin(Z)
    w = np.zeros_like(u)
    return np.stack([u, v, w])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--nu", type=float, default=0.1)
    ap.add_argument("--dt", type=float, default=2e-3)
    ap.add_argument("--autotune", action="store_true",
                    help="pick the FFT plan via repro.tuning instead of the "
                         "hardcoded pipelined/switched default")
    args = ap.parse_args(argv)

    mesh = compat.make_mesh((4, 2), ("data", "model"))
    plan_cfg = None
    if args.autotune:
        from repro.tuning import autotune
        res = autotune(mesh, args.n, real=True, components=3,
                       dtype="float64", verbose=True)
        plan_cfg = res.best_config
        hit = "cache hit" if res.cache_hit else "measured"
        print(f"autotuned plan ({hit}): {res.best.name}")
    plan, fwd, stepj = make_step(mesh, args.n, args.nu, args.dt,
                                 plan_cfg=plan_cfg)
    u0 = jnp.asarray(taylor_green(args.n))
    vr, vi = fwd(u0, None)

    energies = []
    t0 = time.time()
    for i in range(args.steps):
        vr, vi, e, div = stepj(vr, vi)
        energies.append(float(e))
        print(f"step {i:3d}  E = {float(e):.6f}  max|k.v| = {float(div):.2e}",
              flush=True)
        assert float(div) < 1e-8, "velocity left the divergence-free manifold"
    dt_wall = (time.time() - t0) / args.steps
    drops = all(b <= a * (1 + 1e-9) for a, b in zip(energies, energies[1:]))
    print(f"energy monotone decay: {drops}   {dt_wall * 1e3:.1f} ms/step")
    assert drops, "viscous flow must dissipate energy"
    return energies


if __name__ == "__main__":
    main()
