"""Serve a small model with batched requests: prefill + greedy decode.

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import main as serve_main


def main():
    toks = serve_main(["--arch", "smollm-360m", "--smoke",
                       "--batch", "4", "--prompt-len", "32", "--gen", "16"])
    assert toks.shape == (4, 16)
    print("serve_lm OK")


if __name__ == "__main__":
    main()
