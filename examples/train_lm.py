"""Train a ~100M-class LM for a few hundred steps on CPU (end-to-end driver).

    PYTHONPATH=src python examples/train_lm.py --steps 200

Uses a width-reduced smollm-family config (same 32-layer llama shape family,
~14M params so a few hundred steps finish on a CPU host), the deterministic
synthetic pipeline, AdamW, checkpointing every 50 steps, and prints the loss
curve. Loss must drop substantially from ~ln(V).
"""

import argparse
import math
import tempfile

from repro.launch.train import main as train_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args(argv)
    if not args.ckpt_dir:
        args.ckpt_dir = tempfile.mkdtemp(prefix="repro_train_lm_")

    losses = train_main([
        "--arch", "smollm-360m", "--smoke",
        "--steps", str(args.steps), "--batch", "16", "--seq", "128",
        "--lr", "1e-3", "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
    ])
    first, last = losses[0], sum(losses[-10:]) / 10
    print(f"loss: {first:.3f} -> {last:.3f} (ln V = {math.log(512):.3f})")
    # short CI runs only need a downward trend; the full 200-step run drops
    # well past 0.5 nats
    want = 0.5 if args.steps >= 150 else 0.02
    assert last < first - want, f"loss should fall by >{want} nats"


if __name__ == "__main__":
    main()
